"""Calibrated big.LITTLE GEMM simulator (paper validation layer).

This container has one CPU core and no Exynos 5422, so the paper's
experiments cannot be re-run directly.  Instead, this module implements a
discrete-event simulator of the paper's platform whose *only* calibration
inputs are the paper's own single-cluster measurements (Section 3.4) and
cache parameters (Section 3.3):

  * Cortex-A15 cluster: +2.8 GFLOPS per core for cores 1–3, +1.4 for the
    4th → 9.6 GFLOPS peak.
  * Cortex-A7 cluster: ≈2.4 GFLOPS peak with 4 cores.
  * (m_c, k_c): A15 (152, 952); A7 (80, 352); shared-k_c A7 m_c = 32.
  * Architecture-oblivious configs run the LITTLE cluster with the A15's
    parameters, whose A_c panel (152·952·8 B ≈ 1.16 MiB) overflows the A7's
    512 KiB L2 — modelled as a throughput penalty.

Everything else — SSS's ≈40 % of A15-only peak, the SAS optimum at ratio
5–6, CA-SAS's advantage at overloaded ratios, CA-DAS beating every static
variant — must *emerge* from the scheduling model.  Those derived claims
are asserted in ``tests/test_simulator.py`` and reported in EXPERIMENTS.md.

The schedulers exercised here are the same production partitioners from
:mod:`repro.core.schedule` that drive the TPU asymmetric training step —
the simulator is how we show they reproduce the paper before pointing them
at pods.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import blocking as B
from repro.core import schedule as S

DTYPE_BYTES = 8  # paper uses IEEE double precision


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """One cluster, calibrated from the paper's Section 3 measurements."""

    name: str
    n_cores: int
    # Cumulative GFLOPS with 1..n cores active (Section 3.4).
    cum_gflops: tuple[float, ...]
    cache: B.CacheHierarchy
    blocking: B.GotoBlocking
    # Power model (W): cluster static + per-core active; waiting threads
    # poll (paper Section 5.2.2: "idle but active, polling") at a fraction
    # of active power.
    p_static: float
    p_core: float
    poll_frac: float = 0.8

    def rate(self, n_cores: int) -> float:
        return self.cum_gflops[min(n_cores, self.n_cores) - 1] * 1e9

    def power_model(
        self, n_cores: Optional[int] = None, effective_rate: Optional[float] = None
    ) -> B.PowerModel:
        """The spec-level :class:`~repro.core.blocking.PowerModel` equivalent
        of this cluster's Exynos constants.

        ``idle_w`` is the cluster static draw; the per-core active power
        becomes a per-FLOP term at ``effective_rate`` (achieved FLOP/s,
        default the calibrated :meth:`rate` for ``n_cores``).  By
        construction, energy scored through the returned model equals the
        simulator's :func:`_energy` accounting for this cluster (less the
        shared ``P_BASE`` board term) whenever the workload runs at
        ``effective_rate`` — the cross-check tested in
        ``tests/test_energy.py``.
        """

        nc = self.n_cores if n_cores is None else int(n_cores)
        rate = self.rate(nc) if effective_rate is None else float(effective_rate)
        if rate <= 0:
            raise ValueError("effective_rate must be positive")
        return B.PowerModel(
            idle_w=self.p_static,
            flop_j=nc * self.p_core / rate,
            byte_j=0.0,
            poll_frac=self.poll_frac,
        )


A15 = ClusterModel(
    name="cortex-a15",
    n_cores=4,
    cum_gflops=(2.8, 5.6, 8.2, 9.6),
    cache=B.CORTEX_A15,
    blocking=B.PAPER_A15,
    p_static=0.50,
    p_core=0.75,
)
A7 = ClusterModel(
    name="cortex-a7",
    n_cores=4,
    cum_gflops=(0.65, 1.25, 1.85, 2.4),
    cache=B.CORTEX_A7,
    blocking=B.PAPER_A7,
    p_static=0.05,
    p_core=0.08,
)
P_BASE = 0.35  # DRAM + board (paper instruments DRAM/GPU sensors separately)

# Throughput penalty when a cluster runs with blocking parameters whose A_c
# panel overflows its L2 (architecture-oblivious configuration, Section 4).
MISFIT_L2_PENALTY = 0.80
MISFIT_L1_PENALTY = 0.90
GRAB_OVERHEAD_S = 20e-6  # Section 5.4 critical section
BARRIER_S = 5e-6

EXYNOS_5422 = (A15, A7)


@dataclasses.dataclass
class SimResult:
    strategy: str
    r: int
    gflops: float
    makespan_s: float
    energy_j: float
    gflops_per_w: float
    sizes: tuple[int, ...]      # units (rows/cols) per cluster
    busy_s: tuple[float, ...]


# ---------------------------------------------------------------------------
# Effective cluster throughput
# ---------------------------------------------------------------------------


def _size_ramp(r: int) -> float:
    """Performance ramp with problem size (paper Figure 5 saturates ~r≥3k)."""

    return r / (r + 256.0)


def _config_penalty(cluster: ClusterModel, cfg: B.GotoBlocking) -> float:
    pen = 1.0
    if cfg.a_panel_bytes(DTYPE_BYTES) > cluster.cache.l2_bytes * cluster.cache.l2_fill / 0.6 * 1.0:
        # A_c overflowing the usable L2 (architecture-oblivious config).
        pen *= MISFIT_L2_PENALTY
    if cfg.b_micropanel_bytes(DTYPE_BYTES) > cluster.cache.l1_bytes:
        pen *= MISFIT_L1_PENALTY
    return pen


def _fine_grain_eff(cluster: ClusterModel, cfg: B.GotoBlocking, fine: str, n_cores: int) -> float:
    """Load-balance efficiency of the intra-cluster loop (Sections 3.1, 5.3.1).

    Loop 4 partitions ``n_c / n_r`` micro-kernel columns (hundreds —
    plenty); Loop 5 partitions ``m_c / m_r`` rows (tens — scarce, the
    paper's stated reason Loop 4 wins).
    """

    par = (cfg.nc // cfg.nr) if fine == "loop4" else max(1, cfg.mc // cfg.mr)
    return par / (n_cores * math.ceil(par / n_cores))


def _cluster_rate(
    cluster: ClusterModel,
    cfg: B.GotoBlocking,
    *,
    r: int,
    fine: str = "loop4",
    n_cores: Optional[int] = None,
) -> float:
    n = n_cores if n_cores is not None else cluster.n_cores
    return (
        cluster.rate(n)
        * _size_ramp(r)
        * _config_penalty(cluster, cfg)
        * _fine_grain_eff(cluster, cfg, fine, n)
    )


# ---------------------------------------------------------------------------
# Energy
# ---------------------------------------------------------------------------


def _energy(
    clusters: Sequence[ClusterModel],
    busy: Sequence[float],
    active_cores: Sequence[int],
    makespan: float,
) -> float:
    e = P_BASE * makespan
    for cl, b, nc in zip(clusters, busy, active_cores):
        e += cl.p_static * makespan
        if nc > 0:
            wait = makespan - b
            e += nc * (cl.p_core * b + cl.poll_frac * cl.p_core * wait)
    return e


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


def simulate_single_cluster(
    r: int,
    cluster: ClusterModel,
    n_cores: int,
    *,
    fine: str = "loop4",
    clusters: Sequence[ClusterModel] = EXYNOS_5422,
) -> SimResult:
    """One cluster in isolation (paper Section 3.4 / Figure 5)."""

    flops = 2.0 * r**3
    rate = _cluster_rate(cluster, cluster.blocking, r=r, fine=fine, n_cores=n_cores)
    t = flops / rate
    busy = [t if cl is cluster else 0.0 for cl in clusters]
    cores = [n_cores if cl is cluster else 0 for cl in clusters]
    e = _energy(clusters, busy, cores, t)
    return SimResult(
        strategy=f"{cluster.name}-x{n_cores}",
        r=r,
        gflops=flops / t / 1e9,
        makespan_s=t,
        energy_j=e,
        gflops_per_w=flops / 1e9 / e,
        sizes=tuple(r if cl is cluster else 0 for cl in clusters),
        busy_s=tuple(busy),
    )


def ideal_gflops(r: int, clusters: Sequence[ClusterModel] = EXYNOS_5422) -> float:
    """The paper's 'Ideal' line: sum of isolated cluster peaks."""

    return sum(
        simulate_single_cluster(r, cl, cl.n_cores, clusters=clusters).gflops
        for cl in clusters
    )


def _configs_for(
    clusters: Sequence[ClusterModel], cache_aware: bool, coarse: str
) -> list[B.GotoBlocking]:
    """Per-cluster blocking parameters (control trees, Sections 5.1/5.3)."""

    if not cache_aware:
        # Single control tree: everyone runs the fast cluster's parameters.
        return [clusters[0].blocking for _ in clusters]
    if coarse == "loop3":
        # Shared B_c panel forces a common k_c; re-derive m_c for others
        # (the paper's k_c=952 → A7 m_c=32).
        kc = clusters[0].blocking.kc
        out = [clusters[0].blocking]
        for cl in clusters[1:]:
            d = B.derive_goto_blocking(cl.cache, shared_kc=kc)
            out.append(d)
        return out
    return [cl.blocking for cl in clusters]


def simulate_static(
    r: int,
    *,
    ratio: float = 1.0,
    cache_aware: bool = False,
    coarse: str = "loop1",
    fine: str = "loop4",
    clusters: Sequence[ClusterModel] = EXYNOS_5422,
) -> SimResult:
    """SSS (ratio=1, cache_aware=False), SAS, and CA-SAS (Sections 4, 5.2, 5.3)."""

    cfgs = _configs_for(clusters, cache_aware, coarse)
    # Units: columns for Loop 1, rows for Loop 3; flops per unit = 2 r^2.
    table = S.sas_partition(r, ratios=[ratio, 1.0][: len(clusters)])
    sizes = table.sizes()
    rates = [
        _cluster_rate(cl, cfg, r=r, fine=fine) for cl, cfg in zip(clusters, cfgs)
    ]
    times = [s * 2.0 * r * r / rt for s, rt in zip(sizes, rates)]
    makespan = max(times) + BARRIER_S
    flops = 2.0 * r**3
    cores = [cl.n_cores for cl in clusters]
    e = _energy(clusters, times, cores, makespan)
    name = "sss" if (ratio == 1.0 and not cache_aware) else ("ca-sas" if cache_aware else "sas")
    return SimResult(
        strategy=f"{name}(ratio={ratio},{coarse},{fine})",
        r=r,
        gflops=flops / makespan / 1e9,
        makespan_s=makespan,
        energy_j=e,
        gflops_per_w=flops / 1e9 / e,
        sizes=tuple(sizes),
        busy_s=tuple(times),
    )


def simulate_dynamic(
    r: int,
    *,
    cache_aware: bool = True,
    fine: str = "loop4",
    clusters: Sequence[ClusterModel] = EXYNOS_5422,
) -> SimResult:
    """DAS / CA-DAS: dynamic Loop-3 chunking (Section 5.4).

    Chunk stride is each cluster's own ``m_c`` (CA-DAS, two control trees)
    or the fast cluster's ``m_c`` for everyone (DAS, single tree).  The
    coarse loop is Loop 3 per the paper (n_c = 4096 is too coarse to
    distribute dynamically).
    """

    cfgs = _configs_for(clusters, cache_aware, "loop3")
    rates_flops = [
        _cluster_rate(cl, cfg, r=r, fine=fine) for cl, cfg in zip(clusters, cfgs)
    ]
    unit_flops = 2.0 * r * r  # one row of C
    res = S.das_schedule(
        r,
        rates=[rf / unit_flops for rf in rates_flops],
        strides=[cfg.mc for cfg in cfgs],
        grab_overhead=GRAB_OVERHEAD_S,
    )
    flops = 2.0 * r**3
    cores = [cl.n_cores for cl in clusters]
    e = _energy(clusters, res.busy, cores, res.makespan)
    name = "ca-das" if cache_aware else "das"
    return SimResult(
        strategy=f"{name}(loop3,{fine})",
        r=r,
        gflops=flops / res.makespan / 1e9,
        makespan_s=res.makespan,
        energy_j=e,
        gflops_per_w=flops / 1e9 / e,
        sizes=tuple(res.sizes()),
        busy_s=tuple(res.busy),
    )


def sweep_ratio(
    r: int,
    ratios: Sequence[float] = (1, 2, 3, 4, 5, 6, 7),
    **kw,
) -> list[SimResult]:
    return [simulate_static(r, ratio=float(x), **kw) for x in ratios]


__all__ = [
    "ClusterModel",
    "SimResult",
    "A15",
    "A7",
    "EXYNOS_5422",
    "simulate_single_cluster",
    "simulate_static",
    "simulate_dynamic",
    "sweep_ratio",
    "ideal_gflops",
]

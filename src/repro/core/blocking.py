"""Cache/VMEM-aware GEMM blocking configuration.

This module is the TPU adaptation of the paper's Section 3.3 ("Cache
optimization for the big and LITTLE cores").  The paper determines, per core
type, the BLIS parameters ``(m_c, k_c, n_c, m_r, n_r)`` such that

  * the ``k_c x n_r`` micro-panel ``B_r`` streams from the L1 cache,
  * the ``m_c x k_c`` macro-panel ``A_c`` resides in the L2 cache,
  * ``n_c`` is bounded by the L3 cache (absent on the Exynos 5422, so
    ``n_c = 4096``).

On TPU the memory hierarchy is HBM -> VMEM -> vector registers, with a
software-managed VMEM (~16 MiB per core on v5e) feeding a 128x128 MXU.  The
analogous derivation (the "analytical modeling is enough" route of Low et
al., which the paper cites as an alternative to its empirical search) picks
Pallas block shapes ``(bm, bk, bn)`` such that the A-block, B-block and fp32
accumulator — double-buffered for the HBM->VMEM pipeline — fit a VMEM
budget, with MXU-aligned dimensions.

Both derivations live here:

  * :func:`derive_goto_blocking` — the paper's CPU derivation (used by the
    calibrated big.LITTLE simulator and the CPU benchmarks).
  * :func:`derive_block_config` — the TPU/Pallas derivation (used by the
    kernels and control trees).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Hardware descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheHierarchy:
    """A classical cache hierarchy (paper's target)."""

    name: str
    l1_bytes: int
    l2_bytes: int
    l3_bytes: int = 0  # Exynos 5422 has no L3
    line_bytes: int = 64
    # Fraction of each level the GEMM working set may claim.  The remainder
    # is reserved for the C micro-tile, stack, and streaming interference —
    # mirroring how the paper's empirical optima sit below full capacity.
    l1_fill: float = 0.95
    l2_fill: float = 0.60


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Per-device-class power model: idle + per-FLOP + per-byte terms.

    The structure mirrors the calibrated big.LITTLE simulator
    (``repro.core.simulator.ClusterModel.p_static / p_core / poll_frac``):
    a static floor drawn whenever the device is powered, an activity term
    proportional to work executed, and a polling fraction for the
    busy-wait-while-idle state the paper measures on the Cortex-A15
    (spinning cores burn ~80% of active power).  ``gated_w`` is the draw
    of a *parked* device (power-gated / hot-unplugged, the mechanism of
    the energy-aware AMP follow-on work) — 0 by default.

    :meth:`repro.core.simulator.ClusterModel.power_model` derives an
    instance from the Exynos constants so the two models cross-check.
    """

    idle_w: float
    flop_j: float            # joules per FLOP when active
    byte_j: float = 0.0      # joules per HBM byte moved
    poll_frac: float = 0.8   # fraction of active-over-idle power while polling
    gated_w: float = 0.0     # draw when parked (power-gated)

    def active_w(self, flops_per_s: float, bytes_per_s: float = 0.0) -> float:
        """Modeled draw while executing at the given rates."""
        return self.idle_w + self.flop_j * flops_per_s + self.byte_j * bytes_per_s

    def poll_w(self, flops_per_s: float, bytes_per_s: float = 0.0) -> float:
        """Modeled draw while busy-waiting (powered but starved of work)."""
        over = self.active_w(flops_per_s, bytes_per_s) - self.idle_w
        return self.idle_w + self.poll_frac * over

    def energy_j(self, time_s: float, flops: float, bytes_moved: float = 0.0) -> float:
        """Joules for a unit of work taking ``time_s`` wall seconds."""
        return self.idle_w * time_s + self.flop_j * flops + self.byte_j * bytes_moved


# Modeled power constants.  Chosen so the big:little *active*-power ratio
# (~290 W : ~30 W at sustained rates, about 9.5x) mirrors the measured
# Exynos 5422 cluster ratio (A15 quad ~3.5 W : A7 quad ~0.37 W), while the
# little class lands ~2.4x more energy-efficient per unit of work — the
# paper's headline asymmetry (big is faster, LITTLE is cheaper per FLOP).
TPU_V5E_POWER = PowerModel(idle_w=60.0, flop_j=1.0e-12, byte_j=4.0e-11)
TPU_LITTLE_POWER = PowerModel(idle_w=8.0, flop_j=1.6e-13, byte_j=1.5e-11)


@dataclasses.dataclass(frozen=True)
class TpuCoreSpec:
    """A TPU TensorCore as seen by the blocking derivation."""

    name: str = "tpu-v5e"
    vmem_bytes: int = 16 * 1024 * 1024
    mxu: int = 128              # systolic array dimension
    lane: int = 128             # last-dim register tiling
    sublane: int = 8            # second-minor tiling unit for fp32
    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9
    # Fraction of VMEM available to the GEMM pipeline (the rest holds
    # semaphores, spills, and the scalar prefetch state).
    vmem_fill: float = 0.9
    power: PowerModel = TPU_V5E_POWER


# Paper's platform (Section 3.2): per-core L1d 32 KiB; L2 shared per
# cluster — 2 MiB for the Cortex-A15 quad, 512 KiB for the Cortex-A7 quad.
CORTEX_A15 = CacheHierarchy("cortex-a15", l1_bytes=32 * 1024, l2_bytes=2 * 1024 * 1024)
CORTEX_A7 = CacheHierarchy("cortex-a7", l1_bytes=32 * 1024, l2_bytes=512 * 1024)

TPU_V5E = TpuCoreSpec()

# The degraded device class of the motivating heterogeneous fleet (see
# ``repro.core.asymmetric.biglittle_classes``): half the VMEM, half the
# sustained FLOPs and HBM bandwidth.  Single source of truth — the
# asymmetric mesh, the tuning SPECS registry, and the ratio calibration
# all mean *this* hardware when they say "tpu-little".
TPU_LITTLE = TpuCoreSpec(
    name="tpu-little",
    vmem_bytes=8 * 1024 * 1024,
    peak_flops=99e12,
    hbm_bw=410e9,
    power=TPU_LITTLE_POWER,
)


# ---------------------------------------------------------------------------
# Block configurations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GotoBlocking:
    """The paper's five BLIS parameters for one core class."""

    mc: int
    kc: int
    nc: int
    mr: int = 4
    nr: int = 4

    def a_panel_bytes(self, dtype_bytes: int = 8) -> int:
        return self.mc * self.kc * dtype_bytes

    def b_micropanel_bytes(self, dtype_bytes: int = 8) -> int:
        return self.kc * self.nr * dtype_bytes


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Pallas GEMM block shapes (the TPU analogue of ``GotoBlocking``).

    ``bm x bk`` A-blocks and ``bk x bn`` B-blocks are staged HBM->VMEM
    (double buffered by the Pallas pipeline); a ``bm x bn`` fp32 accumulator
    persists in VMEM across the K grid dimension.
    """

    bm: int
    bk: int
    bn: int
    dtype_bytes: int = 2          # bf16 operands
    acc_bytes: int = 4            # fp32 accumulator

    def vmem_bytes(self, double_buffer: bool = True) -> int:
        """Working set: ``double_buffer=False`` is the VMEM-lean k-streaming
        kernel (``gemm_pallas_lean``), which stages one A/B block at a time
        instead of the pipelined pair — half the input footprint, so larger
        (bm, bn) panels fit the same budget."""

        mult = 2 if double_buffer else 1
        a = self.bm * self.bk * self.dtype_bytes
        b = self.bk * self.bn * self.dtype_bytes
        c = self.bm * self.bn * self.acc_bytes
        return mult * (a + b) + c

    def fits(self, spec: TpuCoreSpec = TPU_V5E, *, double_buffer: bool = True) -> bool:
        return self.vmem_bytes(double_buffer) <= spec.vmem_bytes * spec.vmem_fill

    def arithmetic_intensity(self) -> float:
        """FLOPs per HBM byte moved for one (bm, bn) output block column."""
        flops = 2.0 * self.bm * self.bn * self.bk
        bytes_moved = (self.bm * self.bk + self.bk * self.bn) * self.dtype_bytes
        return flops / bytes_moved


# ---------------------------------------------------------------------------
# Paper derivation (CPU caches)
# ---------------------------------------------------------------------------


def derive_goto_blocking(
    cache: CacheHierarchy,
    *,
    dtype_bytes: int = 8,
    mr: int = 4,
    nr: int = 4,
    kc_cap: Optional[int] = None,
    shared_kc: Optional[int] = None,
) -> GotoBlocking:
    """Analytic (m_c, k_c, n_c) for a cache hierarchy, per paper Section 3.3.

    * ``k_c``: the B micro-panel ``k_c x n_r`` must stream from L1 —
      ``k_c * n_r * dtype_bytes <= l1_fill * l1_bytes``.
    * ``m_c``: the A macro-panel ``m_c x k_c`` must reside in L2 —
      ``m_c * k_c * dtype_bytes <= l2_fill * l2_bytes``.
    * ``n_c``: bounded by L3 when present, otherwise the paper's 4096.

    ``shared_kc`` reproduces the Section 5.3 constraint: when Loop 3 is the
    inter-cluster loop the ``B_c`` buffer is shared, forcing a common
    ``k_c`` across classes and a re-derived (smaller) ``m_c`` for the class
    whose L2 cannot hold ``m_c x k_c`` at the shared ``k_c``.
    """

    if shared_kc is not None:
        kc = shared_kc
    else:
        kc = int(cache.l1_fill * cache.l1_bytes / (nr * dtype_bytes))
        # Keep a multiple of 8 like BLIS does for vector-friendly strides.
        kc = max(8, (kc // 8) * 8)
        if kc_cap is not None:
            kc = min(kc, kc_cap)

    mc = int(cache.l2_fill * cache.l2_bytes / (kc * dtype_bytes))
    mc = max(mr, (mc // mr) * mr)
    # Degenerate hierarchies (L2 ≈ L1): the m_c >= m_r floor can overflow
    # L2 — give k_c back until the minimal m_r-row panel fits.
    if shared_kc is None:
        while mc * kc * dtype_bytes > cache.l2_bytes and kc > 8:
            kc = max(8, ((kc // 2) // 8) * 8)
            mc = max(mr, (int(cache.l2_fill * cache.l2_bytes / (kc * dtype_bytes)) // mr) * mr)

    if cache.l3_bytes:
        nc = int(0.5 * cache.l3_bytes / (kc * dtype_bytes))
        nc = max(nr, (nc // nr) * nr)
    else:
        nc = 4096  # paper: "n_c plays a minor role ... set to 4096"
    return GotoBlocking(mc=mc, kc=kc, nc=nc, mr=mr, nr=nr)


# The paper's empirically-determined optima (Section 3.3 / Figure 4),
# recorded for validation and used verbatim by the calibrated simulator.
PAPER_A15 = GotoBlocking(mc=152, kc=952, nc=4096)
PAPER_A7 = GotoBlocking(mc=80, kc=352, nc=4096)
# Section 5.3: shared k_c = 952 (Loop-3 coarse partitioning) forces the
# Cortex-A7 macro-panel down to m_c = 32.
PAPER_A7_SHARED_KC = GotoBlocking(mc=32, kc=952, nc=4096)


# ---------------------------------------------------------------------------
# TPU derivation (VMEM)
# ---------------------------------------------------------------------------


def _round_down(x: int, mult: int) -> int:
    return max(mult, (x // mult) * mult)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def derive_block_config(
    m: int,
    k: int,
    n: int,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    dtype_bytes: int = 2,
    max_bm: int = 1024,
    max_bk: int = 2048,
    max_bn: int = 1024,
    double_buffer: bool = True,
) -> BlockConfig:
    """Pick ``(bm, bk, bn)`` maximizing arithmetic intensity under VMEM.

    Mirrors the paper's capacity argument: the bigger the resident panel,
    the more compute amortizes each byte staged into fast memory.  We grow
    ``bk`` first (it amortizes both A and B traffic, like the paper grows
    ``k_c`` to fill L1), then balance ``bm``/``bn``.  All dims are
    MXU/lane aligned; dims are clamped to the (padded) problem size so tiny
    problems do not claim VMEM they cannot use.

    ``double_buffer=False`` derives for the VMEM-lean k-streaming kernel
    (single-buffered input staging): the same budget admits larger
    (bm, bn) panels — the paper's §5.3 observation that a class with less
    fast memory wants a *different micro-kernel*, not just smaller blocks.
    """

    budget = int(spec.vmem_bytes * spec.vmem_fill)
    align = spec.mxu

    pm = _round_up(min(m, max_bm), align)
    pn = _round_up(min(n, max_bn), align)
    pk = _round_up(min(k, max_bk), align)

    best: Optional[BlockConfig] = None
    bm = pm
    while bm >= align:
        bn = pn
        while bn >= align:
            # Largest aligned bk that fits the budget for this (bm, bn).
            acc = bm * bn * 4
            # A+B staging per unit bk: pipelined pair or one lean buffer.
            per_k = (2 if double_buffer else 1) * (bm + bn) * dtype_bytes
            if acc >= budget:
                bn //= 2
                continue
            bk = _round_down(min(pk, (budget - acc) // per_k), align)
            cfg = BlockConfig(bm=bm, bk=bk, bn=bn, dtype_bytes=dtype_bytes)
            if cfg.fits(spec, double_buffer=double_buffer):
                if best is None or cfg.arithmetic_intensity() > best.arithmetic_intensity():
                    best = cfg
                elif (
                    math.isclose(cfg.arithmetic_intensity(), best.arithmetic_intensity())
                    and cfg.vmem_bytes() < best.vmem_bytes()
                ):
                    best = cfg
            bn //= 2
        bm //= 2
    assert best is not None, "no feasible block config — VMEM budget too small"
    return best


def pad_to_blocks(m: int, k: int, n: int, cfg: BlockConfig) -> tuple[int, int, int]:
    """Padded problem dims so the Pallas grid divides evenly."""

    return (_round_up(m, cfg.bm), _round_up(k, cfg.bk), _round_up(n, cfg.bn))


def search_grid(
    coarse: bool,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    dtype_bytes: int = 2,
) -> list[BlockConfig]:
    """Candidate (bm, bk) grid for the empirical search benchmark.

    The paper runs a coarse sweep over (m_c, k_c) and then refines around
    the best region (Figure 4).  This enumerates the same two-stage
    structure over MXU-aligned Pallas blocks; ``bn`` is fixed at 256 like
    the paper fixes ``n_r``.
    """

    step = 256 if coarse else 128
    out = []
    for bm in range(128, 1025, step):
        for bk in range(128, 2049, step):
            cfg = BlockConfig(bm=bm, bk=bk, bn=256, dtype_bytes=dtype_bytes)
            if cfg.fits(spec):
                out.append(cfg)
    return out


__all__ = [
    "CacheHierarchy",
    "TpuCoreSpec",
    "GotoBlocking",
    "BlockConfig",
    "CORTEX_A15",
    "CORTEX_A7",
    "TPU_V5E",
    "TPU_LITTLE",
    "PAPER_A15",
    "PAPER_A7",
    "PAPER_A7_SHARED_KC",
    "derive_goto_blocking",
    "derive_block_config",
    "pad_to_blocks",
    "search_grid",
]

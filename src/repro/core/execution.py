"""Class-routed execution contexts: one ambient control tree per device class.

The paper's central mechanism (Section 5.3) is that *every* micro-kernel
invocation runs under the executing core class's control tree — the tree
picks both the blocking parameters and the micro-kernel implementation.
This module is the jax_pallas realization of that routing:

  * :class:`ExecutionContext` — a context-manager binding one device
    class's :class:`~repro.core.control_tree.ControlTree` as the *ambient*
    configuration.  Every :func:`repro.kernels.ops.gemm` /
    :func:`~repro.kernels.ops.linear` call anywhere in the model zoo
    resolves its backend and block shapes from the active context instead
    of per-call arguments, so model code never hand-threads
    ``config=``/``backend=``.
  * the **backend dispatch table** (:data:`BACKENDS`) — the single
    vocabulary of micro-kernel implementations (previously scattered
    across ``ops.py``'s if/elif chain, ``control_tree.py``'s ``Backend``
    literal, and the ``_on_tpu()`` auto-probe).
  * :func:`resolve_block_config` — the single tuned-or-analytical
    resolution path: the ``$REPRO_TUNING_CACHE`` entry for the class's
    core spec wins, the Section-3.3 analytical derivation is the fallback.
  * :func:`class_sharded` — per-class programs within one SPMD step: a
    ``shard_map`` over the pod axis in which each pod shard runs the
    program traced under *its* class's context (true CA-SAS, paper
    §5.3–5.4; DESIGN.md §2), with :class:`ShardProvenance` recording
    which tree governs which shard.

With **no context active** every call behaves exactly as before this layer
existed: ``backend="auto"`` probes the JAX backend (Pallas on TPU, XLA
otherwise) and ``config=None`` resolves via the env-var cache keyed by
``$REPRO_TUNING_SPEC`` — bit-identical defaults.

Contexts nest: entering a context shadows the outer one, exiting restores
it (exception-safe).  All state lives in :mod:`contextvars` (the active
context plus a per-thread/per-task token stack), so one shared context
object may be entered concurrently from several threads or asyncio tasks
— enter/exit just have to pair up locally, as with any context manager.
Explicit per-call arguments always win over the ambient context — the
context only fills ``backend="auto"`` and ``config=None`` holes.
"""

from __future__ import annotations

import contextvars
import dataclasses
from typing import TYPE_CHECKING, Callable, Literal, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.blocking import TPU_V5E, BlockConfig, TpuCoreSpec, derive_block_config
from repro.observability import trace as _obs

if TYPE_CHECKING:  # control_tree imports Backend from here; keep it one-way.
    from repro.core.control_tree import ControlTree

# ---------------------------------------------------------------------------
# Backend dispatch table (the one backend vocabulary)
# ---------------------------------------------------------------------------

Backend = Literal[
    "xla", "pallas", "pallas_interpret", "pallas_lean", "pallas_lean_interpret"
]


def _xla_gemm(a2, b, config, out_dtype):
    # Declare the dot output in the compute dtype: the MXU still
    # accumulates fp32 per shard, but GSPMD then places the
    # tensor-parallel all-reduce on the bf16 tensor instead of an fp32
    # intermediate — half the wire bytes on every row-parallel
    # projection (EXPERIMENTS.md §Perf A).
    pet = jnp.float32 if out_dtype == jnp.float32 else out_dtype
    return jnp.dot(a2, b, preferred_element_type=pet).astype(out_dtype)


def _pallas_gemm(a2, b, config, out_dtype):
    from repro.kernels.gemm import gemm_pallas

    return gemm_pallas(a2, b, config, out_dtype=out_dtype)


def _pallas_interpret_gemm(a2, b, config, out_dtype):
    from repro.kernels.gemm import gemm_pallas

    return gemm_pallas(a2, b, config, out_dtype=out_dtype, interpret=True)


def _pallas_lean_gemm(a2, b, config, out_dtype):
    from repro.kernels.gemm import gemm_pallas_lean

    return gemm_pallas_lean(a2, b, config, out_dtype=out_dtype)


def _pallas_lean_interpret_gemm(a2, b, config, out_dtype):
    from repro.kernels.gemm import gemm_pallas_lean

    return gemm_pallas_lean(a2, b, config, out_dtype=out_dtype, interpret=True)


def _paged_attn_xla(q, pages_k, pages_v, page_table, pos):
    from repro.kernels.paged_attention import paged_attention_xla

    return paged_attention_xla(q, pages_k, pages_v, page_table, pos)


def _paged_attn_pallas(q, pages_k, pages_v, page_table, pos):
    from repro.kernels.paged_attention import paged_attention_pallas

    return paged_attention_pallas(q, pages_k, pages_v, page_table, pos)


def _paged_attn_pallas_interpret(q, pages_k, pages_v, page_table, pos):
    from repro.kernels.paged_attention import paged_attention_pallas

    return paged_attention_pallas(
        q, pages_k, pages_v, page_table, pos, interpret=True
    )


# name -> kernel callable.  The keys are the only backend names the stack
# accepts; ``"auto"`` is a request resolved by :func:`resolve_backend` /
# :func:`resolve_paged_attn_backend`, never a table entry.  Entries span
# more than one *op family* now (GEMM micro-kernels take
# ``(a2, b, config, out_dtype)``; paged-attention decode kernels take
# ``(q, pages_k, pages_v, page_table, pos)``) — :data:`BACKEND_OPS` tags
# each name with its family and the dispatch funnels validate the tag, so
# a tree or CLI flag can never route a GEMM into an attention kernel.
BACKENDS: dict[str, Callable] = {
    "xla": _xla_gemm,
    "pallas": _pallas_gemm,
    "pallas_interpret": _pallas_interpret_gemm,
    "pallas_lean": _pallas_lean_gemm,
    "pallas_lean_interpret": _pallas_lean_interpret_gemm,
    "paged_attn_xla": _paged_attn_xla,
    "paged_attn_pallas": _paged_attn_pallas,
    "paged_attn_pallas_interpret": _paged_attn_pallas_interpret,
}

# name -> op family ("gemm" | "paged_attn").
BACKEND_OPS: dict[str, str] = {
    "xla": "gemm",
    "pallas": "gemm",
    "pallas_interpret": "gemm",
    "pallas_lean": "gemm",
    "pallas_lean_interpret": "gemm",
    "paged_attn_xla": "paged_attn",
    "paged_attn_pallas": "paged_attn",
    "paged_attn_pallas_interpret": "paged_attn",
}

BACKEND_NAMES: tuple[str, ...] = tuple(BACKENDS)

# The GEMM sub-vocabulary — what control trees, the tuner, and the
# ``--backend`` CLI flags may name.
GEMM_BACKEND_NAMES: tuple[str, ...] = tuple(
    n for n, op in BACKEND_OPS.items() if op == "gemm"
)


def backend_op(name: str) -> str:
    """The op family of a dispatch-table entry (validating the name)."""

    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}")
    return BACKEND_OPS[name]

# Compiled backend -> its CPU-runnable interpret twin (identity for
# backends that already run anywhere).  The parity harness walks BACKENDS
# through this map, so every new table entry MUST be registered here —
# tests/test_backend_parity.py fails loudly on a missing twin.
INTERPRET_TWIN: dict[str, str] = {
    "xla": "xla",
    "pallas": "pallas_interpret",
    "pallas_interpret": "pallas_interpret",
    "pallas_lean": "pallas_lean_interpret",
    "pallas_lean_interpret": "pallas_lean_interpret",
    "paged_attn_xla": "paged_attn_xla",
    "paged_attn_pallas": "paged_attn_pallas_interpret",
    "paged_attn_pallas_interpret": "paged_attn_pallas_interpret",
}

# Pipelined backend -> the VMEM-lean variant of the same execution family
# (compiled or interpret).  Control trees use this to keep a class's full
# shared panel when only the lean working set fits its VMEM.
LEAN_VARIANTS: dict[str, str] = {
    "pallas": "pallas_lean",
    "pallas_interpret": "pallas_lean_interpret",
}

# Backends whose kernels stage inputs double-buffered; the lean variants
# single-buffer (BlockConfig.vmem_bytes(double_buffer=False) is their
# working-set model).  "xla" ignores block configs entirely.
_LEAN_BACKENDS = frozenset(LEAN_VARIANTS.values())


def interpret_twin(name: str) -> str:
    """The CPU-runnable twin of a backend (validating both names)."""

    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}")
    twin = INTERPRET_TWIN.get(name)
    if twin is None or twin not in BACKENDS:
        raise ValueError(
            f"backend {name!r} has no interpret twin registered in "
            f"INTERPRET_TWIN — add one so the parity harness can cover it"
        )
    return twin


def backend_double_buffers(name: str) -> bool:
    """Does this backend's kernel stage inputs double-buffered?

    Decides which VMEM working-set model governs block-config feasibility
    (``BlockConfig.fits(spec, double_buffer=...)``).
    """

    return name not in _LEAN_BACKENDS


# interpret name -> its compiled family (inverse of INTERPRET_TWIN,
# identity pairs dropped): "pallas_lean_interpret" -> "pallas_lean".
_COMPILED_TWIN: dict[str, str] = {
    t: c for c, t in INTERPRET_TWIN.items() if c != t
}


def align_backend_family(variant: str, requested: str) -> str:
    """Map a recorded kernel variant onto ``requested``'s execution family.

    A tuning-cache entry normally records the *hardware* variant
    (``"pallas_lean"``); when the tree is built for interpret-mode
    execution the same variant must run through its interpret twin — and,
    symmetrically, an interpret name that leaked into a cache (hand-edited
    or merged from a CPU run) must map back to the compiled kernel on a
    hardware tree rather than silently running the Python interpreter.
    """

    if requested.endswith("_interpret"):
        return interpret_twin(variant)
    return _COMPILED_TWIN.get(variant, variant)


def backend_vocabulary() -> frozenset[str]:
    """Every backend token the stack accepts anywhere: the dispatch-table
    names plus the ``"auto"`` request.  The static analyzer's drift
    detector (RPR005) is keyed off this, so the lint vocabulary can never
    diverge from the live registry."""

    return frozenset(BACKENDS) | {"auto"}


def validate_registry() -> list[str]:
    """Statically verify the dispatch tables' closure invariants.

    Returns a list of human-readable violations (empty == healthy).  Ran
    by the ``repro.analysis`` registry pass and by a fast unit test, so a
    new backend that forgets its twin/family registration fails at
    import-check time instead of deep inside dispatch.  Checks:

    * ``BACKENDS`` and ``BACKEND_OPS`` name exactly the same entries, and
      every op-family tag is known;
    * ``INTERPRET_TWIN`` covers every entry, maps into the table, keeps
      the op family, and is idempotent (a twin is its own twin) — the
      parity harness walks this map, so these are its route guarantees;
    * ``LEAN_VARIANTS`` maps double-buffered entries to single-buffered
      entries of the same family;
    * ``kernels.gemm.GEMM_KERNELS`` (the tuner's search dimension) names
      only compiled GEMM-family dispatch entries.
    """

    problems: list[str] = []
    known_ops = {"gemm", "paged_attn"}
    if set(BACKENDS) != set(BACKEND_OPS):
        problems.append(
            f"BACKENDS/BACKEND_OPS disagree: "
            f"{sorted(set(BACKENDS) ^ set(BACKEND_OPS))}"
        )
    for name, op in BACKEND_OPS.items():
        if op not in known_ops:
            problems.append(f"BACKEND_OPS[{name!r}] = {op!r} is not a known op family")
    if set(INTERPRET_TWIN) != set(BACKENDS):
        problems.append(
            f"INTERPRET_TWIN does not cover BACKENDS exactly: "
            f"{sorted(set(INTERPRET_TWIN) ^ set(BACKENDS))}"
        )
    for name, twin in INTERPRET_TWIN.items():
        if twin not in BACKENDS:
            problems.append(f"INTERPRET_TWIN[{name!r}] = {twin!r} not in BACKENDS")
            continue
        if BACKEND_OPS.get(name) != BACKEND_OPS.get(twin):
            problems.append(
                f"INTERPRET_TWIN[{name!r}] = {twin!r} crosses op families"
            )
        if INTERPRET_TWIN.get(twin) != twin:
            problems.append(
                f"interpret twin {twin!r} (of {name!r}) is not its own twin"
            )
    for name, lean in LEAN_VARIANTS.items():
        if name not in BACKENDS or lean not in BACKENDS:
            problems.append(f"LEAN_VARIANTS {name!r} -> {lean!r} not in BACKENDS")
            continue
        if BACKEND_OPS[name] != BACKEND_OPS[lean]:
            problems.append(
                f"LEAN_VARIANTS {name!r} -> {lean!r} crosses op families"
            )
        if not backend_double_buffers(name) or backend_double_buffers(lean):
            problems.append(
                f"LEAN_VARIANTS {name!r} -> {lean!r} must map a "
                "double-buffered entry to a single-buffered one"
            )
    from repro.kernels.gemm import GEMM_KERNELS

    for name in GEMM_KERNELS:
        if name not in BACKENDS:
            problems.append(f"GEMM_KERNELS entry {name!r} not in BACKENDS")
        elif BACKEND_OPS[name] != "gemm":
            problems.append(f"GEMM_KERNELS entry {name!r} is not a GEMM backend")
        elif name.endswith("_interpret"):
            problems.append(
                f"GEMM_KERNELS entry {name!r} is an interpret twin — the "
                "variant registry holds compiled kernels only"
            )
    return problems


def on_tpu() -> bool:
    """The auto-probe: is the default JAX backend a TPU?"""

    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def resolve_backend(name: str) -> str:
    """Collapse a GEMM ``"auto"`` to a concrete table entry; validate the rest.

    GEMM callers only (control trees, the ops funnel, dry-run): a name
    from another op family is rejected here, at resolution time, so it can
    never reach a kernel with the wrong signature.
    """

    if name == "auto":
        return "pallas" if on_tpu() else "xla"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}")
    if BACKEND_OPS[name] != "gemm":
        raise ValueError(
            f"backend {name!r} is a {BACKEND_OPS[name]!r} kernel, not a GEMM"
        )
    return name


def resolve_paged_attn_backend(name: str) -> str:
    """Collapse a paged-attention ``"auto"``; validate the op family."""

    if name == "auto":
        return "paged_attn_pallas" if on_tpu() else "paged_attn_xla"
    if backend_op(name) != "paged_attn":
        raise ValueError(
            f"backend {name!r} is a {BACKEND_OPS[name]!r} kernel, not a "
            f"paged-attention kernel"
        )
    return name


def dispatch_gemm(a2, b, *, config=None, backend: str = "auto", out_dtype=None):
    """Route a 2-D GEMM through the backend table (the kernels' funnel)."""

    out_dtype = out_dtype or a2.dtype
    return BACKENDS[resolve_backend(backend)](a2, b, config, out_dtype)


def dispatch_paged_attention(
    q, pages_k, pages_v, page_table, pos, *, backend: str = "auto"
):
    """Route a paged decode-attention call through the backend table.

    The decode path's funnel: ``layers.decode_attention_paged`` calls this
    per layer, so the paged kernels live in the same vocabulary — and the
    same parity harness — as the GEMM micro-kernels.
    """

    return BACKENDS[resolve_paged_attn_backend(backend)](
        q, pages_k, pages_v, page_table, pos
    )


# ---------------------------------------------------------------------------
# Block-config resolution (tuned cache -> analytical fallback)
# ---------------------------------------------------------------------------

_DTYPE_NAMES = {1: "int8", 2: "bfloat16", 4: "float32"}


def dtype_name_for_bytes(dtype_bytes: int) -> str:
    return _DTYPE_NAMES.get(dtype_bytes, f"bytes{dtype_bytes}")


def tuned_block_config(
    m: int,
    k: int,
    n: int,
    *,
    spec: Optional[TpuCoreSpec] = None,
    dtype_name: str = "bfloat16",
    dtype_bytes: int = 2,
) -> Optional[BlockConfig]:
    """The ``$REPRO_TUNING_CACHE`` entry for this (spec, dtype, shape), or None.

    ``spec=None`` keeps today's kernel-path behavior: the cache key's spec
    name comes from ``$REPRO_TUNING_SPEC`` (default ``tpu-v5e``).
    """

    from repro.tuning.cache import cached_block_config

    return cached_block_config(
        m, k, n, dtype_name, dtype_bytes,
        spec_name=spec.name if spec is not None else None,
    )


def tuned_kernel_backend(
    m: int,
    k: int,
    n: int,
    *,
    spec: Optional[TpuCoreSpec] = None,
    dtype_name: str = "bfloat16",
) -> Optional[str]:
    """The kernel variant the tuner recorded for this entry, or None.

    The cache entry's ``"backend"`` field holds the winning micro-kernel
    variant (a :data:`BACKENDS` key) since the variant search landed;
    older caches stored the *measurement* backend there (``"cost-model"``/
    ``"wallclock"``) — any value outside the dispatch table is ignored, so
    old caches keep working with the default kernel.
    """

    from repro.tuning.cache import cached_kernel_backend

    name = cached_kernel_backend(
        m, k, n, dtype_name, spec_name=spec.name if spec is not None else None
    )
    return name if name in BACKENDS else None


def resolve_block_config(
    m: int,
    k: int,
    n: int,
    *,
    spec: Optional[TpuCoreSpec] = None,
    dtype_name: str = "bfloat16",
    dtype_bytes: int = 2,
    double_buffer: bool = True,
) -> tuple[BlockConfig, str]:
    """Tuned config on cache hit, analytical derivation on miss.

    Returns ``(config, source)`` with ``source in ("tuned", "analytical")``
    so callers (control trees, tests) can record provenance.
    ``double_buffer`` names the *consuming kernel's* buffering model: the
    analytical fallback derives under it, and a tuned hit is honored only
    if the consumer can hold it — an entry recorded for the lean kernel
    (or one that overflows the spec double-buffered) must not reach the
    pipelined kernel, whose working set is twice the one the entry was
    validated under.  (The converse is safe: any double-buffer-feasible
    block is lean-feasible.)
    """

    cfg = tuned_block_config(
        m, k, n, spec=spec, dtype_name=dtype_name, dtype_bytes=dtype_bytes
    )
    if cfg is not None:
        usable = True
        if double_buffer:
            recorded = tuned_kernel_backend(
                m, k, n, spec=spec, dtype_name=dtype_name
            )
            if recorded is not None and not backend_double_buffers(recorded):
                usable = False  # a lean-only winner: pipelined would spill
            elif spec is not None and not cfg.fits(spec):
                usable = False
        if usable:
            return cfg, "tuned"
    return (
        derive_block_config(
            m, k, n,
            spec=spec or TPU_V5E,
            dtype_bytes=dtype_bytes,
            double_buffer=double_buffer,
        ),
        "analytical",
    )


# ---------------------------------------------------------------------------
# The execution context itself
# ---------------------------------------------------------------------------


def _same_bucket(a: tuple[int, int, int], b: tuple[int, int, int]) -> bool:
    """Do two problem shapes pad to the same 128-lane MXU tile per dim?

    Uses the tuning cache's own bucket function so block-config reuse
    decisions can never drift from the cache-key bucketing.
    """

    from repro.tuning.cache import _bucket

    return all(_bucket(x) == _bucket(y) for x, y in zip(a, b))


_ACTIVE: contextvars.ContextVar[Optional["ExecutionContext"]] = contextvars.ContextVar(
    "repro_execution_context", default=None
)
# LIFO of reset tokens for the enters made *in the current thread/task*.
# Held in a ContextVar of immutable tuples: each asyncio task (copied
# context) and each thread sees its own stack, so a single shared
# ExecutionContext instance can be entered concurrently everywhere —
# enter/exit only have to pair up locally, as with any context manager.
_TOKENS: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_execution_tokens", default=()
)


@dataclasses.dataclass
class ExecutionContext:
    """Ambient per-device-class execution configuration (a context manager).

    Binds one class's control tree: ``ops.gemm`` calls under this context
    take their backend from ``tree.backend`` and, for Pallas backends,
    resolve their block shapes per call shape from the tuning cache keyed
    by ``tree.spec`` (falling back to the analytical derivation for that
    spec).  ``tree.block`` itself is the canonical-shape config carrying
    the Section-5.3 shared-panel structure; per-call shapes re-resolve so
    a little-VMEM class never inherits a big-class block it cannot hold.
    """

    device_class: str
    tree: "ControlTree"

    def __enter__(self) -> "ExecutionContext":
        # Token bookkeeping lives in _TOKENS (per-thread *and* per-task),
        # never on the instance: one long-lived context (e.g. a Trainer's)
        # may be entered concurrently from threads and asyncio tasks.
        token = _ACTIVE.set(self)
        _TOKENS.set(_TOKENS.get() + (token,))
        return self

    def __exit__(self, *exc) -> bool:
        stack = _TOKENS.get()
        _TOKENS.set(stack[:-1])
        _ACTIVE.reset(stack[-1])
        return False

    @property
    def spec(self) -> TpuCoreSpec:
        return self.tree.spec

    def backend(self) -> str:
        """The concrete dispatch-table entry this context routes to."""

        return resolve_backend(self.tree.backend)

    def block_config(
        self, m: int, k: int, n: int, dtype_name: str, dtype_bytes: int
    ) -> BlockConfig:
        """Per-call-shape block config for this class (tuned or analytical).

        ``tree.block`` carries either a hand-picked configuration (trees
        built directly, no ``problem_shape`` recorded) or the Section-5.3
        shared-panel constraint, neither of which a fresh per-spec
        derivation can reconstruct — so it is reused whenever it can be.

        Hand-built trees are authoritative (the old ``gemm_with_tree``
        semantics): their block is used verbatim on a dtype match, or with
        the operand bytes re-labelled otherwise (same shapes), with a
        fresh derivation only if the re-labelled working set overflows
        this class's VMEM.

        Mesh-built trees reuse ``tree.block`` for calls padding into the
        same 128-lane bucket the tree was built for.  Resolution order:
        tree.block on a dtype match; else a tuned cache entry for this
        class's spec at the call's actual dtype — under a Loop-3 (rows)
        tree only if it agrees on the shared ``bk``, the same rule
        ``build_control_trees`` enforces; else the dtype-re-labelled
        tree.block (VMEM-fit guarded).  Off-bucket shapes re-resolve
        against this class's spec.

        VMEM-fit checks use the *tree backend's* buffering model: a lean
        (single-buffered) backend admits blocks the pipelined kernel could
        not hold — that is the point of the variant.  A tuned entry is
        likewise honored only if this tree's kernel can hold it (a
        lean-only winner must not reach a pipelined tree).  Hand-built
        blocks are clamped to the lane-padded call dims — they apply to
        *every* call shape, and an un-clamped oversize block would now be
        rejected by the kernels' shape validation instead of silently
        padding.
        """

        tree = self.tree
        db = backend_double_buffers(self.backend())
        hand_built = tree.problem_shape is None

        def _clamp(blk: BlockConfig) -> BlockConfig:
            lane = tree.spec.lane
            pad = lambda d: max(lane, ((d + lane - 1) // lane) * lane)  # noqa: E731
            return dataclasses.replace(
                blk,
                bm=min(blk.bm, pad(m)),
                bk=min(blk.bk, pad(k)),
                bn=min(blk.bn, pad(n)),
            )

        reuse = hand_built or _same_bucket((m, k, n), tree.problem_shape)
        if reuse and tree.block.dtype_bytes == dtype_bytes:
            return _clamp(tree.block) if hand_built else tree.block
        if reuse:
            relabeled = dataclasses.replace(tree.block, dtype_bytes=dtype_bytes)
            if hand_built and relabeled.fits(tree.spec, double_buffer=db):
                return _clamp(relabeled)
        tuned = tuned_block_config(
            m, k, n, spec=tree.spec, dtype_name=dtype_name, dtype_bytes=dtype_bytes
        )
        if (
            tuned is not None
            and (not reuse or tree.coarse_loop != "rows" or tuned.bk == tree.block.bk)
            and tuned.fits(tree.spec, double_buffer=db)
        ):
            return tuned
        if reuse and not hand_built and relabeled.fits(tree.spec, double_buffer=db):
            return relabeled
        return derive_block_config(
            m, k, n, spec=tree.spec, dtype_bytes=dtype_bytes, double_buffer=db
        )


def current_context() -> Optional[ExecutionContext]:
    """The innermost active context, or None (→ pre-context defaults)."""

    return _ACTIVE.get()


# ---------------------------------------------------------------------------
# Per-class programs within one SPMD step (shard_map over the pod axis)
# ---------------------------------------------------------------------------


def compat_shard_map(f, *, mesh, in_specs, out_specs, auto=frozenset()):
    """``shard_map`` with replication checking off, across jax versions.

    The replication-check kwarg was renamed (``check_rep`` →
    ``check_vma``); class-sharded bodies carry per-shard control flow the
    checker cannot see through, so it is always disabled here.
    """

    from jax.experimental.shard_map import shard_map

    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if auto:
        kwargs["auto"] = frozenset(auto)
    try:
        return shard_map(f, check_rep=False, **kwargs)
    except TypeError:  # newer jax renamed the kwarg
        return shard_map(f, check_vma=False, **kwargs)


@dataclasses.dataclass(frozen=True)
class ShardProvenance:
    """Which class's control tree governs one pod shard (paper §5.3)."""

    pod: int
    device_class: str
    spec: str
    backend: str
    block_source: str  # "tuned" | "analytical" — the tree's provenance
    block: BlockConfig


@dataclasses.dataclass(eq=False)  # identity hash/eq: jit-able as a callable
class ClassShardedFn:
    """A callable wrapping ``fn`` so each pod shard runs its own class's
    program, plus the per-shard provenance (for assertions / telemetry).

    ``trace_log`` records, at trace time, which contexts actually traced a
    branch — the proof that each class's tree was ambient while its
    program was built (appended once per trace; jit retraces append again).
    """

    fn: Callable
    provenance: tuple[ShardProvenance, ...]
    trace_log: list
    mixed: bool  # False on the single-class fallback (no shard_map)

    def __call__(self, *args):
        return self.fn(*args)


def class_sharded(
    fn: Callable,
    *,
    mesh,
    contexts: Sequence[ExecutionContext],
    pod_class: Sequence[int],
    in_specs,
    out_specs,
    axis: str = "pod",
    epilogue: Optional[Callable] = None,
    auto: Optional[frozenset] = None,
    pod_class_spec=None,
) -> ClassShardedFn:
    """True CA-SAS within one SPMD step: per-class programs under shard_map.

    The paper's §5.3/§5.4 schemes run *different* control trees on the big
    and LITTLE clusters simultaneously inside one gemm.  Here, a
    ``shard_map`` over the mesh's ``axis`` (the pod axis) gives every pod
    its shard of the work, and each shard *selects the program traced
    under its own class's execution context*: ``fn`` is traced once per
    class, each trace under that class's :class:`ExecutionContext` (so
    every ``ops.gemm`` in branch *c* resolves class *c*'s tuned block
    config and backend), and a ``lax.switch`` on the shard's class index
    picks the branch at run time.  Pods of the same class take the same
    branch, so intra-class (auto-axis) collectives stay consistent.

    ``contexts`` is ordered by class index; ``pod_class[i]`` is the class
    index of pod ``i`` and ``pod_class_spec`` shards it one-per-pod —
    ``repro.distributed.sharding.pod_class_specs`` produces the pair
    (``AsymmetricMesh.class_sharded`` feeds it through; the spec defaults
    to ``P(axis)``).  The class index reaches each shard as a pod-sharded
    *input*, not ``axis_index`` — keeping the body free of partition-id
    lowering so partial-auto meshes work on every backend.

    ``epilogue(out, shard_args, axis)`` runs inside the shard_map body
    *after* the switch — the one place cross-pod collectives are legal
    (all pods execute it, branch-independent).  Use it for the weighted
    gradient psum of a train step.  With a single class the fallback
    wrapper simply activates the one context around ``fn`` — no
    shard_map, bit-identical to the pre-class-sharded path — and calls
    ``epilogue`` with ``axis=None``.

    ``fn`` must itself contain no cross-``axis`` collectives (they would
    run under a data-dependent branch and deadlock across classes).

    The shard_map is **fully manual** by default: devices sharing a pod
    coordinate replicate that pod's program (exact, and free when the
    non-pod axes have extent 1 — the host realization).  Passing the
    non-pod axes via ``auto`` would let GSPMD keep partitioning the
    fine-grain Loop-4 math across them, but current XLA's partitioner
    CHECK-fails on ``lax.scan`` inside a ``switch`` branch under a manual
    subgroup (verified on 0.4.x), and every model in the zoo scans over
    layers — so ``auto`` is opt-in until the partitioner supports it.
    """

    contexts = list(contexts)
    if not contexts:
        raise ValueError("need at least one execution context")
    pod_class = tuple(int(c) for c in pod_class)
    if any(c < 0 or c >= len(contexts) for c in pod_class):
        raise ValueError(
            f"pod_class {pod_class} out of range for {len(contexts)} classes"
        )
    provenance = tuple(
        ShardProvenance(
            pod=i,
            device_class=contexts[c].device_class,
            spec=contexts[c].spec.name,
            backend=contexts[c].backend(),
            block_source=contexts[c].tree.block_source,
            block=contexts[c].tree.block,
        )
        for i, c in enumerate(pod_class)
    )
    trace_log: list = []

    if len(contexts) == 1:
        # Single-class fallback: the one context governs the whole program
        # — exactly the pre-class-sharded execution path, no shard_map.
        ctx = contexts[0]

        def single(*args):
            with ctx:
                trace_log.append((ctx.device_class, ctx.tree.block_source))
                _obs.instant(
                    "execution.trace", cat="execution", mixed=False,
                    device_class=ctx.device_class, backend=ctx.backend(),
                    block_source=ctx.tree.block_source,
                )
                out = fn(*args)
            if epilogue is not None:
                out = epilogue(out, args, None)
            return out

        return ClassShardedFn(
            fn=single, provenance=provenance, trace_log=trace_log, mixed=False
        )

    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no {axis!r} axis; axes={mesh.axis_names}")
    if mesh.shape[axis] != len(pod_class):
        raise ValueError(
            f"pod_class covers {len(pod_class)} pods but mesh axis "
            f"{axis!r} has size {mesh.shape[axis]}"
        )
    if auto is None:
        auto = frozenset()
    manual = frozenset(mesh.axis_names) - frozenset(auto)

    def _branch(ctx: ExecutionContext):
        def branch(ops):
            with ctx:
                # Trace-time record: this class's tree was ambient while
                # its per-class program was built.
                trace_log.append((ctx.device_class, ctx.tree.block_source))
                _obs.instant(
                    "execution.trace", cat="execution", mixed=True,
                    device_class=ctx.device_class, backend=ctx.backend(),
                    block_source=ctx.tree.block_source,
                )
                return fn(*ops)

        return branch

    branches = [_branch(ctx) for ctx in contexts]

    def body(cls, *shard_args):
        from repro.distributed.sharding import activation_manual_axes

        # Manual axes are fixed inside this body: activation constraints
        # traced here must not mention them.
        with activation_manual_axes(manual):
            out = jax.lax.switch(cls[0], branches, shard_args)
            if epilogue is not None:
                out = epilogue(out, shard_args, axis)
        return out

    from jax.sharding import PartitionSpec as P

    if pod_class_spec is None:
        pod_class_spec = P(axis)
    smap = compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(pod_class_spec,) + tuple(in_specs),
        out_specs=out_specs,
        auto=auto,
    )
    idx = jnp.asarray(pod_class, jnp.int32)

    def wrapped(*args):
        return smap(idx, *args)

    return ClassShardedFn(
        fn=wrapped, provenance=provenance, trace_log=trace_log, mixed=True
    )


def context_for_tree(tree: "ControlTree") -> ExecutionContext:
    """Wrap an existing control tree (e.g. one of ``build_control_trees``)."""

    return ExecutionContext(device_class=tree.device_class, tree=tree)


def default_context(
    *,
    spec: Optional[TpuCoreSpec] = None,
    shape: tuple[int, int, int] = (1024, 1024, 1024),
    backend: str = "auto",
    device_class: Optional[str] = None,
) -> ExecutionContext:
    """A single-class context for homogeneous runs (dry-run, plain serving).

    With no tuning cache active this is behavior-neutral: the tree holds
    the analytical config and the auto-resolved backend, exactly what a
    bare ``ops.gemm`` call would pick.
    """

    from repro.core.control_tree import build_control_trees

    spec = spec or TPU_V5E
    name = device_class or spec.name
    trees = build_control_trees(
        {name: spec}, *shape, backend=resolve_backend(backend)
    )
    return ExecutionContext(device_class=name, tree=trees[name])


__all__ = [
    "Backend",
    "BACKENDS",
    "BACKEND_NAMES",
    "BACKEND_OPS",
    "GEMM_BACKEND_NAMES",
    "INTERPRET_TWIN",
    "LEAN_VARIANTS",
    "ClassShardedFn",
    "ExecutionContext",
    "ShardProvenance",
    "align_backend_family",
    "backend_double_buffers",
    "backend_op",
    "backend_vocabulary",
    "validate_registry",
    "class_sharded",
    "compat_shard_map",
    "context_for_tree",
    "current_context",
    "default_context",
    "dispatch_gemm",
    "dispatch_paged_attention",
    "dtype_name_for_bytes",
    "interpret_twin",
    "on_tpu",
    "resolve_backend",
    "resolve_block_config",
    "resolve_paged_attn_backend",
    "tuned_block_config",
    "tuned_kernel_backend",
]

"""Asymmetric device-class abstraction over a JAX mesh.

The paper's big.LITTLE clusters become *device classes*: groups of pods (or
hosts) with unequal sustained throughput.  Real fleets exhibit this through
multi-generation hardware (a v5e pod next to a v4 pod), thermally degraded
hosts, or pods with different ICI topology.  A mesh axis (``"pod"``) indexes
the classes; within a class, work is spread symmetrically over the
``data``/``model`` axes (the paper's fine-grain Loop-4 partitioning).

:class:`AsymmetricMesh` couples the mesh with a per-class performance model
and the schedulers of :mod:`repro.core.schedule`, producing the padded
batch layout that the SPMD train step consumes:

  * ``chunk table``   — per-pod batch share (rows of the paper's Loop 3),
  * ``batch layout``  — ``(n_pods, c_max, ...)`` plus per-pod valid counts,
  * masked loss / weighted all-reduce make gradients exact under padding.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import blocking as B
from repro.core import schedule as S


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """One throughput class of accelerators (the analogue of a cluster)."""

    name: str
    n_pods: int = 1
    chips_per_pod: int = 256
    peak_flops: float = 197e12      # per chip, bf16
    hbm_bw: float = 819e9           # per chip
    ici_bw: float = 50e9            # per link
    # Sustained throughput relative to the fastest class (the paper's ratio
    # knob normalizes the A15 to 1).  Calibrated online by DynamicScheduler.
    rel_throughput: float = 1.0
    spec: B.TpuCoreSpec = B.TPU_V5E


# A homogeneous production fleet (the dry-run default): two identical pods.
def homogeneous_classes(n_pods: int = 2, chips_per_pod: int = 256) -> list[DeviceClass]:
    return [
        DeviceClass(name=f"pod{i}", n_pods=1, chips_per_pod=chips_per_pod)
        for i in range(n_pods)
    ]


# The motivating heterogeneous fleet: a current-gen pod plus a previous-gen
# pod at ~0.35 relative sustained throughput (v4 ≈ 275/197 peak but lower
# achieved bf16 utilization + half HBM bw in this scenario) — the TPU
# analogue of the paper's 9.6 vs 2.4 GFLOPS clusters (ratio 4).
def biglittle_classes(chips_per_pod: int = 256) -> list[DeviceClass]:
    big = DeviceClass(name="big", chips_per_pod=chips_per_pod, rel_throughput=1.0)
    little = DeviceClass(
        name="little",
        chips_per_pod=chips_per_pod,
        peak_flops=99e12,
        hbm_bw=410e9,
        rel_throughput=0.25,
        spec=B.TPU_LITTLE,
    )
    return [big, little]


@dataclasses.dataclass
class BatchLayout:
    """Padded per-pod batch layout for the asymmetric SPMD step."""

    global_batch: int
    sizes: list[int]          # valid rows per pod, sum == global_batch
    c_max: int                # padded per-pod rows
    mask: np.ndarray          # (n_pods, c_max) float32 validity mask

    @property
    def padded_batch(self) -> int:
        return len(self.sizes) * self.c_max


class AsymmetricMesh:
    """Couples device classes with the paper's schedulers.

    This object is pure scheduling state — it never touches
    ``jax.devices()`` — so it can be built anywhere (tests, dry-run,
    launcher) and combined with whatever ``jax.sharding.Mesh`` the caller
    constructs for the same pod count.
    """

    def __init__(
        self,
        classes: Sequence[DeviceClass],
        *,
        strategy: str = "ca-das",
        batch_tile: int = 8,
        init_ratio: Optional[float] = None,
        tree_shape: tuple[int, int, int] = (1024, 1024, 1024),
        backend: str = "auto",
        objective: str = "perf",
    ):
        if strategy not in ("sss", "sas", "ca-sas", "das", "ca-das"):
            raise ValueError(f"unknown strategy {strategy!r}")
        self.classes = list(classes)
        self.strategy = strategy
        self.batch_tile = batch_tile
        self.tree_shape = tuple(tree_shape)  # canonical GEMM shape for the trees
        self.backend = backend
        self.objective = S.validate_objective(objective)
        self._trees: dict[tuple[int, int, int], dict] = {}
        self.calibration = None  # set by from_calibration()
        self.n_pods = sum(c.n_pods for c in self.classes)
        # Per-pod throughput weights (a class may own several pods).
        self._pod_class = [
            (ci, c) for ci, c in enumerate(self.classes) for _ in range(c.n_pods)
        ]
        ratios = [c.rel_throughput for _, c in self._pod_class]
        if init_ratio is not None and len(ratios) == 2:
            ratios = [init_ratio, 1.0]
        workers = [c.chips_per_pod for _, c in self._pod_class]
        tiles = self._tiles()
        self.scheduler = S.DynamicScheduler(
            self.n_pods,
            init_ratios=ratios,
            workers=workers,
            tiles=tiles if strategy in ("ca-sas", "ca-das") else [batch_tile] * self.n_pods,
            objective=objective,
            powers=self.pod_active_watts() if objective != "perf" else None,
        )

    @classmethod
    def from_calibration(
        cls,
        classes: Sequence[DeviceClass],
        calibration=None,
        *,
        probe_shape: tuple[int, int, int] = (1024, 1024, 1024),
        backend: str = "cost-model",
        measurements=None,
        **kwargs,
    ) -> "AsymmetricMesh":
        """Build a mesh whose per-class throughputs are *measured*, not typed.

        Runs (or accepts) a :class:`repro.tuning.ratio.Calibration` over
        ``classes`` and replaces each class's hand-set ``rel_throughput``
        with the calibrated ratio — the paper's Section 5.2.2 knob, set
        empirically.  With ``backend="wallclock"`` pass ``measurements``
        (per-class :class:`~repro.tuning.ratio.ClassMeasurement` records,
        e.g. from ``benchmarks.bench_schedulers.measure_class_step_times``
        or real per-pod step times) — one host cannot wallclock-compare
        heterogeneous core specs itself.  The result seeds
        ``DynamicScheduler.init_ratios``; the between-steps feedback keeps
        refining from there.
        """

        from repro.tuning.ratio import calibrate_class_ratios

        if calibration is None:
            calibration = calibrate_class_ratios(
                classes,
                probe_shape=probe_shape,
                backend=backend,
                measurements=measurements,
            )
        if len(calibration.ratios) != len(classes):
            raise ValueError(
                f"calibration covers {len(calibration.ratios)} classes, "
                f"got {len(classes)}"
            )
        calibrated = [
            dataclasses.replace(c, rel_throughput=float(r))
            for c, r in zip(classes, calibration.ratios)
        ]
        mesh = cls(calibrated, **kwargs)
        mesh.calibration = calibration
        return mesh

    def _tiles(self) -> list[int]:
        # CA: each pod's chunk aligns to its own microbatch tile — a slower
        # class gets a proportionally *smaller* stride, mirroring the
        # per-class m_c of the paper (A15 m_c=152 vs A7 m_c=32).  The
        # fastest class keeps the full batch_tile; others scale down by
        # their relative throughput, floored at 1.
        top = max(cc.rel_throughput for cc in self.classes)
        out = []
        for _, c in self._pod_class:
            out.append(max(1, int(round(self.batch_tile * c.rel_throughput / top))))
        return out

    # -- execution contexts (per-class control trees) ---------------------

    def _primary_class(self) -> DeviceClass:
        """The fastest class (ties broken by listed order) — the anchor."""

        return max(self.classes, key=lambda c: c.rel_throughput)

    # -- per-shard class lookup (the pod→class mapping) -------------------

    def pod_class_indices(self) -> list[int]:
        """Class index (into ``self.classes``) per pod — pod→class map."""

        return [ci for ci, _ in self._pod_class]

    def class_of_pod(self, pod: int) -> DeviceClass:
        """The device class that owns pod ``pod``."""

        return self._pod_class[pod][1]

    def control_trees(self, shape: Optional[tuple[int, int, int]] = None) -> dict:
        """Per-class control trees for ``shape`` (default: ``tree_shape``).

        Built once per shape and memoized.  The *fastest* class anchors
        the shared-B-panel ``bk`` regardless of listing order (classes are
        sorted by throughput before ``build_control_trees``, whose first
        entry is the anchor) — so the primary class never trains with
        panel strides constrained by a slow class's VMEM.  Each class's
        block config resolves through the tuning cache for *its own* core
        spec, falling back to the analytical derivation.
        """

        from repro.core import execution as X
        from repro.core.control_tree import build_control_trees

        shape = tuple(shape) if shape is not None else self.tree_shape
        trees = self._trees.get(shape)
        if trees is None:
            ordered = sorted(
                self.classes, key=lambda c: -c.rel_throughput
            )  # stable: listed order breaks ties
            specs = {c.name: c.spec for c in ordered}
            trees = build_control_trees(
                specs, *shape, backend=X.resolve_backend(self.backend)
            )
            self._trees[shape] = trees
        return trees

    def class_backends(
        self, shape: Optional[tuple[int, int, int]] = None
    ) -> dict[str, str]:
        """Resolved micro-kernel variant per class (paper §5.3).

        The per-class trees may name *different* ``execution.BACKENDS``
        entries — e.g. ``big → "pallas"`` and ``little → "pallas_lean"``
        when only the lean working set fits little's VMEM, or when the
        tuning cache recorded the lean variant as that class's winner.  A
        mixed :meth:`class_sharded` step then runs both variants
        simultaneously (one per pod shard); ``ShardProvenance.backend``
        records which variant each shard executed.
        """

        from repro.core import execution as X

        return {
            name: X.resolve_backend(tree.backend)
            for name, tree in self.control_trees(shape).items()
        }

    def execution_context(
        self,
        class_name: Optional[str] = None,
        *,
        shape: Optional[tuple[int, int, int]] = None,
    ):
        """An :class:`~repro.core.execution.ExecutionContext` for one class.

        ``class_name=None`` binds the fastest class (ties broken by listed
        order) — the tree the single SPMD program runs under when the mesh
        is homogeneous-per-program.  Activate it around jit tracing /
        calls::

            with mesh.execution_context("little"):
                y = ops.gemm(x, w)   # little's tuned tree governs
        """

        from repro.core.execution import ExecutionContext

        trees = self.control_trees(shape)
        if class_name is None:
            class_name = self._primary_class().name  # same anchor as the trees
        if class_name not in trees:
            raise KeyError(
                f"unknown device class {class_name!r}; have {sorted(trees)}"
            )
        return ExecutionContext(device_class=class_name, tree=trees[class_name])

    def class_contexts(self, *, shape: Optional[tuple[int, int, int]] = None):
        """One :class:`ExecutionContext` per class, in ``classes`` order
        (the order ``pod_class_indices`` indexes into)."""

        from repro.core.execution import ExecutionContext

        trees = self.control_trees(shape)
        return [
            ExecutionContext(device_class=c.name, tree=trees[c.name])
            for c in self.classes
        ]

    def class_sharded(
        self,
        fn,
        *,
        mesh,
        in_specs,
        out_specs,
        axis: str = "pod",
        shape: Optional[tuple[int, int, int]] = None,
        epilogue=None,
    ):
        """Wrap ``fn`` so each pod shard runs under its own class's tree.

        The SPMD realization of the paper's CA-SAS (§5.3): one
        ``shard_map`` step in which every pod executes the program traced
        under *its* class's execution context — big pods under big's tuned
        control tree, LITTLE pods under little's — instead of the whole
        step running under a single primary-class context.

        ``mesh`` is the ``jax.sharding.Mesh`` whose ``axis`` indexes the
        pods (``mesh.shape[axis]`` must equal ``n_pods``).  Falls back to
        the single-context wrapper (bit-identical to
        ``execution_context()`` activation, no shard_map) when the mesh
        has one class, when the mesh lacks the pod axis, or when the axis
        size is 1.  See :func:`repro.core.execution.class_sharded`.
        """

        from repro.core import execution as X
        from repro.distributed.sharding import pod_class_specs

        contexts = self.class_contexts(shape=shape)
        single = (
            len(contexts) == 1
            or axis not in getattr(mesh, "axis_names", ())
            or mesh.shape[axis] == 1
        )
        if single:
            primary = self._primary_class().name
            ctx = next(c for c in contexts if c.device_class == primary)
            return X.class_sharded(
                fn,
                mesh=mesh,
                contexts=[ctx],
                pod_class=[0] * self.n_pods,
                in_specs=in_specs,
                out_specs=out_specs,
                axis=axis,
                epilogue=epilogue,
            )
        pod_class, pod_spec = pod_class_specs(self, axis=axis)
        return X.class_sharded(
            fn,
            mesh=mesh,
            contexts=contexts,
            pod_class=pod_class,
            in_specs=in_specs,
            out_specs=out_specs,
            axis=axis,
            epilogue=epilogue,
            pod_class_spec=pod_spec,
        )

    # -- power ------------------------------------------------------------

    def pod_active_watts(self) -> list[float]:
        """Modeled draw per pod while executing at its sustained rates.

        Per-chip active power from the class spec's :class:`~repro.core.
        blocking.PowerModel` (idle + per-FLOP + per-byte at the chip's peak
        rates), scaled by chips per pod.
        """

        return [
            c.spec.power.active_w(c.peak_flops, c.hbm_bw) * c.chips_per_pod
            for _, c in self._pod_class
        ]

    def pod_idle_watts(self) -> list[float]:
        """Modeled draw per pod while powered but idle."""

        return [c.spec.power.idle_w * c.chips_per_pod for _, c in self._pod_class]

    def pod_poll_watts(self) -> list[float]:
        """Modeled draw per pod while busy-waiting (powered, no work)."""

        return [
            c.spec.power.poll_w(c.peak_flops, c.hbm_bw) * c.chips_per_pod
            for _, c in self._pod_class
        ]

    def pod_gated_watts(self) -> list[float]:
        """Modeled draw per pod while parked (power-gated)."""

        return [c.spec.power.gated_w * c.chips_per_pod for _, c in self._pod_class]

    def pods_by_efficiency(self) -> list[int]:
        """Pod indices sorted most energy-efficient first (fewest modeled
        joules per unit of work: active watts / aggregate throughput),
        ties broken by pod index."""

        active = self.pod_active_watts()
        agg = [
            c.rel_throughput * c.chips_per_pod for _, c in self._pod_class
        ]
        return sorted(
            range(self.n_pods),
            key=lambda i: (active[i] / agg[i] if agg[i] > 0 else float("inf"), i),
        )

    # -- scheduling -------------------------------------------------------

    def chunk_table(self, global_batch: int) -> S.ChunkTable:
        if self.strategy == "sss":
            return S.sss_partition(global_batch, self.n_pods)
        return self.scheduler.table(global_batch)

    def observe_step(self, per_pod_units: Sequence[int], per_pod_times: Sequence[float]):
        """Feed measured step times back (DAS/CA-DAS straggler mitigation)."""

        if self.strategy in ("das", "ca-das"):
            self.scheduler.observe(per_pod_units, per_pod_times)

    def slot_budgets(
        self,
        slots_per_pod: int,
        n_work: int,
        *,
        parked: Optional[Sequence[int]] = None,
    ) -> list[int]:
        """Per-pod admission budgets over a fixed ``n_pods × slots_per_pod``
        slot table (the serving engine's slot regions).

        ``n_work`` is the offered load (in-flight + queued requests); the
        scheduler's chunk table splits it across pods proportionally to
        calibrated throughput — under the same rebalance hysteresis as
        training — and any share exceeding a pod's fixed region spills to
        pods with headroom, highest *aggregate* pod throughput
        (``rel_throughput × chips_per_pod``) first, consistent with how
        ``sas_partition(workers=...)`` apportions and with
        :meth:`imbalance`.  At saturation every region is full; below it,
        slow pods hold proportionally fewer concurrent requests, the
        serving analogue of the paper's smaller LITTLE panel.  Budgets
        change only when the scheduler re-derives its table (drift past
        the threshold) or the load level changes — never mid-step.

        ``parked`` pods (the energy objective's power-gated pods) get a
        hard zero budget; their share and any spill go to unparked pods
        only, and the total is capped by unparked capacity.
        """

        cap = int(slots_per_pod)
        parked_set = set(int(p) for p in parked) if parked else set()
        unparked = [i for i in range(self.n_pods) if i not in parked_set]
        total = min(int(n_work), len(unparked) * cap)
        if total <= 0 or not unparked:
            return [0] * self.n_pods
        sizes = list(self.chunk_table(total).sizes())
        while len(sizes) < self.n_pods:
            sizes.append(0)
        budgets = [
            0 if i in parked_set else min(cap, int(s)) for i, s in enumerate(sizes)
        ]
        spill = total - sum(budgets)
        # Highest-aggregate-throughput pods absorb the spill first
        # (stable by pod order); parked pods never do.
        order = sorted(
            unparked,
            key=lambda i: (
                -(self._pod_class[i][1].rel_throughput
                  * self._pod_class[i][1].chips_per_pod),
                i,
            ),
        )
        while spill > 0:
            for i in order:
                if spill == 0:
                    break
                take = min(cap - budgets[i], spill)
                budgets[i] += take
                spill -= take
        return budgets

    def batch_layout(self, global_batch: int) -> BatchLayout:
        table = self.chunk_table(global_batch)
        sizes = table.sizes()
        while len(sizes) < self.n_pods:
            sizes.append(0)
        c_max = max(
            self.batch_tile,
            int(np.ceil(max(sizes) / self.batch_tile)) * self.batch_tile,
        )
        mask = np.zeros((self.n_pods, c_max), np.float32)
        for i, s in enumerate(sizes):
            mask[i, :s] = 1.0
        return BatchLayout(global_batch=global_batch, sizes=sizes, c_max=c_max, mask=mask)

    # -- analysis ---------------------------------------------------------

    def imbalance(self, layout: BatchLayout) -> float:
        """Relative makespan excess vs a perfectly rate-proportional split."""

        rates = np.array(
            [c.rel_throughput * c.chips_per_pod for _, c in self._pod_class], np.float64
        )
        t = np.array(layout.sizes) / rates
        ideal = layout.global_batch / rates.sum()
        return float(t.max() / ideal - 1.0)


def calibrate_ratios(step_times: Sequence[Sequence[float]], units: Sequence[int]) -> list[float]:
    """Throughput ratios from measured per-pod step times (median-robust)."""

    rates = [u / float(np.median(ts)) for u, ts in zip(units, step_times)]
    top = max(rates)
    return [r / top for r in rates]


__all__ = [
    "DeviceClass",
    "AsymmetricMesh",
    "BatchLayout",
    "homogeneous_classes",
    "biglittle_classes",
    "calibrate_ratios",
]

"""Core: the paper's contribution — cache-aware GEMM configuration and
asymmetric scheduling — as composable JAX-side modules."""

from repro.core.blocking import (
    BlockConfig,
    CacheHierarchy,
    GotoBlocking,
    TpuCoreSpec,
    derive_block_config,
    derive_goto_blocking,
)
from repro.core.control_tree import ControlTree, build_control_trees
from repro.core.execution import (
    ExecutionContext,
    context_for_tree,
    current_context,
    default_context,
)
from repro.core.schedule import (
    ChunkTable,
    DynamicScheduler,
    ca_sas_partition,
    das_schedule,
    sas_partition,
    sss_partition,
)
from repro.core.asymmetric import AsymmetricMesh, DeviceClass

__all__ = [
    "BlockConfig", "CacheHierarchy", "GotoBlocking", "TpuCoreSpec",
    "derive_block_config", "derive_goto_blocking",
    "ControlTree", "build_control_trees",
    "ExecutionContext", "context_for_tree", "current_context", "default_context",
    "ChunkTable", "DynamicScheduler",
    "ca_sas_partition", "das_schedule", "sas_partition", "sss_partition",
    "AsymmetricMesh", "DeviceClass",
]

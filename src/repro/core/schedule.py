"""Iteration-space partitioning and scheduling across asymmetric device classes.

Implements the paper's four scheduling strategies (Sections 4, 5.2, 5.4) as
pure, testable partitioners over a 1-D iteration space:

  * **SSS** — symmetric-static: equal chunks per worker, oblivious to class
    throughput (the architecture-oblivious baseline of Section 4).
  * **SAS** — static-asymmetric: chunks proportional to a per-class
    performance *ratio* knob (Section 5.2; the paper exposes the ratio via
    environment variables — here it is an explicit argument / calibrated
    from measurements).
  * **CA-SAS** — SAS with per-class tile alignment: each class's chunk is
    aligned to *its own* stride (``m_c`` in the paper; the per-class block
    shape or microbatch on TPU) — the "two control trees" of Section 5.3.
  * **DAS / CA-DAS** — dynamic: a discrete-time greedy scheduler where each
    class's leader grabs the next chunk (sized by its own stride) whenever
    the class becomes idle (Section 5.4's critical-section loop).  Under
    XLA's static-shape SPMD an intra-step work queue is not expressible, so
    the production path uses :class:`DynamicScheduler` — a between-steps
    feedback controller that re-derives the SAS table from observed
    per-class throughput (straggler mitigation).  The intra-step queue
    itself is modelled faithfully in :mod:`repro.core.simulator` for
    validation against the paper's figures.

All partitioners guarantee exact coverage (chunks sum to the iteration
count) and respect tile alignment where requested; these invariants are
property-tested in ``tests/test_property.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.observability import trace as _trace


@dataclasses.dataclass(frozen=True)
class Chunk:
    """A half-open range ``[start, start + size)`` assigned to a class."""

    cls: int
    start: int
    size: int

    @property
    def stop(self) -> int:
        return self.start + self.size


@dataclasses.dataclass(frozen=True)
class ChunkTable:
    """A full static partition of ``[0, n_units)`` across classes."""

    n_units: int
    chunks: tuple[Chunk, ...]

    def sizes(self) -> list[int]:
        out: dict[int, int] = {}
        for c in self.chunks:
            out[c.cls] = out.get(c.cls, 0) + c.size
        n_cls = max(out) + 1 if out else 0
        return [out.get(i, 0) for i in range(n_cls)]

    def validate(self) -> None:
        pos = 0
        for c in self.chunks:
            if c.start != pos or c.size < 0:
                raise ValueError(f"non-contiguous chunk table at {c}")
            pos = c.stop
        if pos != self.n_units:
            raise ValueError(f"chunk table covers {pos} of {self.n_units} units")


# ---------------------------------------------------------------------------
# Scheduling objectives
# ---------------------------------------------------------------------------

# What the scheduler optimizes.  ``perf`` is the paper's baseline (minimize
# makespan); ``energy`` minimizes modeled joules (the companion work's
# throughput-per-Watt goal); ``edp`` minimizes the energy-delay product,
# the standard compromise between the two.
OBJECTIVES = ("perf", "energy", "edp")

# Exponent applied to the per-class energy-efficiency discount: perf
# ignores efficiency entirely, energy weighs it fully, edp takes the
# geometric middle (sqrt) — minimizing E*t trades each factor evenly.
_OBJECTIVE_EXP = {"perf": 0.0, "energy": 1.0, "edp": 0.5}


def validate_objective(objective: str) -> str:
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; expected one of {OBJECTIVES}"
        )
    return objective


def objective_discounts(
    objective: str,
    rates: Sequence[float],
    powers: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """Per-class efficiency discounts in ``(0, 1]`` for an objective.

    ``powers[i]`` is class ``i``'s modeled active draw in watts; the energy
    cost of a unit of work on class ``i`` is then ``powers[i] / rates[i]``
    joules.  The discount is ``(c_min / c_i) ** exp`` — 1.0 for the most
    efficient class, smaller for classes that burn more joules per unit —
    raised to the objective's exponent (0 for perf, 1 for energy, 0.5 for
    edp).  Under a *uniform* power model (powers proportional to rates,
    i.e. identical joules per unit) every discount is exactly 1.0, so the
    energy and edp objectives reduce bit-identically to perf.
    """

    validate_objective(objective)
    rates = np.asarray(rates, dtype=np.float64)
    n = len(rates)
    if objective == "perf" or powers is None:
        return np.ones(n)
    powers = np.asarray(powers, dtype=np.float64)
    if len(powers) != n:
        raise ValueError(f"expected {n} class powers, got {len(powers)}")
    disc = np.ones(n)
    live = (rates > 0.0) & (powers > 0.0)
    if not live.any():
        return disc
    cost = np.where(live, powers / np.maximum(rates, 1e-300), np.inf)  # J/unit
    rel = cost[live].min() / cost[live]
    disc[live] = rel ** _OBJECTIVE_EXP[objective]
    return disc


def _largest_remainder(weights: np.ndarray, total: int) -> np.ndarray:
    """Apportion ``total`` integer units proportionally to ``weights``."""

    weights = np.asarray(weights, dtype=np.float64)
    if weights.sum() <= 0:
        raise ValueError("weights must have positive sum")
    quota = weights / weights.sum() * total
    base = np.floor(quota).astype(np.int64)
    rem = total - int(base.sum())
    # Hand out the remainder to the largest fractional parts.
    order = np.argsort(-(quota - base))
    base[order[:rem]] += 1
    return base


def sss_partition(n_units: int, n_classes: int) -> ChunkTable:
    """Architecture-oblivious equal split (paper Section 4)."""

    sizes = _largest_remainder(np.ones(n_classes), n_units)
    return _table_from_sizes(n_units, sizes)


def sas_partition(
    n_units: int,
    ratios: Sequence[float],
    *,
    workers: Optional[Sequence[int]] = None,
    tiles: Optional[Sequence[int]] = None,
) -> ChunkTable:
    """Static-asymmetric partition (paper Section 5.2).

    ``ratios[i]`` is the relative per-worker throughput of class ``i`` (the
    paper's big:LITTLE ratio knob).  ``workers[i]`` scales by class size
    (4 cores per cluster in the paper; chips per pod here).  ``tiles[i]``
    aligns each class's chunk to its own stride — passing per-class tiles
    turns SAS into **CA-SAS** (two control trees, Section 5.3); a common
    tile is plain SAS with a single control tree.
    """

    ratios = np.asarray(ratios, dtype=np.float64)
    n_classes = len(ratios)
    w = np.asarray(workers if workers is not None else np.ones(n_classes))
    sizes = _largest_remainder(ratios * w, n_units)

    if tiles is not None:
        sizes = _align_sizes(sizes, np.asarray(tiles, dtype=np.int64), n_units)
    return _table_from_sizes(n_units, sizes)


def ca_sas_partition(
    n_units: int,
    ratios: Sequence[float],
    tiles: Sequence[int],
    *,
    workers: Optional[Sequence[int]] = None,
) -> ChunkTable:
    """CA-SAS = SAS with per-class tile (stride) alignment (Section 5.3)."""

    return sas_partition(n_units, ratios, workers=workers, tiles=tiles)


def _align_sizes(sizes: np.ndarray, tiles: np.ndarray, n_units: int) -> np.ndarray:
    """Round class sizes to their tiles while preserving the exact total.

    A class whose tile exceeds its proportional share cannot align without
    starving — *that class alone* keeps its unaligned share (the paper's
    partial-panel case: a cluster processes a sub-``m_c`` panel at reduced
    efficiency rather than no panel at all); every other class keeps its
    ``m_c`` alignment.  The residue from rounding the aligned classes down
    goes to a class that is already unaligned when one exists, else to the
    class with the smallest tile (the paper's LITTLE cluster mopping up
    remainder rows).  Since ``aligned[i] <= sizes[i]`` for every class the
    residue is provably non-negative.
    """

    sizes = sizes.copy()
    starved = (tiles > np.maximum(sizes, 1)) & (sizes > 0)
    aligned = np.where(starved, sizes, (sizes // tiles) * tiles)
    residue = int(n_units - aligned.sum())
    if starved.any():
        # Already-partial classes absorb the remainder; pick the one with
        # the smallest tile (closest analogue of the paper's sink).
        candidates = np.where(starved)[0]
        sink = int(candidates[np.argmin(tiles[candidates])])
    else:
        sink = int(np.argmin(tiles))
    aligned[sink] += residue
    return aligned


def _table_from_sizes(n_units: int, sizes: np.ndarray) -> ChunkTable:
    chunks = []
    pos = 0
    for cls, s in enumerate(sizes):
        chunks.append(Chunk(cls=cls, start=pos, size=int(s)))
        pos += int(s)
    table = ChunkTable(n_units=n_units, chunks=tuple(chunks))
    table.validate()
    return table


# ---------------------------------------------------------------------------
# Dynamic scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DasResult:
    """Outcome of the intra-step dynamic schedule (paper Section 5.4)."""

    assignments: list[Chunk]
    makespan: float
    busy: list[float]  # per-class busy time
    energy_j: Optional[float] = None  # modeled joules (when powers given)

    def sizes(self) -> list[int]:
        n_cls = len(self.busy)
        out = [0] * n_cls
        for c in self.assignments:
            out[c.cls] += c.size
        return out


def das_schedule(
    n_units: int,
    rates: Sequence[float],
    strides: Sequence[int],
    *,
    grab_overhead: float = 0.0,
    unit_cost: float = 1.0,
    objective: str = "perf",
    powers: Optional[Sequence[float]] = None,
    idle_powers: Optional[Sequence[float]] = None,
) -> DasResult:
    """Greedy dynamic chunk distribution (paper Section 5.4).

    Each class's leader, upon becoming idle, enters the critical section and
    claims the next ``strides[cls]`` units (its own ``m_c``); the work is
    then spread across the class's cores (folded into ``rates[cls]``, the
    aggregate class throughput in units/second).  ``grab_overhead`` models
    the critical section.  Deterministic: ties broken by class index.

    Non-``perf`` objectives bias the greedy choice toward energy-efficient
    classes via *virtual time*: class ``i`` advances its selection clock by
    ``dur / discount_i`` (see :func:`objective_discounts`), so a class that
    burns more joules per unit looks proportionally slower to the selector
    and grabs proportionally less work — while physical times, busy, and
    makespan still account real seconds.  Under a uniform power model every
    discount is 1.0 and the schedule is bit-identical to ``perf``.  When
    ``powers`` is given, ``energy_j`` reports the modeled joules (active
    draw while busy plus, when ``idle_powers`` is given, idle draw for the
    remainder of the makespan).

    A zero-rate class (a dead pod) never grabs work — it is skipped by the
    greedy loop, exactly as a hung cluster leader would never re-enter the
    paper's critical section.  All classes dead is unschedulable and raises.
    """

    rates = list(map(float, rates))
    strides = [max(1, int(s)) for s in strides]
    disc = objective_discounts(objective, rates, powers)
    alive = [i for i, r in enumerate(rates) if r > 0.0]
    if not alive and n_units > 0:
        raise ValueError("all class rates are zero — nothing can grab work")
    t = [0.0] * len(rates)   # next-free physical time per class
    tv = [0.0] * len(rates)  # virtual time: physical / efficiency discount
    busy = [0.0] * len(rates)
    pos = 0
    assignments: list[Chunk] = []
    while pos < n_units:
        cls = min(alive, key=lambda i: (tv[i], i))
        size = min(strides[cls], n_units - pos)
        dur = grab_overhead + size * unit_cost / rates[cls]
        assignments.append(Chunk(cls=cls, start=pos, size=size))
        pos += size
        t[cls] += dur
        tv[cls] += dur / disc[cls] if disc[cls] > 0 else float("inf")
        busy[cls] += dur
    makespan = max(t) if t else 0.0
    energy = None
    if powers is not None:
        p = np.asarray(powers, dtype=np.float64)
        energy = float(np.dot(p, busy))
        if idle_powers is not None:
            ip = np.asarray(idle_powers, dtype=np.float64)
            energy += float(np.dot(ip, makespan - np.asarray(busy)))
    return DasResult(
        assignments=assignments, makespan=makespan, busy=busy, energy_j=energy
    )


class DynamicScheduler:
    """Between-steps feedback controller (the SPMD-compatible CA-DAS).

    Observes per-class execution times of the previous step and re-derives
    the SAS chunk table for the next one from the throughput EMA.  This is
    the production straggler-mitigation path: a pod that slows down (thermal
    throttling, failing host) automatically sheds work, exactly as the
    paper's dynamic scheme sheds work from the LITTLE cluster — but at step
    granularity, which is what XLA's static shapes allow.

    **Rebalance hysteresis**: re-deriving the table costs a relayout
    downstream (the trainer re-pads its batch; the serving engine resizes
    its slot regions), so :meth:`table` keeps returning the *previous*
    partition until the calibrated throughput shares drift past
    ``rebalance_threshold`` (relative drift of the normalized rates since
    the last re-derivation).  This mirrors how the paper's workers keep
    their assignment between micro-kernel grabs (§5.4) instead of
    re-partitioning every iteration; noise-level timing jitter no longer
    thrashes the layout.
    """

    def __init__(
        self,
        n_classes: int,
        *,
        init_ratios: Optional[Sequence[float]] = None,
        tiles: Optional[Sequence[int]] = None,
        workers: Optional[Sequence[int]] = None,
        ema: float = 0.5,
        rebalance_threshold: float = 0.05,
        objective: str = "perf",
        powers: Optional[Sequence[float]] = None,
    ):
        self.n_classes = n_classes
        self.ema = float(ema)
        self.tiles = list(tiles) if tiles is not None else None
        self.workers = list(workers) if workers is not None else None
        self.objective = validate_objective(objective)
        self.powers = (
            np.asarray(powers, dtype=np.float64).copy() if powers is not None else None
        )
        if self.powers is not None and len(self.powers) != n_classes:
            raise ValueError(
                f"expected {n_classes} class powers, got {len(self.powers)}"
            )
        self.rates = np.asarray(
            init_ratios if init_ratios is not None else np.ones(n_classes), dtype=np.float64
        ).copy()
        self.rebalance_threshold = float(rebalance_threshold)
        self._last_sizes: Optional[np.ndarray] = None
        self._last_n_units: Optional[int] = None
        self._table_rates: Optional[np.ndarray] = None  # rates at last re-derive
        self._last_table: Optional[ChunkTable] = None
        self.rebalances = 0

    def observe(self, class_units: Sequence[int], class_times: Sequence[float]) -> None:
        """Record measured units processed and wall time per class.

        A starvation floor (2 % of the fastest class) keeps every class
        observable: a class that received zero units has no throughput
        signal, and without the floor it could never re-enter the schedule
        (the paper's dynamic queue has the same property — every cluster
        always grabs at least one chunk).

        Both sequences must have exactly ``n_classes`` entries: a caller
        handing per-pod telemetry to a per-class scheduler (or vice versa)
        is a wiring bug, not a partial observation.
        """

        if len(class_units) != self.n_classes or len(class_times) != self.n_classes:
            raise ValueError(
                f"observe() expects {self.n_classes} per-class entries, got "
                f"{len(class_units)} units / {len(class_times)} times"
            )
        for i, (u, dt) in enumerate(zip(class_units, class_times)):
            if u > 0 and dt > 0:
                inst = u / dt
                self.rates[i] = self.ema * inst + (1 - self.ema) * self.rates[i]
        floor = 0.02 * float(self.rates.max())
        self.rates = np.maximum(self.rates, floor)

    def drift(self) -> float:
        """Relative drift of the normalized rates since the last re-derive.

        ``max_i |r̂_i - r̂_last_i| / max_j r̂_last_j`` over the per-class
        throughput *shares* (normalization makes a uniform slowdown — which
        changes no assignment — zero drift).  The delta is measured against
        the **largest** reference share, not each class's own: a
        starvation-floored near-dead class (share pinned at the ~2 % floor)
        would otherwise amplify noise-level jitter into constant rebalance
        thrash, since any absolute wobble divided by a tiny own-share looks
        enormous.  ``inf`` before any table has been derived.
        """

        if self._table_rates is None:
            return float("inf")
        cur = self.rates / self.rates.sum()
        ref = self._table_rates / self._table_rates.sum()
        return float(np.max(np.abs(cur - ref)) / ref.max())

    def needs_rebalance(self) -> bool:
        """Would :meth:`table` re-derive the partition right now?"""

        return self.drift() > self.rebalance_threshold

    def table(self, n_units: int) -> ChunkTable:
        """The partition for ``n_units``, re-derived only past hysteresis.

        The cached table is reused while the rate shares stay within
        ``rebalance_threshold`` of the shares the table was derived from
        (and ``n_units`` is unchanged); a different ``n_units`` always
        re-derives (the old sizes cannot cover it) without counting as a
        rebalance.
        """

        if (
            self._last_table is not None
            and self._last_n_units == n_units
            and not self.needs_rebalance()
        ):
            return self._last_table
        drift = self.drift()  # trigger magnitude, before _table_rates resets
        # Non-perf objectives shrink inefficient classes' shares by their
        # efficiency discount; under uniform power every discount is 1.0
        # and the weights (hence the table) are bit-identical to perf.
        weights = self.rates * objective_discounts(
            self.objective, self.rates, self.powers
        )
        t = sas_partition(n_units, weights, workers=self.workers, tiles=self.tiles)
        sizes = np.asarray(t.sizes())
        if (
            self._last_sizes is not None
            and self._last_n_units == n_units
            and len(self._last_sizes) == len(sizes)
            and np.any(sizes != self._last_sizes)
        ):
            self.rebalances += 1
            _trace.instant(
                "scheduler.rebalance", cat="scheduler",
                drift=drift, threshold=self.rebalance_threshold,
                n_units=n_units,
                before=[int(s) for s in self._last_sizes],
                after=[int(s) for s in sizes],
            )
        self._last_sizes = sizes
        self._last_n_units = n_units
        self._table_rates = self.rates.copy()
        self._last_table = t
        return t


def deficit_route(weights: Sequence[float], routed: Sequence[int]) -> int:
    """Largest-remainder router: the class furthest behind its quota.

    Given target ``weights`` and cumulative per-class ``routed`` counts,
    returns the class whose share of the *next* total (``sum(routed)+1``)
    is most under-served — so the running split tracks the proportional
    quota with bounded deficit, exactly like the serving engine's
    admission router (extracted from there so the fleet can route
    requests over engines with the same arithmetic it uses over classes).
    """

    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1 or len(w) != len(routed):
        raise ValueError(
            f"weights/routed arity mismatch: {len(w)} vs {len(routed)}"
        )
    if not w.sum() > 0:
        raise ValueError(f"need positive total weight, got {w.tolist()}")
    total = int(sum(routed)) + 1
    quota = w / w.sum() * total
    base = np.floor(quota).astype(np.int64)
    rem = total - int(base.sum())
    order = np.argsort(-(quota - base), kind="stable")
    base[order[:rem]] += 1
    return int(np.argmax(base - np.asarray(routed)))


def fleet_scheduler(
    rel_throughput: Sequence[float],
    *,
    ema: float = 0.5,
    rebalance_threshold: float = 0.05,
    objective: str = "perf",
    powers: Optional[Sequence[float]] = None,
) -> DynamicScheduler:
    """The engines-as-classes adapter: a :class:`DynamicScheduler` whose
    "classes" are whole serving engines.

    This is the paper's scheduling story lifted one level — calibrated
    tokens-per-second per engine plays ``rel_throughput``, and the same
    EMA/drift/hysteresis machinery (class-count-agnostic since PR 3)
    balances *requests* over engines instead of rows over pods.  No
    tiles, no worker multiplicity: a request is the indivisible unit.
    """

    rel = [float(r) for r in rel_throughput]
    if not rel or min(rel) <= 0:
        raise ValueError(f"need positive per-engine throughputs, got {rel}")
    return DynamicScheduler(
        len(rel),
        init_ratios=rel,
        ema=ema,
        rebalance_threshold=rebalance_threshold,
        objective=objective,
        powers=powers,
    )


def balanced_ratio(rates: Sequence[float]) -> float:
    """The paper's optimal ratio knob: fast rate / slow rate (Section 5.2.2).

    Defined for any number of classes in any order — the knob is the spread
    between the fastest and slowest class (1.0 when homogeneous or with a
    single class).  Non-positive rates have no meaningful ratio and raise.
    """

    rates = list(map(float, rates))
    if not rates:
        raise ValueError("need at least one class rate")
    if min(rates) <= 0.0:
        raise ValueError(f"class rates must be positive, got {rates}")
    return max(rates) / min(rates)


__all__ = [
    "Chunk",
    "ChunkTable",
    "DasResult",
    "DynamicScheduler",
    "OBJECTIVES",
    "validate_objective",
    "objective_discounts",
    "sss_partition",
    "sas_partition",
    "ca_sas_partition",
    "das_schedule",
    "balanced_ratio",
    "deficit_route",
    "fleet_scheduler",
]

"""Control trees: per-device-class execution configuration.

BLIS drives every operation from a recursive *control tree* encoding loop
strides, packing points, and per-loop parallelization (paper Section 5.1).
The paper's key mechanism (Section 5.3) duplicates this structure — one tree
per core class — so "fast" and "slow" threads run with different cache
parameters and, potentially, different micro-kernels.

Here a :class:`ControlTree` carries, per device class:

  * the Pallas :class:`~repro.core.blocking.BlockConfig` (the loop strides),
  * the coarse/fine loop choice (which axis is partitioned across classes
    vs within a class — the paper's Loop 1/3 × Loop 4/5 grid),
  * the micro-kernel selection (a name in the
    :data:`repro.core.execution.BACKENDS` dispatch table).

:func:`build_control_trees` reproduces the Section 5.3 dependency: if the
coarse axis is the *rows* axis (the paper's Loop 3), the staged B panel is
shared between classes, forcing a common ``bk`` and a re-derived (smaller)
``bm`` for classes with less fast memory.  Each class's block config first
consults the ``$REPRO_TUNING_CACHE`` entry for *its own* core spec (the
paper's per-class empirical optimum), falling back to the analytical
derivation; ``block_source`` records which path won.

Trees are *activated*, not hand-threaded: wrap them in an
:class:`~repro.core.execution.ExecutionContext` (usually via
``AsymmetricMesh.execution_context``) and every ``ops.gemm`` underneath
runs under the class's configuration.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Mapping, Optional

from repro.core import blocking as B
from repro.core import execution as X
from repro.core.execution import Backend  # one backend vocabulary (re-export)

CoarseLoop = Literal["cols", "rows"]  # paper's Loop 1 (j_c/n) vs Loop 3 (i_c/m)
FineLoop = Literal["loop4", "loop5", "both"]


@dataclasses.dataclass(frozen=True)
class ControlTree:
    """Execution configuration for one device class."""

    device_class: str
    block: B.BlockConfig
    coarse_loop: CoarseLoop = "rows"
    fine_loop: FineLoop = "loop4"
    backend: Backend = "xla"
    # TPU spec used to derive `block`; kept for re-derivation under
    # shared-panel constraints.
    spec: B.TpuCoreSpec = B.TPU_V5E
    # Provenance of `block`: "tuned" (cache hit for this class's spec) or
    # "analytical" (Section-3.3 derivation / shared-panel re-derivation).
    block_source: str = "analytical"
    # (m, k, n) the tree was built for; execution contexts reuse `block`
    # verbatim for calls in the same 128-lane shape bucket.
    problem_shape: Optional[tuple[int, int, int]] = None

    def with_block(self, block: B.BlockConfig) -> "ControlTree":
        return dataclasses.replace(self, block=block)


def build_control_trees(
    specs: Mapping[str, B.TpuCoreSpec],
    m: int,
    k: int,
    n: int,
    *,
    coarse_loop: CoarseLoop = "rows",
    fine_loop: FineLoop = "loop4",
    backend: Backend = "xla",
    cache_aware: bool = True,
    dtype_bytes: int = 2,
    use_cache: bool = True,
) -> dict[str, ControlTree]:
    """One control tree per device class (paper Sections 5.1/5.3).

    With ``cache_aware=False`` every class reuses the *first* class's block
    config — the single-control-tree baseline the paper calls plain SAS/DAS.
    With ``cache_aware=True`` each class derives its own config; if
    ``coarse_loop == "rows"`` (Loop 3) the B panel is shared, so ``bk`` is
    forced to the first class's value and each other class re-derives the
    largest ``bm`` that fits its own VMEM at that ``bk`` — the exact
    structure of the paper's ``k_c = 952 -> m_c = 32`` adjustment.

    With ``use_cache=True`` (default) each class's config is resolved
    through :func:`repro.core.execution.resolve_block_config`: the active
    ``$REPRO_TUNING_CACHE`` entry for that class's spec wins, the
    analytical derivation is the fallback — with no cache env var set this
    is exactly the old behavior.  Under the shared-B-panel constraint a
    tuned entry is honored only if it agrees on the shared ``bk``;
    otherwise the class falls back to the ``bm`` re-derivation (a tuned
    panel stride cannot override the panel it shares).

    **Micro-kernel variants** (paper §5.3: each class may get its own
    micro-kernel, not just its own blocking): when ``backend`` is a
    Pallas-family backend, a class's tree may name the VMEM-lean variant
    (``execution.LEAN_VARIANTS``) instead —

    * a tuned cache entry that *records* a kernel variant selects it
      (mapped onto ``backend``'s compiled/interpret family), and
    * under the shared-B-panel constraint, a class whose VMEM cannot hold
      the shared panel double-buffered keeps the **full panel on the lean
      kernel** when its single-buffered working set fits, rather than
      shrinking ``bm`` — the lean trade (no DMA/compute overlap, half the
      staging footprint) beats crippling the panel's arithmetic intensity.
    """

    names = list(specs)
    if not names:
        raise ValueError("need at least one device class")
    first = names[0]
    dtype_name = X.dtype_name_for_bytes(dtype_bytes)
    lean_backend = X.LEAN_VARIANTS.get(backend)  # None for xla / lean itself

    def _recorded_variant(spec: B.TpuCoreSpec) -> str:
        """Backend for a tuned entry: the cache-recorded variant, mapped
        onto the requested backend's family; XLA trees stay XLA."""

        if not use_cache or backend == "xla":
            return backend
        recorded = X.tuned_kernel_backend(
            m, k, n, spec=spec, dtype_name=dtype_name
        )
        if recorded is None or recorded == "xla":
            return backend
        return X.align_backend_family(recorded, backend)

    def _resolve(spec: B.TpuCoreSpec) -> tuple[B.BlockConfig, str]:
        # Resolve under the buffering model of the kernel the tree will
        # actually name: an entry recorded for the lean kernel pairs with
        # the lean backend (set by _recorded_variant below), so its
        # single-buffer-only block stays acceptable here.
        db = X.backend_double_buffers(_recorded_variant(spec))
        if use_cache:
            return X.resolve_block_config(
                m, k, n, spec=spec, dtype_name=dtype_name, dtype_bytes=dtype_bytes,
                double_buffer=db,
            )
        return (
            B.derive_block_config(
                m, k, n, spec=spec, dtype_bytes=dtype_bytes, double_buffer=db
            ),
            "analytical",
        )

    base, base_src = _resolve(specs[first])
    trees: dict[str, ControlTree] = {}
    for name in names:
        class_backend = backend
        if not cache_aware or name == first:
            blk, src = base, base_src
            if src == "tuned":
                # Always the *first* class's recorded variant: with
                # cache_aware=False every class mirrors the first class's
                # configuration wholesale (the single-control-tree SAS
                # baseline) — consulting each class's own entry here would
                # leak per-class variants into a deliberately uniform run.
                class_backend = _recorded_variant(specs[first])
        elif coarse_loop == "rows":
            # Shared B panel: a tuned entry for this class may only be used
            # if it agrees on the common bk; otherwise keep the full shared
            # panel on the lean kernel when only its single-buffered
            # working set fits this class's VMEM, else re-derive bm.
            tuned = (
                X.tuned_block_config(
                    m, k, n,
                    spec=specs[name],
                    dtype_name=dtype_name,
                    dtype_bytes=dtype_bytes,
                )
                if use_cache
                else None
            )
            if tuned is not None and tuned.bk == base.bk:
                blk, src = tuned, "tuned"
                class_backend = _recorded_variant(specs[name])
            else:
                blk = _rederive_bm(
                    specs[name], base, dtype_bytes,
                    double_buffer=X.backend_double_buffers(backend),
                )
                src = "analytical"
                if lean_backend is not None:
                    # The lean kernel's single-buffered working set keeps a
                    # larger (often the full) shared panel in this class's
                    # VMEM: prefer the bigger panel on the lean variant
                    # over crippling bm under the pipelined kernel.
                    lean_blk = _rederive_bm(
                        specs[name], base, dtype_bytes, double_buffer=False
                    )
                    if lean_blk.bm > blk.bm:
                        blk, class_backend = lean_blk, lean_backend
        else:
            # Independent panels (Loop 1): fully independent resolution.
            blk, src = _resolve(specs[name])
            if src == "tuned":
                class_backend = _recorded_variant(specs[name])
        trees[name] = ControlTree(
            device_class=name,
            block=blk,
            coarse_loop=coarse_loop,
            fine_loop=fine_loop,
            backend=class_backend,
            spec=specs[name],
            block_source=src,
            problem_shape=(m, k, n),
        )
    return trees


def _rederive_bm(
    spec: B.TpuCoreSpec,
    base: B.BlockConfig,
    dtype_bytes: int,
    *,
    double_buffer: bool = True,
) -> B.BlockConfig:
    budget = int(spec.vmem_bytes * spec.vmem_fill)
    bk, bn = base.bk, base.bn
    bm = base.bm
    while bm > spec.mxu:
        cfg = B.BlockConfig(bm=bm, bk=bk, bn=bn, dtype_bytes=dtype_bytes)
        if cfg.vmem_bytes(double_buffer) <= budget:
            break
        bm //= 2
    cfg = B.BlockConfig(bm=max(bm, spec.mxu), bk=bk, bn=bn, dtype_bytes=dtype_bytes)
    return cfg


__all__ = ["ControlTree", "build_control_trees", "CoarseLoop", "FineLoop", "Backend"]

"""Measured per-pod step times: the probe that closes the DAS loop.

One SPMD step yields a single wall time, so per-pod attribution needs a
measurement substrate (serving.py's long-standing caveat; PR 5 removed
the fabricated equal-times fallback precisely because occupancy would
masquerade as speed).  :class:`StepTimeProbe` supplies the honest
signal the way ``benchmarks.bench_schedulers.measure_class_step_times``
does for calibration: periodically time a probe program under each
class's execution context — the class's own control tree picks its
backend and block shapes, so the measurement reflects that class's real
per-row cost — and between refreshes report

    ``times[pod] = units[pod] * row_seconds[class(pod)]``

for the units the engine actually ran on each pod.  Under
``DynamicScheduler.observe`` the rate then reduces to
``units / (units * s_c) = 1 / s_c`` — pure class speed, independent of
occupancy, which is exactly the quantity the paper's §5.2.2/§5.4
feedback is defined over.

The probe is the engine's default ``pod_time_hook`` but stays inert
(returns ``None``; calibration frozen, zero work) until observability
is enabled — keeping the off-is-free contract and the engine-vs-baseline
bit-identity/bench gates untouched.  Pass ``always=True`` to measure
regardless (tests, external telemetry loops).
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

from repro.observability import metrics as MET
from repro.observability import trace as T

_ROW_SECONDS = MET.gauge(
    "probe_row_seconds",
    "Measured per-row step cost of one device class (last refresh)",
    labels=("device_class",),
)
_REFRESHES = MET.counter(
    "probe_refreshes_total", "Probe re-measurement rounds performed"
)


class StepTimeProbe:
    """``ServingEngine(pod_time_hook=...)`` implementation on measured time.

    Parameters
    ----------
    asym : the engine's :class:`~repro.core.asymmetric.AsymmetricMesh`
        (its per-class execution contexts are what get timed).
    probe_shape : GEMM the default workload times under each class's
        context; rows (``m``) are the per-row normalizer.  Small by
        default — a refresh costs ~classes × reps × one tiny GEMM.
    interval : steps between re-measurements (the first refresh lands in
        the engine's step-0 compile window, so steady-state decode pays
        nothing until the next interval boundary).
    reps : timing repetitions per class (median taken).
    workloads : optional ``{class_name: zero-arg callable}`` override —
        the callable is timed in place of the probe GEMM (still under
        the class's context, still normalized by ``probe_shape[0]``
        rows).  Lets tests and fleets probe with representative work.
    always : measure even while observability is disabled.
    """

    def __init__(
        self,
        asym,
        *,
        probe_shape: tuple[int, int, int] = (128, 128, 128),
        interval: int = 64,
        reps: int = 2,
        workloads: Optional[dict[str, Callable[[], object]]] = None,
        always: bool = False,
    ):
        self.asym = asym
        self.probe_shape = tuple(probe_shape)
        self.interval = max(1, int(interval))
        self.reps = max(1, int(reps))
        self.workloads = dict(workloads) if workloads else None
        self.always = bool(always)
        self._pod_class = asym.pod_class_indices()
        self._row_seconds: Optional[list[float]] = None  # per class index
        self.last_measured: dict[str, float] = {}
        self.refreshes = 0

    def active(self) -> bool:
        return self.always or T.enabled()

    def _default_workload(self, ctx):
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.kernels import ops

        m, k, n = self.probe_shape
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        return lambda: jax.block_until_ready(ops.gemm(a, b))

    def refresh(self) -> list[float]:
        """Re-measure every class's per-row cost; returns the new table."""

        with T.span("probe.refresh", cat="probe", shape=list(self.probe_shape)):
            rows = max(1, self.probe_shape[0])
            out = []
            for c in self.asym.classes:
                ctx = self.asym.execution_context(c.name, shape=self.probe_shape)
                with ctx:
                    work = (
                        self.workloads.get(c.name) if self.workloads else None
                    ) or self._default_workload(ctx)
                    work()  # warmup: compile/dispatch cost is not step cost
                    times = []
                    for _ in range(self.reps):
                        t0 = time.perf_counter()
                        work()
                        times.append(time.perf_counter() - t0)
                times.sort()
                sec = times[len(times) // 2]
                out.append(sec / rows)
                self.last_measured[c.name] = sec
                _ROW_SECONDS.labels(device_class=c.name).set(sec / rows)
        self._row_seconds = out
        self.refreshes += 1
        _REFRESHES.inc()
        T.instant(
            "probe.measured", cat="probe",
            row_seconds={c.name: out[i] for i, c in enumerate(self.asym.classes)},
        )
        return out

    def __call__(
        self, step: int, pod_units: Optional[Sequence[int]] = None
    ) -> Optional[list[float]]:
        """Per-pod seconds for this step, or ``None`` while inactive.

        ``pod_units`` is the per-pod active unit count the engine ran
        (rows / slots); omitted, each pod is charged one unit.
        """

        if not self.active():
            return None
        if self._row_seconds is None or step % self.interval == 0:
            self.refresh()
        if pod_units is None:
            pod_units = [1] * len(self._pod_class)
        return [
            float(u) * self._row_seconds[self._pod_class[pod]]
            for pod, u in enumerate(pod_units)
        ]


__all__ = ["StepTimeProbe"]

"""Trace report CLI: summarize a trace file, export Chrome trace JSON.

Reads either the native buffer format (``TraceBuffer.save``) or an
already-exported Chrome ``traceEvents`` file and prints a per-name
summary (count, total/mean/max duration) plus a per-device-class
rollup of the spans that carry scheduling provenance.

Robust to damaged inputs by design: the post-mortem tool for a killed
engine must not die of the kill itself.  A truncated or corrupt trace
file is *salvaged* — every record that still parses is kept, bad ones
are skipped and counted (``skipped_records`` in the meta, a WARNING in
the CLI header) — instead of crashing on the first bad byte.

Usage::

    python -m repro.observability.report trace.json [--chrome out.json]
                                                    [--top N]
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Optional, Sequence

from repro.util.atomic import atomic_write_json


def _salvage_events(text: str) -> tuple[list[dict], int]:
    """Recover parseable event objects from a damaged trace file.

    Scans the region after the first ``"events"``/``"traceEvents"`` key
    (or the whole text when neither survives), decoding one JSON object
    at a time; anything that fails to parse is skipped to the next ``{``
    and counted.  Lossy by nature — the point is that a truncated tail
    (killed engine, full disk) costs only the torn record, not the run's
    whole trace.
    """

    m = re.search(r'"(?:traceEvents|events)"\s*:\s*\[', text)
    pos = m.end() if m else 0
    dec = json.JSONDecoder()
    events: list[dict] = []
    skipped = 0
    while True:
        nxt = text.find("{", pos)
        if nxt < 0:
            break
        # A '{' at depth 0 here is an event candidate; on decode failure
        # count it and resume after the brace.
        try:
            obj, end = dec.raw_decode(text, nxt)
        except json.JSONDecodeError:
            skipped += 1
            pos = nxt + 1
            continue
        if isinstance(obj, dict):
            events.append(obj)
        else:
            skipped += 1
        pos = end
    return events, skipped


def load_events(path: str) -> tuple[list[dict], dict]:
    """Normalize either trace format to native-style event dicts
    (``ts``/``dur`` in seconds); returns ``(events, meta)``.

    Corrupt or truncated files degrade to a salvage scan: bad records
    are skipped, and their count lands in ``meta["skipped_records"]``
    (0 when the file parsed cleanly).
    """

    with open(path) as f:
        text = f.read()
    skipped = 0
    try:
        data = json.loads(text)
    except json.JSONDecodeError:
        chrome = '"traceEvents"' in text
        raw, skipped = _salvage_events(text)
        skipped = max(skipped, 1)  # the torn tail itself counts
        if chrome:
            data = {"traceEvents": raw}
        else:
            data = {"events": raw}
    if isinstance(data, dict) and "traceEvents" in data:
        events = []
        for ev in data["traceEvents"]:
            if not isinstance(ev, dict):
                skipped += 1
                continue
            events.append({
                "name": ev.get("name", "?"),
                "cat": ev.get("cat", "span"),
                "ph": ev.get("ph", "X"),
                "ts": float(ev.get("ts", 0.0)) / 1e6,
                "dur": float(ev.get("dur", 0.0)) / 1e6,
                "tid": ev.get("tid", 0),
                "parent": (ev.get("args") or {}).get("parent"),
                "args": ev.get("args") or {},
            })
        meta = {"format": "chrome", **(data.get("otherData") or {})}
        meta["skipped_records"] = skipped
        return events, meta
    if isinstance(data, dict) and "events" in data:
        meta = {k: v for k, v in data.items() if k != "events"}
        events = []
        for ev in data["events"]:
            if isinstance(ev, dict):
                events.append(ev)
            else:
                skipped += 1
        meta = {"format": "native", **meta}
        meta["skipped_records"] = skipped
        return events, meta
    raise ValueError(f"{path}: neither a native trace nor a Chrome trace")


def summarize(events: list[dict], *, top: int = 20) -> str:
    spans = [e for e in events if e.get("ph") == "X"]
    instants = [e for e in events if e.get("ph") == "i"]

    by_name: dict[str, list[float]] = {}
    for e in spans:
        by_name.setdefault(e.get("name", "?"), []).append(
            float(e.get("dur", 0.0))
        )
    by_class: dict[str, list[float]] = {}
    for e in spans:
        dc = (e.get("args") or {}).get("device_class")
        if dc:
            by_class.setdefault(str(dc), []).append(float(e.get("dur", 0.0)))

    lines = [
        f"{len(events)} events ({len(spans)} spans, {len(instants)} instants)",
        "",
        f"{'span':<32}{'count':>8}{'total_ms':>12}{'mean_ms':>10}{'max_ms':>10}",
    ]
    ranked = sorted(by_name.items(), key=lambda kv: -sum(kv[1]))
    for name, durs in ranked[:top]:
        total = sum(durs)
        lines.append(
            f"{name:<32}{len(durs):>8}{total * 1e3:>12.2f}"
            f"{total / len(durs) * 1e3:>10.3f}{max(durs) * 1e3:>10.3f}"
        )
    if len(ranked) > top:
        lines.append(f"... {len(ranked) - top} more span names (--top to widen)")

    if by_class:
        lines += ["", f"{'device_class':<32}{'spans':>8}{'total_ms':>12}"]
        for dc, durs in sorted(by_class.items()):
            lines.append(f"{dc:<32}{len(durs):>8}{sum(durs) * 1e3:>12.2f}")

    if instants:
        counts: dict[str, int] = {}
        for e in instants:
            name = e.get("name", "?")
            counts[name] = counts.get(name, 0) + 1
        lines += ["", "instants: " + ", ".join(
            f"{n}×{c}" for n, c in sorted(counts.items())
        )]

    kv = kv_pool_rollup(instants)
    if kv is not None:
        lines += ["", (
            "kv page pool: peak {peak_live_pages} pages live "
            "({allocs} allocs / {frees} frees, {pages_allocated} pages in / "
            "{pages_freed} out, final live {final_live_pages})"
        ).format(**kv)]
    return "\n".join(lines)


def kv_pool_rollup(instants: list[dict]) -> Optional[dict]:
    """Peak page occupancy from ``engine.page_alloc``/``engine.page_free``.

    Each instant carries the pool's ``pages_live`` *after* the event, so
    the peak over the stream is the pool's true high-water mark (matching
    ``PagePool.peak_live`` when the trace covers the engine's lifetime).
    Returns None when the trace has no page events.
    """

    allocs = [e for e in instants if e.get("name") == "engine.page_alloc"]
    frees = [e for e in instants if e.get("name") == "engine.page_free"]
    if not allocs and not frees:
        return None
    events = sorted(allocs + frees, key=lambda e: float(e.get("ts", 0.0)))
    live = [int((e.get("args") or {}).get("pages_live", 0)) for e in events]
    return {
        "allocs": len(allocs),
        "frees": len(frees),
        "pages_allocated": sum(
            int((e.get("args") or {}).get("pages", 0)) for e in allocs),
        "pages_freed": sum(
            int((e.get("args") or {}).get("pages", 0)) for e in frees),
        "peak_live_pages": max(live) if live else 0,
        "final_live_pages": live[-1] if live else 0,
    }


def export_chrome(events: list[dict], path: str) -> str:
    import os

    out = []
    for e in events:
        rec = {
            "name": e.get("name", "?"),
            "cat": e.get("cat", "span"),
            "ph": e.get("ph", "X"),
            "ts": round(max(float(e.get("ts", 0.0)), 0.0) * 1e6, 3),
            "pid": os.getpid(),
            "tid": e.get("tid", 0),
            "args": dict(e.get("args") or {}),
        }
        if rec["ph"] == "X":
            rec["dur"] = round(float(e.get("dur", 0.0)) * 1e6, 3)
        if rec["ph"] == "i":
            rec["s"] = "t"
        if e.get("parent"):
            rec["args"]["parent"] = e["parent"]
        out.append(rec)
    return atomic_write_json(
        path, {"traceEvents": out, "displayTimeUnit": "ms"},
        indent=1, sort_keys=False, default=str,
    )


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability.report",
        description="Summarize a repro trace file; optionally export Chrome trace.",
    )
    ap.add_argument("trace", help="native trace (TraceBuffer.save) or Chrome JSON")
    ap.add_argument("--chrome", default=None,
                    help="write a Chrome traceEvents JSON here")
    ap.add_argument("--top", type=int, default=20,
                    help="span names to show in the duration table")
    args = ap.parse_args(argv)

    events, meta = load_events(args.trace)
    dropped = meta.get("dropped", 0)
    head = f"{args.trace} [{meta.get('format')}]"
    if dropped:
        head += f" — WARNING: {dropped} events dropped (buffer capacity)"
    skipped = meta.get("skipped_records", 0)
    if skipped:
        head += (
            f" — WARNING: {skipped} corrupt/truncated records skipped"
        )
    print(head)
    print(summarize(events, top=args.top))
    if args.chrome:
        print(f"wrote Chrome trace to {export_chrome(events, args.chrome)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

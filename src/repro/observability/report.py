"""Trace report CLI: summarize a trace file, export Chrome trace JSON.

Reads either the native buffer format (``TraceBuffer.save``) or an
already-exported Chrome ``traceEvents`` file and prints a per-name
summary (count, total/mean/max duration) plus a per-device-class
rollup of the spans that carry scheduling provenance.

Usage::

    python -m repro.observability.report trace.json [--chrome out.json]
                                                    [--top N]
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence


def load_events(path: str) -> tuple[list[dict], dict]:
    """Normalize either trace format to native-style event dicts
    (``ts``/``dur`` in seconds); returns ``(events, meta)``."""

    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict) and "traceEvents" in data:
        events = []
        for ev in data["traceEvents"]:
            events.append({
                "name": ev.get("name", "?"),
                "cat": ev.get("cat", "span"),
                "ph": ev.get("ph", "X"),
                "ts": float(ev.get("ts", 0.0)) / 1e6,
                "dur": float(ev.get("dur", 0.0)) / 1e6,
                "tid": ev.get("tid", 0),
                "parent": (ev.get("args") or {}).get("parent"),
                "args": ev.get("args") or {},
            })
        return events, {"format": "chrome", **(data.get("otherData") or {})}
    if isinstance(data, dict) and "events" in data:
        meta = {k: v for k, v in data.items() if k != "events"}
        return list(data["events"]), {"format": "native", **meta}
    raise ValueError(f"{path}: neither a native trace nor a Chrome trace")


def summarize(events: list[dict], *, top: int = 20) -> str:
    spans = [e for e in events if e.get("ph") == "X"]
    instants = [e for e in events if e.get("ph") == "i"]

    by_name: dict[str, list[float]] = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(float(e.get("dur", 0.0)))
    by_class: dict[str, list[float]] = {}
    for e in spans:
        dc = (e.get("args") or {}).get("device_class")
        if dc:
            by_class.setdefault(str(dc), []).append(float(e.get("dur", 0.0)))

    lines = [
        f"{len(events)} events ({len(spans)} spans, {len(instants)} instants)",
        "",
        f"{'span':<32}{'count':>8}{'total_ms':>12}{'mean_ms':>10}{'max_ms':>10}",
    ]
    ranked = sorted(by_name.items(), key=lambda kv: -sum(kv[1]))
    for name, durs in ranked[:top]:
        total = sum(durs)
        lines.append(
            f"{name:<32}{len(durs):>8}{total * 1e3:>12.2f}"
            f"{total / len(durs) * 1e3:>10.3f}{max(durs) * 1e3:>10.3f}"
        )
    if len(ranked) > top:
        lines.append(f"... {len(ranked) - top} more span names (--top to widen)")

    if by_class:
        lines += ["", f"{'device_class':<32}{'spans':>8}{'total_ms':>12}"]
        for dc, durs in sorted(by_class.items()):
            lines.append(f"{dc:<32}{len(durs):>8}{sum(durs) * 1e3:>12.2f}")

    if instants:
        counts: dict[str, int] = {}
        for e in instants:
            counts[e["name"]] = counts.get(e["name"], 0) + 1
        lines += ["", "instants: " + ", ".join(
            f"{n}×{c}" for n, c in sorted(counts.items())
        )]

    kv = kv_pool_rollup(instants)
    if kv is not None:
        lines += ["", (
            "kv page pool: peak {peak_live_pages} pages live "
            "({allocs} allocs / {frees} frees, {pages_allocated} pages in / "
            "{pages_freed} out, final live {final_live_pages})"
        ).format(**kv)]
    return "\n".join(lines)


def kv_pool_rollup(instants: list[dict]) -> Optional[dict]:
    """Peak page occupancy from ``engine.page_alloc``/``engine.page_free``.

    Each instant carries the pool's ``pages_live`` *after* the event, so
    the peak over the stream is the pool's true high-water mark (matching
    ``PagePool.peak_live`` when the trace covers the engine's lifetime).
    Returns None when the trace has no page events.
    """

    allocs = [e for e in instants if e.get("name") == "engine.page_alloc"]
    frees = [e for e in instants if e.get("name") == "engine.page_free"]
    if not allocs and not frees:
        return None
    events = sorted(allocs + frees, key=lambda e: float(e.get("ts", 0.0)))
    live = [int((e.get("args") or {}).get("pages_live", 0)) for e in events]
    return {
        "allocs": len(allocs),
        "frees": len(frees),
        "pages_allocated": sum(
            int((e.get("args") or {}).get("pages", 0)) for e in allocs),
        "pages_freed": sum(
            int((e.get("args") or {}).get("pages", 0)) for e in frees),
        "peak_live_pages": max(live) if live else 0,
        "final_live_pages": live[-1] if live else 0,
    }


def export_chrome(events: list[dict], path: str) -> str:
    import os

    out = []
    for e in events:
        rec = {
            "name": e.get("name", "?"),
            "cat": e.get("cat", "span"),
            "ph": e.get("ph", "X"),
            "ts": round(max(float(e.get("ts", 0.0)), 0.0) * 1e6, 3),
            "pid": os.getpid(),
            "tid": e.get("tid", 0),
            "args": dict(e.get("args") or {}),
        }
        if rec["ph"] == "X":
            rec["dur"] = round(float(e.get("dur", 0.0)) * 1e6, 3)
        if rec["ph"] == "i":
            rec["s"] = "t"
        if e.get("parent"):
            rec["args"]["parent"] = e["parent"]
        out.append(rec)
    with open(path, "w") as f:
        json.dump({"traceEvents": out, "displayTimeUnit": "ms"}, f,
                  indent=1, default=str)
        f.write("\n")
    return path


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability.report",
        description="Summarize a repro trace file; optionally export Chrome trace.",
    )
    ap.add_argument("trace", help="native trace (TraceBuffer.save) or Chrome JSON")
    ap.add_argument("--chrome", default=None,
                    help="write a Chrome traceEvents JSON here")
    ap.add_argument("--top", type=int, default=20,
                    help="span names to show in the duration table")
    args = ap.parse_args(argv)

    events, meta = load_events(args.trace)
    dropped = meta.get("dropped", 0)
    head = f"{args.trace} [{meta.get('format')}]"
    if dropped:
        head += f" — WARNING: {dropped} events dropped (buffer capacity)"
    print(head)
    print(summarize(events, top=args.top))
    if args.chrome:
        print(f"wrote Chrome trace to {export_chrome(events, args.chrome)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

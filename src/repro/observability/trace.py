"""Trace spans over a bounded in-memory buffer, Chrome-trace exportable.

The span API mirrors :class:`repro.core.execution.ExecutionContext`'s
contextvar discipline: the active-span stack lives in a ``ContextVar``
holding an immutable tuple, so concurrent threads (each thread starts
from the default empty stack) and interleaved asyncio tasks (each task
runs in a copied context) nest and restore independently, and ``with``
semantics make exit exception-safe (a failing span is recorded with its
error class rather than leaked).

Recording is cheap and lock-bounded: events append to a fixed-capacity
deque (oldest events drop, counted in ``dropped``) and nothing here
imports jax or numpy — the disabled fast path is a single module-global
``None`` check, which is what lets hot loops call :func:`complete`
unconditionally.

Two export formats:

  * :meth:`TraceBuffer.save` — the native ``{"version", "events"}`` JSON
    the ``python -m repro.observability.report`` CLI summarizes,
  * :meth:`TraceBuffer.chrome_trace` — the Chrome ``traceEvents`` JSON
    (load in ``chrome://tracing`` or Perfetto); complete spans nest by
    time containment per thread, instants render as marks, counters as
    tracks.

Span ``args`` carry the scheduling provenance the repo's assertions
already speak: ``device_class``, ``backend``, ``block_source``.
"""

from __future__ import annotations

import collections
import contextvars
import dataclasses
import os
import threading
import time
from typing import Any, Optional

from repro.util.atomic import atomic_write_json

DEFAULT_CAPACITY = 65536


@dataclasses.dataclass
class TraceEvent:
    """One recorded event; ``ts``/``dur`` are seconds on the buffer's
    ``perf_counter`` clock, relative to the buffer's epoch."""

    name: str
    cat: str
    ph: str                      # "X" complete | "i" instant | "C" counter
    ts: float
    dur: float
    tid: int
    parent: Optional[str]
    args: dict


class TraceBuffer:
    """Bounded, thread-safe event sink (oldest events evict, counted)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.epoch = time.perf_counter()
        self.dropped = 0
        self._events: collections.deque = collections.deque(maxlen=self.capacity)
        self._lock = threading.Lock()

    def add(self, ev: TraceEvent) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    @property
    def events(self) -> list[TraceEvent]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    # -- export -----------------------------------------------------------

    def to_dict(self) -> dict:
        """Native format: everything the report CLI needs, lossless."""

        return {
            "version": 1,
            "clock": "perf_counter",
            "capacity": self.capacity,
            "dropped": self.dropped,
            "events": [dataclasses.asdict(ev) for ev in self.events],
        }

    def save(self, path: str) -> str:
        # Atomic + durable (shared helper): a crash mid-save — which is
        # exactly when a trace matters most — must never leave a torn
        # file for the post-mortem report to choke on.
        return atomic_write_json(
            path, self.to_dict(), indent=1, sort_keys=True, default=str
        )

    def chrome_trace(self) -> dict:
        """Chrome ``traceEvents`` JSON (times in microseconds)."""

        pid = os.getpid()
        out = []
        for ev in self.events:
            rec: dict[str, Any] = {
                "name": ev.name,
                "cat": ev.cat,
                "ph": ev.ph,
                "ts": round(max(ev.ts, 0.0) * 1e6, 3),
                "pid": pid,
                "tid": ev.tid,
                "args": dict(ev.args),
            }
            if ev.ph == "X":
                rec["dur"] = round(ev.dur * 1e6, 3)
            if ev.ph == "i":
                rec["s"] = "t"  # thread-scoped instant mark
            if ev.parent:
                rec["args"]["parent"] = ev.parent
            out.append(rec)
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"clock": "perf_counter", "dropped": self.dropped},
        }

    def export_chrome_trace(self, path: str) -> str:
        return atomic_write_json(
            path, self.chrome_trace(), indent=1, sort_keys=False, default=str
        )


# -- module state (the one switch) ------------------------------------------

_BUFFER: Optional[TraceBuffer] = None

# Active-span stack: immutable tuple in a ContextVar, exactly the token
# discipline of ExecutionContext — per-thread defaults and per-task
# context copies give threads and asyncio tasks independent stacks.
_STACK: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_trace_spans", default=()
)


def enable(capacity: int = DEFAULT_CAPACITY) -> TraceBuffer:
    """Turn tracing on (idempotent: an existing buffer is kept)."""

    global _BUFFER
    if _BUFFER is None:
        _BUFFER = TraceBuffer(capacity)
    return _BUFFER


def disable() -> Optional[TraceBuffer]:
    """Turn tracing off; returns the detached buffer (for export)."""

    global _BUFFER
    buf, _BUFFER = _BUFFER, None
    return buf


def enabled() -> bool:
    return _BUFFER is not None


def get_buffer() -> Optional[TraceBuffer]:
    return _BUFFER


# -- recording ---------------------------------------------------------------


def complete(name: str, t0: float, dur: float, *, cat: str = "span", **args) -> None:
    """Record an already-measured interval (``t0`` = ``perf_counter`` at
    start).  The hot-loop API: callers that already time themselves
    (engine step, trainer step) record post hoc with zero control-flow
    change; disabled cost is this ``None`` check."""

    buf = _BUFFER
    if buf is None:
        return
    stack = _STACK.get()
    buf.add(
        TraceEvent(
            name=name,
            cat=cat,
            ph="X",
            ts=t0 - buf.epoch,
            dur=dur,
            tid=threading.get_ident(),
            parent=stack[-1].name if stack else None,
            args=args,
        )
    )


def instant(name: str, *, cat: str = "span", **args) -> None:
    """Record a point event (e.g. a rebalance) if tracing is on."""

    buf = _BUFFER
    if buf is None:
        return
    stack = _STACK.get()
    buf.add(
        TraceEvent(
            name=name,
            cat=cat,
            ph="i",
            ts=time.perf_counter() - buf.epoch,
            dur=0.0,
            tid=threading.get_ident(),
            parent=stack[-1].name if stack else None,
            args=args,
        )
    )


def counter(name: str, *, cat: str = "metric", **values) -> None:
    """Record a Chrome counter-track sample (numeric values only)."""

    buf = _BUFFER
    if buf is None:
        return
    buf.add(
        TraceEvent(
            name=name,
            cat=cat,
            ph="C",
            ts=time.perf_counter() - buf.epoch,
            dur=0.0,
            tid=threading.get_ident(),
            parent=None,
            args=values,
        )
    )


class _NoopSpan:
    """Returned by :func:`span` while tracing is off: zero state, reusable."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def tag(self, **kw):
        return self


_NOOP = _NoopSpan()


class Span:
    """One timed region; create via :func:`span`, use as a context manager.

    Entering pushes onto the contextvar stack (so children see their
    parent); exiting pops, measures the duration, and records — tagged
    with the exception class if the body raised.  A span object is
    single-use.
    """

    __slots__ = ("name", "cat", "args", "_t0")

    def __init__(self, name: str, cat: str, args: dict):
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def tag(self, **kw) -> "Span":
        """Attach tags after creation (e.g. results known mid-span)."""

        self.args.update(kw)
        return self

    def __enter__(self) -> "Span":
        _STACK.set(_STACK.get() + (self,))
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur = time.perf_counter() - self._t0
        stack = _STACK.get()
        if stack and stack[-1] is self:
            _STACK.set(stack[:-1])
        else:  # misnested exit: drop self wherever it sits, keep the rest
            _STACK.set(tuple(s for s in stack if s is not self))
        buf = _BUFFER
        if buf is not None:
            args = dict(self.args)
            if exc_type is not None:
                args["error"] = exc_type.__name__
            outer = _STACK.get()
            buf.add(
                TraceEvent(
                    name=self.name,
                    cat=self.cat,
                    ph="X",
                    ts=self._t0 - buf.epoch,
                    dur=dur,
                    tid=threading.get_ident(),
                    parent=outer[-1].name if outer else None,
                    args=args,
                )
            )
        return False


def span(name: str, *, cat: str = "span", **args):
    """A context manager timing its body (no-op while tracing is off)."""

    if _BUFFER is None:
        return _NOOP
    return Span(name, cat, args)


def current_span() -> Optional[Span]:
    """The innermost active span of this thread/task, if any."""

    stack = _STACK.get()
    return stack[-1] if stack else None


__all__ = [
    "DEFAULT_CAPACITY",
    "TraceEvent",
    "TraceBuffer",
    "Span",
    "enable",
    "disable",
    "enabled",
    "get_buffer",
    "span",
    "complete",
    "instant",
    "counter",
    "current_span",
]

"""Telemetry for the asymmetric-scheduling stack: spans, metrics, probe.

Three surfaces, one switch:

  * :mod:`repro.observability.trace` — contextvar-nested spans over a
    bounded in-memory event buffer, exported as Chrome-trace/Perfetto
    JSON.  Spans carry the scheduling provenance the rest of the repo
    already proves (device class, backend variant, ``block_source``).
  * :mod:`repro.observability.metrics` — a registry of labeled
    counters/gauges/histograms with Prometheus text exposition and a
    JSON snapshot.
  * :mod:`repro.observability.probe` — the measured per-pod step-time
    probe that plugs into ``ServingEngine(pod_time_hook=...)`` and
    closes the paper's DAS calibration loop (§5.2.2/§5.4) on real
    timings instead of fabricated ones.

**Off is free.**  Everything here is disabled by default; the disabled
path is a single ``None`` check per instrumentation site.  Nothing in
this package imports jax, instrumentation never alters a jitted program
(events are recorded around already-measured wall times), and the
default engine probe returns ``None`` (frozen calibration, zero work)
while observability is off — the contract the ``bench_serving`` gate
enforces.

Enable with :func:`enable` (or ``repro.launch.serve --trace/--metrics``)
and summarize with ``python -m repro.observability.report``.
"""

from repro.observability import metrics  # noqa: F401
from repro.observability.metrics import REGISTRY  # noqa: F401
from repro.observability.trace import (  # noqa: F401
    disable,
    enable,
    enabled,
    get_buffer,
)

__all__ = ["enable", "disable", "enabled", "get_buffer", "metrics", "REGISTRY"]

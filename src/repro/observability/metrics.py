"""Labeled counters/gauges/histograms with Prometheus text exposition.

A deliberately small, dependency-free registry (no prometheus_client in
the image, and the scrape side of a fleet only needs the text format):

  * families are registered once by name (re-registration with the same
    kind/labels returns the existing family — instrumented modules can
    declare their metrics idempotently at call sites),
  * ``family.labels(k=v)`` materializes one child per label-value tuple,
  * :meth:`MetricsRegistry.exposition` renders the Prometheus text
    format (``# HELP``/``# TYPE``, escaped label values, histogram
    ``_bucket``/``_sum``/``_count`` with cumulative ``le`` buckets),
  * :meth:`MetricsRegistry.snapshot` returns the same state as a
    JSON-serializable dict keyed by metric name (what ``launch/serve.py
    --metrics`` writes and the CI smoke greps).

Updates are float arithmetic under one registry lock — host-side and
cheap relative to anything this repo times — but instrumentation sites
in hot loops still gate on ``trace.enabled()`` so the observability-off
path stays free.
"""

from __future__ import annotations

import re
import threading
from typing import Optional, Sequence

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Step/candidate wall times land between ~100µs (tiny CPU probe GEMMs)
# and tens of seconds (compiles); the default grid covers that span.
DEFAULT_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _labels_str(names: Sequence[str], values: Sequence[str], extra: str = "") -> str:
    parts = [f'{n}="{_escape_label(str(v))}"' for n, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got {amount}")
        self.value += amount


class _Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class _Histogram:
    __slots__ = ("uppers", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float]):
        self.uppers = tuple(sorted(float(b) for b in buckets)) + (float("inf"),)
        self.counts = [0] * len(self.uppers)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, ub in enumerate(self.uppers):
            if v <= ub:
                self.counts[i] += 1
                break

    def cumulative(self) -> list[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out


class MetricFamily:
    """One named metric and its per-label-value children."""

    def __init__(self, registry: "MetricsRegistry", kind: str, name: str,
                 help: str, label_names: tuple[str, ...],
                 buckets: Optional[Sequence[float]] = None):
        self.kind = kind
        self.name = name
        self.help = help
        self.label_names = label_names
        self._buckets = tuple(buckets) if buckets is not None else DEFAULT_BUCKETS
        self._registry = registry
        self._children: dict[tuple, object] = {}

    def _make_child(self):
        if self.kind == "counter":
            return _Counter()
        if self.kind == "gauge":
            return _Gauge()
        return _Histogram(self._buckets)

    def labels(self, **kv):
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, got {sorted(kv)}"
            )
        key = tuple(str(kv[n]) for n in self.label_names)
        with self._registry._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make_child()
        return child

    def _default(self):
        if self.label_names:
            raise ValueError(f"{self.name} is labeled; call .labels(...) first")
        return self.labels()

    # Unlabeled convenience: family acts as its own single child.
    def inc(self, amount: float = 1.0):
        self._default().inc(amount)

    def set(self, v: float):
        self._default().set(v)

    def dec(self, amount: float = 1.0):
        self._default().dec(amount)

    def observe(self, v: float):
        self._default().observe(v)

    def samples(self) -> list[tuple[tuple, object]]:
        with self._registry._lock:
            return sorted(self._children.items())


class MetricsRegistry:
    def __init__(self):
        self._families: dict[str, MetricFamily] = {}
        self._lock = threading.Lock()

    def _register(self, kind: str, name: str, help: str,
                  labels: Sequence[str], buckets=None) -> MetricFamily:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        label_names = tuple(labels)
        for ln in label_names:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.label_names != label_names:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}"
                        f"{fam.label_names}, cannot re-register as {kind}{label_names}"
                    )
                return fam
            fam = MetricFamily(self, kind, name, help, label_names, buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels: Sequence[str] = ()) -> MetricFamily:
        return self._register("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", labels: Sequence[str] = ()) -> MetricFamily:
        return self._register("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "", labels: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> MetricFamily:
        return self._register("histogram", name, help, labels, buckets)

    def reset(self) -> None:
        """Drop all families (tests)."""

        with self._lock:
            self._families.clear()

    # -- output -----------------------------------------------------------

    def exposition(self) -> str:
        """Prometheus text exposition format (0.0.4)."""

        lines = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key, child in fam.samples():
                if fam.kind == "histogram":
                    cum = child.cumulative()
                    for ub, c in zip(child.uppers, cum):
                        le = f'le="{_fmt(ub)}"'
                        lines.append(
                            f"{name}_bucket"
                            f"{_labels_str(fam.label_names, key, le)} {c}"
                        )
                    ls = _labels_str(fam.label_names, key)
                    lines.append(f"{name}_sum{ls} {_fmt(child.sum)}")
                    lines.append(f"{name}_count{ls} {child.count}")
                else:
                    ls = _labels_str(fam.label_names, key)
                    lines.append(f"{name}{ls} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-serializable state, keyed by metric name."""

        out = {}
        for name in sorted(self._families):
            fam = self._families[name]
            samples = []
            for key, child in fam.samples():
                labels = dict(zip(fam.label_names, key))
                if fam.kind == "histogram":
                    samples.append({
                        "labels": labels,
                        "count": child.count,
                        "sum": child.sum,
                        "buckets": {
                            _fmt(ub): c
                            for ub, c in zip(child.uppers, child.cumulative())
                        },
                    })
                else:
                    samples.append({"labels": labels, "value": child.value})
            out[name] = {"kind": fam.kind, "help": fam.help, "samples": samples}
        return out


# The process-wide registry every instrumented module shares.
REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "", labels: Sequence[str] = ()) -> MetricFamily:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: Sequence[str] = ()) -> MetricFamily:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: Sequence[str] = (),
              buckets: Optional[Sequence[float]] = None) -> MetricFamily:
    return REGISTRY.histogram(name, help, labels, buckets)


__all__ = [
    "DEFAULT_BUCKETS",
    "MetricFamily",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
]

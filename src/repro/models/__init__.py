"""Model zoo: dense / MoE / SSM / hybrid decoders + enc-dec, scan-over-layers."""

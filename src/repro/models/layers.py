"""Shared neural-net layers (pure-functional, explicit param pytrees).

Conventions:
  * params are stored fp32 (master weights); compute casts to bf16 at the
    point of use (mixed-precision policy),
  * normalizations and softmax run in fp32,
  * every dense projection routes through :func:`repro.kernels.ops.gemm`
    so the paper's control-tree block configuration governs the hot loops,
  * attention is *chunked over queries* (scores never materialize more than
    ``q_chunk × S_k``), which together with layer remat bounds activation
    memory — see EXPERIMENTS.md §Perf for the measured effect.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale: Optional[float] = None, dtype=PARAM_DTYPE):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=PARAM_DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (half-rotation / LLaMA convention)
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: (..., S) int32."""

    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def chunked_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    scale: Optional[float] = None,
):
    """GQA-native attention, chunked over queries (scores ≤ q_chunk × S_k).

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) with Hq % Hkv == 0.  Grouped
    einsums keep the KV-head dim explicit — repeating KV heads materializes
    a G×-larger tensor and (sharded) triggers involuntary SPMD
    rematerialization, measured at +115 GiB/device on mixtral decode
    (EXPERIMENTS.md §Perf).  The q-offset convention assumes queries are
    the *suffix* of the key sequence.
    """

    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    q_chunk = min(q_chunk, sq)
    pad = (-sq) % q_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    n_chunks = qp.shape[1] // q_chunk

    kT = k.transpose(0, 2, 3, 1).astype(COMPUTE_DTYPE)  # (B,Hkv,D,Sk)
    vT = v.transpose(0, 2, 1, 3).astype(COMPUTE_DTYPE)  # (B,Hkv,Sk,D)

    # Sliding-window block skipping (paper-style iteration-space
    # restriction): a q-chunk can only attend to the trailing
    # ``q_chunk + window`` keys, so slice K/V instead of masking the full
    # row — an Sk/(q_chunk+window) FLOP and score-traffic reduction
    # (8.6× on mixtral prefill_32k; EXPERIMENTS.md §Perf B).
    span = sk
    if window is not None and causal:
        span = min(sk, q_chunk + window)

    def one_chunk(i):
        qc = jax.lax.dynamic_slice_in_dim(qp, i * q_chunk, q_chunk, axis=1)
        qc = qc.reshape(b, q_chunk, hkv, g, d).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,qc,D)
        qc = qc.astype(COMPUTE_DTYPE)
        q_idx = (sk - sq) + i * q_chunk + jnp.arange(q_chunk)
        if span < sk:
            start = jnp.clip((sk - sq) + i * q_chunk + q_chunk - span, 0, sk - span)
            kc = jax.lax.dynamic_slice_in_dim(kT, start, span, axis=3)
            vc = jax.lax.dynamic_slice_in_dim(vT, start, span, axis=2)
            k_idx = start + jnp.arange(span)
        else:
            kc, vc = kT, vT
            k_idx = jnp.arange(sk)
        s = jnp.einsum("bhgqd,bhds->bhgqs", qc, kc, preferred_element_type=jnp.float32)
        s = s * scale
        mask = jnp.ones((q_chunk, span), bool)
        if causal:
            mask &= q_idx[:, None] >= k_idx[None, :]
        if window is not None:
            mask &= (q_idx[:, None] - k_idx[None, :]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
        o = jnp.einsum("bhgqs,bhsd->bhgqd", p, vc, preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))  # (n,B,Hkv,G,qc,D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, n_chunks * q_chunk, hq, d)
    return out[:, :sq]


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None      # sliding-window attention (Mixtral)
    causal: bool = True
    use_rope: bool = True


def init_attention(key, cfg: AttnConfig):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads * cfg.d_head)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
        "wo": dense_init(ks[3], (cfg.n_heads * cfg.d_head, cfg.d_model)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), PARAM_DTYPE)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), PARAM_DTYPE)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), PARAM_DTYPE)
    return p


def _qkv(p, x, cfg: AttnConfig, positions):
    b, s, _ = x.shape
    c = lambda w: w.astype(COMPUTE_DTYPE)
    q = ops.linear(x, c(p["wq"]), p.get("bq"))
    k = ops.linear(x, c(p["wk"]), p.get("bk"))
    v = ops.linear(x, c(p["wv"]), p.get("bv"))
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attention(p, x, cfg: AttnConfig, *, positions=None):
    """Full-sequence attention (training / prefill). x: (B,S,D)."""

    from repro.distributed.sharding import constrain_qkv_context_parallel

    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    q, k, v = constrain_qkv_context_parallel(q, k, v, cfg.n_heads)
    o = chunked_attention(q, k, v, causal=cfg.causal, window=cfg.window)
    o = o.reshape(b, s, cfg.n_heads * cfg.d_head)
    return ops.linear(o, p["wo"].astype(COMPUTE_DTYPE)), (k, v)


def decode_attention(p, x, cfg: AttnConfig, cache_k, cache_v, pos, *, live=None):
    """Single-token decode against a (ring or linear) KV cache.

    x: (B, 1, D); cache_k/v: (B, S_cache, Hkv, Dh); pos: int32 — the
    absolute position of the new token.  Either a scalar (same position
    across the batch, static batching) or a ``(B,)`` vector of *per-row*
    positions (the serving engine's slot table, where every slot ages
    independently).  With a sliding window the cache is a ring buffer of
    size ``window`` and ``pos`` indexes modulo the window.

    The two forms are value-identical when the vector is constant: the
    per-row cache write places the same bits at the same slot, and the
    validity mask broadcasts to the same elements — the engine's
    vector-position step is bit-identical to the scalar-position path
    (asserted in tests/test_serving.py).  A vector position past the cache
    length simply writes nothing (the one-hot hits no slot), so retired
    slots can keep aging harmlessly until they are re-admitted.  (The
    scalar path's ``dynamic_update_slice`` *clamps* instead of dropping —
    scalar callers never run phantom lanes, so the distinction is moot
    there.)

    ``live`` (optional, ``(B,)`` bool) marks rows whose attention output
    is real; dead rows (retired-but-not-refreshed phantom lanes) have
    their attention output zeroed before the output projection so their
    row content is engine-defined (identical between the dense and paged
    engines) rather than whatever their stale cache produces.  Masked
    lanes never influence *other* rows either way — every op here is
    row-local — so ``live=None`` keeps the historical output bit-for-bit.
    """

    b = x.shape[0]
    s_cache = cache_k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim > 0  # (B,) per-row absolute positions
    positions = pos[:, None] if per_slot else jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)

    slot = pos % s_cache if cfg.window is not None else pos
    if per_slot:
        # Per-row scatter: O(B·Hkv·Dh) written, aliasable in place under
        # donation (a broadcast-select would rewrite the whole cache every
        # token).  mode="drop" skips rows whose position is past the cache
        # length — the retired phantom lanes write nothing.
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, slot].set(
            k[:, 0].astype(cache_k.dtype), mode="drop"
        )
        cache_v = cache_v.at[rows, slot].set(
            v[:, 0].astype(cache_v.dtype), mode="drop"
        )
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)

    # GQA-native grouped einsum over the raw cache — no KV repetition.
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.d_head).astype(COMPUTE_DTYPE)
    s = jnp.einsum(
        "bqhgd,bshd->bhgqs", qg, cache_k.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    ) / np.sqrt(cfg.d_head)
    k_idx = jnp.arange(s_cache)
    if per_slot:
        if cfg.window is not None:
            # ring buffer: all slots valid once wrapped
            valid = (k_idx[None, :] <= slot[:, None]) | (pos[:, None] >= s_cache)
        else:
            # Clamp before comparing: a phantom lane aged past the cache
            # (pos >= s_cache) must saturate to "whole cache valid", never
            # overflow-wrap the comparison.  (Equivalent for every
            # in-range pos — k_idx stays < s_cache — but explicit.)
            valid = k_idx[None, :] <= jnp.minimum(pos[:, None], s_cache - 1)
        s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    else:
        if cfg.window is not None:
            valid = (k_idx <= slot) | (pos >= s_cache)
        else:
            valid = k_idx <= pos
        s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
    o = jnp.einsum(
        "bhgqs,bshd->bqhgd", pattn, cache_v.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    )
    if live is not None:
        o = jnp.where(live[:, None, None, None, None], o, 0.0)
    o = o.astype(x.dtype).reshape(b, 1, cfg.n_heads * cfg.d_head)
    return ops.linear(o, p["wo"].astype(COMPUTE_DTYPE)), (cache_k, cache_v)


def decode_attention_paged(
    p, x, cfg: AttnConfig, pages_k, pages_v, page_table, pos, *,
    live=None, backend: str = "auto",
):
    """Single-token decode against a *paged* KV pool (one layer's arena).

    x: (B, 1, D); pages_k/v: (P, page_size, Hkv, Dh) — the shared page
    arena; page_table: (B, W) int32 — each row's pages, where
    ``W · page_size`` is the logical cache length ``S_cache``; pos: (B,)
    int32 per-row absolute positions (always per-slot — the paged path
    only exists for the serving engine).

    Write side mirrors the dense per-slot scatter: the new K/V lands at
    logical slot ``pos % S_cache`` (ring) / ``pos`` (linear) inside the
    row's page for that slot; rows whose table entry is unallocated
    (SENTINEL) or whose linear position is past the cache write nothing
    (``mode="drop"`` — same semantics as the dense phantom-lane drop).
    Rows sharing a page (the engine's shared phantom lane) write
    *identical* values by construction, so scatter order cannot matter.

    Read side routes through ``execution.dispatch_paged_attention``: the
    XLA gather route reproduces the dense arithmetic bit-for-bit; the
    Pallas route streams pages with an online softmax (tolerance-tested).
    ``live`` as in :func:`decode_attention`.
    """

    from repro.core.execution import dispatch_paged_attention

    b = x.shape[0]
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    w = page_table.shape[1]
    s_cache = w * page_size
    pos = jnp.asarray(pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, pos[:, None])

    slot = pos % s_cache if cfg.window is not None else pos
    rows = jnp.arange(b)
    page = page_table[rows, jnp.clip(slot // page_size, 0, w - 1)]
    # Linear positions past the cache write nothing, as on the dense path
    # (any out-of-range page — this marker or a SENTINEL table entry —
    # makes the scatter drop the row).
    page = jnp.where(slot < s_cache, page, jnp.int32(n_pages))
    off = slot % page_size
    pages_k = pages_k.at[page, off].set(k[:, 0].astype(pages_k.dtype), mode="drop")
    pages_v = pages_v.at[page, off].set(v[:, 0].astype(pages_v.dtype), mode="drop")

    o = dispatch_paged_attention(
        q[:, 0], pages_k, pages_v, page_table, pos, backend=backend
    )  # (B, Hq, Dh)
    if live is not None:
        o = jnp.where(live[:, None, None], o, jnp.zeros((), o.dtype))
    o = o.reshape(b, 1, cfg.n_heads * cfg.d_head)
    return ops.linear(o, p["wo"].astype(COMPUTE_DTYPE)), (pages_k, pages_v)


def cross_attention(p, x, enc_k, enc_v, cfg: AttnConfig):
    """Decoder→encoder attention (Whisper). enc_k/v precomputed (B,Se,Hkv,Dh)."""

    b, s, _ = x.shape
    c = lambda w: w.astype(COMPUTE_DTYPE)
    q = ops.linear(x, c(p["wq"]), p.get("bq")).reshape(b, s, cfg.n_heads, cfg.d_head)
    o = chunked_attention(
        q, enc_k.astype(COMPUTE_DTYPE), enc_v.astype(COMPUTE_DTYPE), causal=False
    )
    o = o.reshape(b, s, cfg.n_heads * cfg.d_head)
    return ops.linear(o, p["wo"].astype(COMPUTE_DTYPE))


def init_cross_kv(key, cfg: AttnConfig):
    ks = jax.random.split(key, 2)
    return {
        "wk": dense_init(ks[0], (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
        "wv": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
    }


def encode_cross_kv(p, enc_out, cfg: AttnConfig):
    b, s, _ = enc_out.shape
    c = lambda w: w.astype(COMPUTE_DTYPE)
    k = ops.linear(enc_out, c(p["wk"])).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = ops.linear(enc_out, c(p["wv"])).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_glu(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], (d_model, d_ff)),
        "w3": dense_init(ks[1], (d_model, d_ff)),
        "w2": dense_init(ks[2], (d_ff, d_model)),
    }


def apply_glu(p, x):
    c = lambda w: w.astype(COMPUTE_DTYPE)
    h = jax.nn.silu(ops.gemm(x, c(p["w1"])).astype(jnp.float32)).astype(COMPUTE_DTYPE)
    h = h * ops.gemm(x, c(p["w3"]))
    return ops.gemm(h, c(p["w2"]))


def init_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 2)
    return {
        "w1": dense_init(ks[0], (d_model, d_ff)),
        "b1": jnp.zeros((d_ff,), PARAM_DTYPE),
        "w2": dense_init(ks[1], (d_ff, d_model)),
        "b2": jnp.zeros((d_model,), PARAM_DTYPE),
    }


def apply_mlp(p, x):
    c = lambda w: w.astype(COMPUTE_DTYPE)
    h = ops.linear(x, c(p["w1"]), p["b1"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return ops.linear(h, c(p["w2"]), p["b2"])


def sinusoidal_positions(s: int, d: int):
    pos = np.arange(s)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((s, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


__all__ = [
    "COMPUTE_DTYPE",
    "PARAM_DTYPE",
    "AttnConfig",
    "dense_init",
    "embed_init",
    "rms_norm",
    "layer_norm",
    "rope",
    "repeat_kv",
    "chunked_attention",
    "init_attention",
    "apply_attention",
    "decode_attention",
    "decode_attention_paged",
    "cross_attention",
    "init_cross_kv",
    "encode_cross_kv",
    "init_glu",
    "apply_glu",
    "init_mlp",
    "apply_mlp",
    "sinusoidal_positions",
]

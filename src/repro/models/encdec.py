"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model).  Pre-LN transformer with
sinusoidal positions, MHA (no RoPE), GELU MLPs; the output projection is
weight-tied to the decoder token embedding (as in Whisper).

Decode: self-attention KV cache of ``seq_len`` plus cross-attention K/V
computed once from the encoder output (``enc_frames`` positions).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain_batch
from repro.kernels import ops
from repro.models import layers as L


def _acfg(cfg: ArchConfig, causal: bool) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim,
        causal=causal,
        use_rope=False,
    )


def _init_enc_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    z = lambda: jnp.zeros((d,), L.PARAM_DTYPE)
    o = lambda: jnp.ones((d,), L.PARAM_DTYPE)
    return {
        "ln1_w": o(), "ln1_b": z(),
        "attn": L.init_attention(ks[0], _acfg(cfg, causal=False)),
        "ln2_w": o(), "ln2_b": z(),
        "mlp": L.init_mlp(ks[1], d, cfg.d_ff),
    }


def _init_dec_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    z = lambda: jnp.zeros((d,), L.PARAM_DTYPE)
    o = lambda: jnp.ones((d,), L.PARAM_DTYPE)
    return {
        "ln1_w": o(), "ln1_b": z(),
        "attn": L.init_attention(ks[0], _acfg(cfg, causal=True)),
        "lnx_w": o(), "lnx_b": z(),
        "xattn": L.init_attention(ks[1], _acfg(cfg, causal=False)),
        "xkv": L.init_cross_kv(ks[2], _acfg(cfg, causal=False)),
        "ln2_w": o(), "ln2_b": z(),
        "mlp": L.init_mlp(ks[3], d, cfg.d_ff),
    }


def init_encdec(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    enc_keys = jax.random.split(k1, cfg.enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    d = cfg.d_model
    return {
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "embed": L.embed_init(k3, (cfg.vocab, d)),
        "enc_ln_w": jnp.ones((d,), L.PARAM_DTYPE), "enc_ln_b": jnp.zeros((d,), L.PARAM_DTYPE),
        "dec_ln_w": jnp.ones((d,), L.PARAM_DTYPE), "dec_ln_b": jnp.zeros((d,), L.PARAM_DTYPE),
    }


def _enc_layer(cfg: ArchConfig):
    acfg = _acfg(cfg, causal=False)

    def f(x, p):
        x = constrain_batch(x)
        h, _ = L.apply_attention(p["attn"], L.layer_norm(x, p["ln1_w"], p["ln1_b"]), acfg)
        x = x + h
        h = L.apply_mlp(p["mlp"], L.layer_norm(x, p["ln2_w"], p["ln2_b"]))
        return x + h, None

    return f


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""

    s = frames.shape[1]
    x = frames.astype(L.COMPUTE_DTYPE) + L.sinusoidal_positions(s, cfg.d_model).astype(
        L.COMPUTE_DTYPE
    )
    body = jax.checkpoint(_enc_layer(cfg))
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.layer_norm(x, params["enc_ln_w"], params["enc_ln_b"])


def _dec_layer(cfg: ArchConfig, enc_out):
    acfg = _acfg(cfg, causal=True)
    xcfg = _acfg(cfg, causal=False)

    def f(x, p):
        x = constrain_batch(x)
        h, _ = L.apply_attention(p["attn"], L.layer_norm(x, p["ln1_w"], p["ln1_b"]), acfg)
        x = x + h
        ek, ev = L.encode_cross_kv(p["xkv"], enc_out, xcfg)
        h = L.cross_attention(p["xattn"], L.layer_norm(x, p["lnx_w"], p["lnx_b"]), ek, ev, xcfg)
        x = x + h
        h = L.apply_mlp(p["mlp"], L.layer_norm(x, p["ln2_w"], p["ln2_b"]))
        return x + h, None

    return f


def forward_encdec(params, cfg: ArchConfig, batch, *, remat: bool = True):
    """batch: {"frames": (B,Se,D), "tokens": (B,Sd)} -> logits (B,Sd,V)."""

    enc_out = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = params["embed"][tokens].astype(L.COMPUTE_DTYPE)
    x = x + L.sinusoidal_positions(s, cfg.d_model).astype(L.COMPUTE_DTYPE)
    body = _dec_layer(cfg, enc_out)
    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
    logits = ops.gemm(x, params["embed"].T.astype(L.COMPUTE_DTYPE))  # tied head
    return constrain_batch(logits, extra=("model",)), jnp.float32(0)


def init_decode_state(params_or_none, cfg: ArchConfig, batch: int, seq_len: int):
    """Self-attention cache + precomputed cross K/V (abstract-friendly)."""

    ll = cfg.n_layers
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((ll, batch, seq_len, hkv, dh), L.COMPUTE_DTYPE),
        "v": jnp.zeros((ll, batch, seq_len, hkv, dh), L.COMPUTE_DTYPE),
        "cross_k": jnp.zeros((ll, batch, cfg.enc_frames, hkv, dh), L.COMPUTE_DTYPE),
        "cross_v": jnp.zeros((ll, batch, cfg.enc_frames, hkv, dh), L.COMPUTE_DTYPE),
    }


def decode_step(params, cfg: ArchConfig, batch, state, pos):
    """One decoder token against self cache + fixed cross K/V."""

    tokens = batch["tokens"]  # (B,1)
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(L.COMPUTE_DTYPE)
    # Sinusoid at a single (traced) position — avoids a (S, D) HLO constant.
    # ``pos`` may be a scalar or a (B,) vector of per-row positions (the
    # serving engine's slot table); see layers.decode_attention.
    pos = jnp.asarray(pos, jnp.int32)
    d = cfg.d_model
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    if pos.ndim > 0:
        ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / d)[None, :]
        pe = (
            jnp.zeros((b, d), jnp.float32)
            .at[:, 0::2].set(jnp.sin(ang))
            .at[:, 1::2].set(jnp.cos(ang))
        )
        x = x + pe[:, None].astype(L.COMPUTE_DTYPE)
    else:
        ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim / d)
        pe = jnp.zeros((d,), jnp.float32).at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
        x = x + pe[None, None].astype(L.COMPUTE_DTYPE)
    acfg = _acfg(cfg, causal=True)
    xcfg = _acfg(cfg, causal=False)
    live = batch.get("live")  # (B,) bool lane mask; None → all live

    def body(x, inputs):
        p, ck, cv, xk, xv = inputs
        h, (ck, cv) = L.decode_attention(
            p["attn"], L.layer_norm(x, p["ln1_w"], p["ln1_b"]), acfg, ck, cv, pos,
            live=live,
        )
        x = x + h
        h = L.cross_attention(
            p["xattn"], L.layer_norm(x, p["lnx_w"], p["lnx_b"]), xk, xv, xcfg
        )
        x = x + h
        h = L.apply_mlp(p["mlp"], L.layer_norm(x, p["ln2_w"], p["ln2_b"]))
        return x + h, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_blocks"], state["k"], state["v"], state["cross_k"], state["cross_v"]),
    )
    x = L.layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
    logits = ops.gemm(x, params["embed"].T.astype(L.COMPUTE_DTYPE))
    new_state = dict(state)
    new_state.update({"k": ks, "v": vs})
    return logits, new_state


def loss_fn(params, cfg: ArchConfig, batch, *, remat: bool = True):
    from repro.models.transformer import cross_entropy

    logits, aux = forward_encdec(params, cfg, batch, remat=remat)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


__all__ = [
    "init_encdec",
    "encode",
    "forward_encdec",
    "decode_step",
    "init_decode_state",
    "loss_fn",
]

"""Generic decoder-only LM covering the dense / MoE / SSM / hybrid families.

Structure:

  * layer params are **stacked** along a leading ``L`` axis and driven by
    ``jax.lax.scan`` (compile-once-per-layer — the 512-device AOT compiles
    in seconds instead of minutes),
  * every layer body is wrapped in ``jax.checkpoint`` (remat) so the
    backward pass recomputes activations instead of saving 100+ GB/device,
  * hybrid (Zamba2) runs the Mamba2 stack in groups of
    ``shared_attn_every`` with a weight-shared attention+MLP block between
    groups,
  * decode carries caches through the same scan (KV ring buffers for SWA,
    constant-size SSD states for Mamba2).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain_batch
from repro.kernels import ops
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------


def attn_config(cfg: ArchConfig, *, causal: bool = True) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        window=cfg.swa_window,
        causal=causal,
    )


def block_kind(cfg: ArchConfig) -> str:
    return {"dense": "attn_mlp", "moe": "attn_moe", "ssm": "mamba", "hybrid": "mamba"}[
        cfg.family
    ]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "attn_mlp":
        return {
            "ln1": jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
            "attn": L.init_attention(ks[0], attn_config(cfg)),
            "ln2": jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
            "mlp": L.init_glu(ks[1], cfg.d_model, cfg.d_ff),
        }
    if kind == "attn_moe":
        return {
            "ln1": jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
            "attn": L.init_attention(ks[0], attn_config(cfg)),
            "ln2": jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
            "moe": M.init_moe(ks[1], cfg.moe),
        }
    if kind == "mamba":
        return {
            "ln": jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
            "mamba": S.init_mamba2(ks[0], cfg.ssm),
        }
    raise ValueError(kind)


def init_lm(key, cfg: ArchConfig):
    kind = block_kind(cfg)
    k_emb, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, kind))(layer_keys)
    params: dict[str, Any] = {
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.vocab), scale=0.02),
    }
    if not cfg.embed_inputs:
        params["embed"] = L.embed_init(k_emb, (cfg.vocab, cfg.d_model))
    if cfg.shared_attn_every:
        params["shared"] = _init_block(k_shared, cfg, "attn_mlp")
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_attn_block(p, x, cfg: ArchConfig, positions, *, with_moe: bool):
    acfg = attn_config(cfg)
    h, kv = L.apply_attention(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), acfg,
                              positions=positions)
    # Pin each sub-block output to the (sequence-sharded) residual layout
    # in bf16 *before* the residual add: GSPMD then reduce-scatters the
    # bf16 row-parallel partials instead of all-reducing an fp32
    # intermediate (EXPERIMENTS.md §Perf A).
    x = x + constrain_batch(h)
    if with_moe:
        h, aux = M.apply_moe(p["moe"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    else:
        h, aux = L.apply_glu(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps)), 0.0
    return x + constrain_batch(h), aux, kv


def _layer_fn(cfg: ArchConfig, kind: str, positions):
    def f(x, p):
        # Pin batch-sharding (and, when enabled, sequence-sharding) of the
        # residual carry at every layer boundary.  Mamba blocks keep the
        # sequence whole (conv + chunked scan want contiguous S).
        x = constrain_batch(x, allow_seq=(kind != "mamba"))
        if kind == "attn_mlp":
            x, aux, _ = _apply_attn_block(p, x, cfg, positions, with_moe=False)
        elif kind == "attn_moe":
            x, aux, _ = _apply_attn_block(p, x, cfg, positions, with_moe=True)
        elif kind == "mamba":
            h, _ = S.apply_mamba2(p["mamba"], L.rms_norm(x, p["ln"], cfg.norm_eps), cfg.ssm)
            x, aux = x + h, 0.0
        else:
            raise ValueError(kind)
        return x, jnp.asarray(aux, jnp.float32)

    return f


def embed_tokens(params, cfg: ArchConfig, batch):
    if cfg.embed_inputs:
        x = batch["embeds"].astype(L.COMPUTE_DTYPE)
    else:
        x = params["embed"][batch["tokens"]].astype(L.COMPUTE_DTYPE)
    return constrain_batch(x)


def _cast_params(tree):
    """fp32 master -> bf16 compute cast, applied per-shard BEFORE the FSDP
    all-gathers so weights cross the interconnect in bf16 (2× less wire
    traffic than gathering fp32 masters; EXPERIMENTS.md §Perf A-4)."""

    return jax.tree.map(
        lambda w: w.astype(L.COMPUTE_DTYPE) if w.dtype == jnp.float32 else w, tree
    )


def forward_lm(params, cfg: ArchConfig, batch, *, remat: bool = True):
    """Returns (logits_bf16, aux_loss)."""

    x = embed_tokens(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    kind = block_kind(cfg)
    params = dict(params, blocks=_cast_params(params["blocks"]),
                  lm_head=_cast_params(params["lm_head"]))
    if "shared" in params:
        params = dict(params, shared=_cast_params(params["shared"]))
    body = _layer_fn(cfg, kind, positions)
    if remat:
        body = jax.checkpoint(body)

    if cfg.shared_attn_every:
        # Zamba2: groups of `every` mamba layers + a weight-shared attn block.
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        shared = params["shared"]
        aux_total = jnp.float32(0)
        for g in range(n_groups):
            seg = jax.tree.map(lambda a: a[g * every : (g + 1) * every], params["blocks"])
            x, aux = jax.lax.scan(body, x, seg)
            aux_total += aux.sum()
            shared_fn = lambda xx: _apply_attn_block(shared, xx, cfg, positions, with_moe=False)[0]
            x = jax.checkpoint(shared_fn)(x) if remat else shared_fn(x)
        aux = aux_total
    else:
        x, aux_l = jax.lax.scan(body, x, params["blocks"])
        aux = aux_l.sum()

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ops.gemm(x, params["lm_head"].astype(L.COMPUTE_DTYPE))
    return constrain_batch(logits, extra=("model",)), aux


# ---------------------------------------------------------------------------
# Loss / train objective
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels, mask=None):
    """Softmax CE in reduction form — no vocab gather, so the vocab axis
    stays model-sharded under GSPMD (a take_along_axis here forces an
    all-gather of fp32 logits: +100 GiB/device at 102k vocab; see
    EXPERIMENTS.md §Perf iteration 0)."""

    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, vocab, dtype=shifted.dtype)
    ll = jnp.sum(shifted * onehot, axis=-1) - lse
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ArchConfig, batch, *, remat: bool = True):
    logits, aux = forward_lm(params, cfg, batch, remat=remat)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def cache_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.swa_window is not None:
        return min(cfg.swa_window, seq_len)
    return seq_len


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int):
    """Abstract-friendly cache pytree (call under jax.eval_shape for specs)."""

    kind = block_kind(cfg)
    ll = cfg.n_layers
    if kind == "mamba":
        st = S.init_mamba2_state(batch, cfg.ssm)
        state = {"mamba": jax.tree.map(
            lambda a: jnp.zeros((ll,) + a.shape, a.dtype), st)}
    else:
        sc = cache_len(cfg, seq_len)
        kv_shape = (ll, batch, sc, cfg.n_kv_heads, cfg.head_dim)
        state = {
            "k": jnp.zeros(kv_shape, L.COMPUTE_DTYPE),
            "v": jnp.zeros(kv_shape, L.COMPUTE_DTYPE),
        }
    if cfg.shared_attn_every:
        n_apps = cfg.n_layers // cfg.shared_attn_every
        # The shared attention block sees the full sequence; cap its cache
        # at a practical attention window for long-context decode.
        sc = min(seq_len, 32768)
        kv_shape = (n_apps, batch, sc, cfg.n_kv_heads, cfg.head_dim)
        state["shared_k"] = jnp.zeros(kv_shape, L.COMPUTE_DTYPE)
        state["shared_v"] = jnp.zeros(kv_shape, L.COMPUTE_DTYPE)
    return state


def init_decode_state_paged(cfg: ArchConfig, n_pages: int, page_size: int):
    """Paged decode cache: one shared page arena per layer, no batch dim.

    Replaces the dense ``(L, B, S_cache, Hkv, Dh)`` lanes with
    ``(L, n_pages, page_size, Hkv, Dh)`` arenas; rows find their cache
    through the ``batch["page_table"]`` passed to :func:`decode_step`.
    Only the pure KV-cache families page — recurrent (Mamba2) state is
    constant-size per slot already, and the hybrid/encdec caches carry
    extra leaves the page pool does not cover.
    """

    kind = block_kind(cfg)
    if kind == "mamba" or cfg.shared_attn_every:
        raise ValueError(
            f"paged KV state requires a pure KV-cache family, not "
            f"{cfg.family!r} (recurrent state has no pages to allocate)"
        )
    kv_shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return {
        "pages_k": jnp.zeros(kv_shape, L.COMPUTE_DTYPE),
        "pages_v": jnp.zeros(kv_shape, L.COMPUTE_DTYPE),
    }


def _decode_attn_block(p, x, cfg, ck, cv, pos, *, with_moe: bool, window=None,
                       live=None):
    acfg = attn_config(cfg)
    if window is not None:
        acfg = L.AttnConfig(**{**acfg.__dict__, "window": window})
    h, (ck, cv) = L.decode_attention(
        p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), acfg, ck, cv, pos,
        live=live,
    )
    x = x + h
    if with_moe:
        h, _ = M.apply_moe(p["moe"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    else:
        h = L.apply_glu(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + h, ck, cv


def _decode_attn_block_paged(p, x, cfg, pk, pv, table, pos, *, with_moe: bool,
                             live=None):
    acfg = attn_config(cfg)
    h, (pk, pv) = L.decode_attention_paged(
        p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), acfg, pk, pv,
        table, pos, live=live,
    )
    x = x + h
    if with_moe:
        h, _ = M.apply_moe(p["moe"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.moe)
    else:
        h = L.apply_glu(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + h, pk, pv


def decode_step(params, cfg: ArchConfig, batch, state, pos):
    """One-token serve step.

    batch: {"tokens": (B,1)} (or {"embeds": (B,1,D)}); pos: int32 absolute
    position — a scalar (static batching) or a (B,) vector of per-row
    positions (slot-table serving; see layers.decode_attention).  Returns
    (logits (B,1,V), new_state).

    Two optional batch keys extend the serving contract:
      * ``"page_table"`` (B, W) int32 — required when ``state`` is the
        paged cache from :func:`init_decode_state_paged` (detected by its
        ``"pages_k"`` leaf); rows then read/write KV through the page
        arena (layers.decode_attention_paged).
      * ``"live"`` (B,) bool — rows whose attention output is real;
        absent means all live (bit-identical to the historical step).
    """

    x = embed_tokens(params, cfg, batch)
    kind = block_kind(cfg)

    if kind == "mamba":
        def body(x, inputs):
            p, st = inputs
            h, st = S.decode_mamba2(p["mamba"], L.rms_norm(x, p["ln"], cfg.norm_eps),
                                    cfg.ssm, st)
            return x + h, st

        if cfg.shared_attn_every:
            every = cfg.shared_attn_every
            n_groups = cfg.n_layers // every
            shared = params["shared"]
            new_mamba, new_sk, new_sv = [], [], []
            for g in range(n_groups):
                seg_p = jax.tree.map(lambda a: a[g * every : (g + 1) * every], params["blocks"])
                seg_s = jax.tree.map(lambda a: a[g * every : (g + 1) * every], state["mamba"])
                x, st = jax.lax.scan(body, x, (seg_p, seg_s))
                new_mamba.append(st)
                # Shared attention caps its own window (ring if needed).
                sc = state["shared_k"].shape[2]
                x2, ck, cv = _decode_attn_block(
                    shared, x, cfg, state["shared_k"][g], state["shared_v"][g], pos,
                    with_moe=False,
                    window=sc if sc < 524288 else None,
                )
                x = x2
                new_sk.append(ck)
                new_sv.append(cv)
            new_state = {
                "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
                "shared_k": jnp.stack(new_sk, 0),
                "shared_v": jnp.stack(new_sv, 0),
            }
        else:
            x, st = jax.lax.scan(body, x, (params["blocks"], state["mamba"]))
            new_state = {"mamba": st}
    else:
        with_moe = kind == "attn_moe"
        live = batch.get("live")

        if "pages_k" in state:
            table = batch["page_table"]

            def body(x, inputs):
                p, pk, pv = inputs
                x, pk, pv = _decode_attn_block_paged(
                    p, x, cfg, pk, pv, table, pos, with_moe=with_moe, live=live
                )
                return x, (pk, pv)

            x, (pks, pvs) = jax.lax.scan(
                body, x, (params["blocks"], state["pages_k"], state["pages_v"])
            )
            new_state = {"pages_k": pks, "pages_v": pvs}
        else:
            def body(x, inputs):
                p, ck, cv = inputs
                x, ck, cv = _decode_attn_block(
                    p, x, cfg, ck, cv, pos, with_moe=with_moe, live=live
                )
                return x, (ck, cv)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["blocks"], state["k"], state["v"])
            )
            new_state = {"k": ks, "v": vs}

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ops.gemm(x, params["lm_head"].astype(L.COMPUTE_DTYPE))
    return logits, new_state


def prefill(params, cfg: ArchConfig, batch):
    """Full-sequence inference forward; returns logits (no grad, remat off)."""

    logits, _ = forward_lm(params, cfg, batch, remat=False)
    return logits


__all__ = [
    "attn_config",
    "block_kind",
    "init_lm",
    "forward_lm",
    "loss_fn",
    "cross_entropy",
    "decode_step",
    "prefill",
    "init_decode_state",
    "init_decode_state_paged",
    "cache_len",
]

"""Mamba2 (state-space duality) blocks — training (chunked) and decode.

The SSD chunked algorithm [arXiv:2405.21060] is itself a blocked
decomposition of a structured matmul: within-chunk terms are dense
(Q×Q masked GEMMs on the MXU), across-chunk terms ride a recurrent state —
the same "block to fit fast memory, stream the reduction" structure the
paper applies to GEMM.  Chunks are processed with ``lax.scan`` so the
working set stays bounded at ``chunk × chunk`` per head group.

Decode is the dual recurrent form: constant-size state
``(B, H, d_state, headdim)`` per layer, no KV cache — which is why the
``long_500k`` shape runs for the SSM/hybrid architectures.

Sharding note: the reference Mamba2 fuses z/x/B/C/dt into one in_proj; we
keep them as separate projections (mathematically identical) so each output
dim TP-shards cleanly — z/x/dt split over heads ("model" axis), B/C
replicated (they are per-group, G=1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim


def init_mamba2(key, cfg: SSMConfig):
    ks = jax.random.split(key, 6)
    gn2 = 2 * cfg.n_groups * cfg.d_state
    return {
        "wz": L.dense_init(ks[0], (cfg.d_model, cfg.d_inner)),
        "wx": L.dense_init(ks[1], (cfg.d_model, cfg.d_inner)),
        "wbc": L.dense_init(ks[2], (cfg.d_model, gn2)),
        "wdt": L.dense_init(ks[3], (cfg.d_model, cfg.n_heads), scale=0.02),
        "conv_w_x": L.dense_init(ks[4], (cfg.d_conv, cfg.d_inner), scale=0.5),
        "conv_b_x": jnp.zeros((cfg.d_inner,), L.PARAM_DTYPE),
        "conv_w_bc": L.dense_init(ks[4], (cfg.d_conv, gn2), scale=0.5),
        "conv_b_bc": jnp.zeros((gn2,), L.PARAM_DTYPE),
        "dt_bias": jnp.zeros((cfg.n_heads,), L.PARAM_DTYPE),
        "A_log": jnp.zeros((cfg.n_heads,), L.PARAM_DTYPE),
        "D": jnp.ones((cfg.n_heads,), L.PARAM_DTYPE),
        "norm_w": jnp.ones((cfg.d_inner,), L.PARAM_DTYPE),
        "out_proj": L.dense_init(ks[5], (cfg.d_inner, cfg.d_model)),
    }


def _causal_conv(u, w, b, d_conv: int, conv_state=None):
    """Depthwise causal conv + SiLU. u: (B, S, C); w: (K, C)."""

    if conv_state is not None:  # decode: (B, K-1, C) history
        window = jnp.concatenate([conv_state, u], axis=1)  # (B, K, C)
        out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        out = jax.nn.silu(out + b.astype(jnp.float32))
        return out[:, None].astype(u.dtype), window[:, 1:]
    pad = jnp.pad(u, ((0, 0), (d_conv - 1, 0), (0, 0)))
    stacked = jnp.stack([pad[:, i : i + u.shape[1]] for i in range(d_conv)], axis=2)
    out = jnp.einsum("bskc,kc->bsc", stacked.astype(jnp.float32), w.astype(jnp.float32))
    out = jax.nn.silu(out + b.astype(jnp.float32))
    return out.astype(u.dtype), None


def _ssd_chunked(x, dt, A, Bm, Cm, cfg: SSMConfig, init_state=None):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative rates;
    Bm, Cm: (B,S,G,N).  Returns (y, final_state) with state (B,H,N,P) fp32.
    """

    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = min(cfg.chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q
    rep = h // g

    xq = x.reshape(b, nc, q, h, p)
    dtq = dt.reshape(b, nc, q, h)
    bq = Bm.reshape(b, nc, q, g, n)
    cq = Cm.reshape(b, nc, q, g, n)

    # log decay per step: dA = A * dt  (A < 0)
    da = (A[None, None, None, :] * dtq).astype(jnp.float32)     # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                                 # l_t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: scores[t,s] = (C_t · B_s) * exp(l_t - l_s) * dt_s
    cb = jnp.einsum("bcqgn,bcsgn->bcqsg", cq.astype(jnp.float32), bq.astype(jnp.float32))
    cb_h = jnp.broadcast_to(cb[..., None], (b, nc, q, q, g, rep)).reshape(b, nc, q, q, h)
    scores = cb_h * decay * dtq[:, :, None, :, :]                # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", scores, xq.astype(jnp.float32))

    # per-chunk state contribution: sum_s exp(l_Q - l_s) dt_s B_s ⊗ x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                      # (B,nc,Q,H)
    w = tail * dtq
    bqh = jnp.broadcast_to(bq[:, :, :, :, None, :], (b, nc, q, g, rep, n)).reshape(
        b, nc, q, h, n
    )
    chunk_state = jnp.einsum(
        "bcqhn,bcqhp->bchnp", bqh.astype(jnp.float32) * w[..., None], xq.astype(jnp.float32)
    )                                                            # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    cqh = jnp.broadcast_to(cq[:, :, :, :, None, :], (b, nc, q, g, rep, n)).reshape(
        b, nc, q, h, n
    )

    def scan_fn(hstate, inputs):
        cs, cd, c_h, l_t = inputs  # (B,H,N,P), (B,H), (B,Q,H,N), (B,Q,H)
        y_int = jnp.einsum("bqhn,bhnp->bqhp", c_h * jnp.exp(l_t)[..., None], hstate)
        hstate = cd[..., None, None] * hstate + cs
        return hstate, y_int

    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, n, p), jnp.float32)
    )
    xs = (
        chunk_state.transpose(1, 0, 2, 3, 4),
        chunk_decay.transpose(1, 0, 2),
        cqh.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
        cum.transpose(1, 0, 2, 3),
    )
    final, y_inter = jax.lax.scan(scan_fn, h0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)                   # (B,nc,Q,H,P)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def _project(p, xin, cfg: SSMConfig):
    c = lambda w: w.astype(L.COMPUTE_DTYPE)
    xc = xin.astype(L.COMPUTE_DTYPE)
    z = jnp.einsum("bsd,de->bse", xc, c(p["wz"]))
    xu = jnp.einsum("bsd,de->bse", xc, c(p["wx"]))
    bc = jnp.einsum("bsd,de->bse", xc, c(p["wbc"]))
    dt = jnp.einsum("bsd,dh->bsh", xc, c(p["wdt"]))
    return z, xu, bc, dt


def _finalize(p, y, z, xin, cfg: SSMConfig):
    b, s = xin.shape[0], xin.shape[1]
    y = y.reshape(b, s, cfg.d_inner).astype(L.COMPUTE_DTYPE)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(L.COMPUTE_DTYPE)
    y = L.rms_norm(y, p["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(L.COMPUTE_DTYPE))
    return out.astype(xin.dtype)


def apply_mamba2(p, xin, cfg: SSMConfig, *, init_state=None):
    """Full-sequence Mamba2 block. xin: (B,S,D) -> (y, final_ssm_state)."""

    z, xu, bc, dt = _project(p, xin, cfg)
    xu, _ = _causal_conv(xu, p["conv_w_x"], p["conv_b_x"], cfg.d_conv)
    bc, _ = _causal_conv(bc, p["conv_w_bc"], p["conv_b_bc"], cfg.d_conv)
    b, s, _ = xu.shape
    gn = cfg.n_groups * cfg.d_state
    x = xu.reshape(b, s, cfg.n_heads, cfg.headdim)
    Bm = bc[..., :gn].reshape(b, s, cfg.n_groups, cfg.d_state)
    Cm = bc[..., gn:].reshape(b, s, cfg.n_groups, cfg.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, final = _ssd_chunked(x, dtv, A, Bm, Cm, cfg, init_state=init_state)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return _finalize(p, y, z, xin, cfg), final


def init_mamba2_state(batch: int, cfg: SSMConfig, dtype=jnp.float32):
    gn2 = 2 * cfg.n_groups * cfg.d_state
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.headdim), dtype),
        "conv_x": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), L.COMPUTE_DTYPE),
        "conv_bc": jnp.zeros((batch, cfg.d_conv - 1, gn2), L.COMPUTE_DTYPE),
    }


def decode_mamba2(p, xin, cfg: SSMConfig, state):
    """Single-token recurrent step. xin: (B,1,D); state from init_mamba2_state."""

    z, xu, bc, dt = _project(p, xin, cfg)
    xu, conv_x = _causal_conv(
        xu, p["conv_w_x"], p["conv_b_x"], cfg.d_conv, conv_state=state["conv_x"]
    )
    bc, conv_bc = _causal_conv(
        bc, p["conv_w_bc"], p["conv_b_bc"], cfg.d_conv, conv_state=state["conv_bc"]
    )
    b = xin.shape[0]
    gn = cfg.n_groups * cfg.d_state
    x = xu[:, 0].reshape(b, cfg.n_heads, cfg.headdim)
    Bm = bc[:, 0, :gn].reshape(b, cfg.n_groups, cfg.d_state)
    Cm = bc[:, 0, gn:].reshape(b, cfg.n_groups, cfg.d_state)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    rep = cfg.n_heads // cfg.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)          # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(A[None] * dtv)                                # (B,H)
    h = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh * dtv[..., None], x.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
    y = y[:, None]  # (B,1,H,P)
    out = _finalize(p, y, z, xin, cfg)
    return out, {"ssm": h, "conv_x": conv_x, "conv_bc": conv_bc}


__all__ = [
    "SSMConfig",
    "init_mamba2",
    "apply_mamba2",
    "decode_mamba2",
    "init_mamba2_state",
]

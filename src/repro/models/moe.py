"""Mixture-of-Experts FFN with capacity-based dispatch.

Implements top-k routing (Mixtral 8×top-2; Qwen2-MoE 60×top-4 + shared
experts) via the scatter/gather capacity formulation: tokens are grouped by
their batch row (which is the data-sharded axis, so dispatch stays local
under SPMD), ranked within their expert by a cumulative-sum position, and
scattered into per-expert capacity buffers.  Expert GEMMs are batched
einsums whose compiled FLOPs ≈ active FLOPs × capacity factor — a
requirement for the roofline's MODEL_FLOPS/HLO_FLOPs ratio to be honest.

Routing is itself an asymmetric scheduling problem (balancing a shared
iteration space across unequal consumers); the capacity factor plays the
role of the paper's ratio knob, and the auxiliary load-balance loss is the
feedback controller.  See DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    d_ff_shared: int = 0          # aggregated shared-expert width (Qwen2-MoE)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # Reduce-scatter the expert output buffer over "model" (wins 4× on
    # wide-expert MoE like Mixtral; measured to HURT fine-grained-expert
    # MoE, whose weights are FSDP-only — see EXPERIMENTS.md §Perf C).
    rs_output: bool = True


def init_moe(key, cfg: MoEConfig):
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (cfg.d_model, cfg.n_experts), scale=0.02),
        "w1": L.dense_init(ks[1], (cfg.n_experts, cfg.d_model, cfg.d_ff_expert)),
        "w3": L.dense_init(ks[2], (cfg.n_experts, cfg.d_model, cfg.d_ff_expert)),
        "w2": L.dense_init(ks[3], (cfg.n_experts, cfg.d_ff_expert, cfg.d_model)),
    }
    if cfg.d_ff_shared:
        p["shared"] = L.init_glu(ks[4], cfg.d_model, cfg.d_ff_shared)
        p["shared_gate"] = L.dense_init(ks[4], (cfg.d_model, 1), scale=0.02)
    return p


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    c = ((c + 7) // 8) * 8
    # Never allocate more slots than routing decisions exist — a capacity
    # floor at tiny group sizes (decode: 1 token/group) would compute
    # E/top_k times more expert FLOPs than useful.
    return max(1, min(c if c else 1, tokens_per_group * cfg.top_k))


def apply_moe(p, x, cfg: MoEConfig):
    """x: (B, S, D) -> (y, aux_loss).  Groups = batch rows (data-sharded).

    Decode-time group merging: with one token per sequence the per-row
    groups are too small for capacity dispatch (slot waste = E/top_k), so
    rows are merged into groups of >=256 tokens before routing — the
    serving-side analogue of batching micro-kernels into panels.
    """

    b, s, d = x.shape
    if s < 256 and b > 1:
        merge = min(b, max(1, 256 // max(s, 1)))
        while b % merge:
            merge -= 1
        if merge > 1:
            y, aux = apply_moe(p, x.reshape(b // merge, merge * s, d), cfg)
            return y.reshape(b, s, d), aux
    kk = cfg.top_k
    e = cfg.n_experts
    cap = _capacity(s, cfg)

    xc = x.astype(L.COMPUTE_DTYPE)
    logits = jnp.einsum(
        "bsd,de->bse", xc, p["router"].astype(L.COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)               # (B,S,E) fp32
    gate_w, expert_idx = jax.lax.top_k(probs, kk)         # (B,S,k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style), computed per group.
    me = probs.mean(axis=1)                               # (B,E)
    ce = jnp.zeros((b, e), jnp.float32)
    for j in range(kk):  # k is tiny (2 or 4)
        ce = ce + jax.nn.one_hot(expert_idx[..., j], e, dtype=jnp.float32).mean(axis=1)
    aux = (me * ce).sum(-1).mean() * e * cfg.router_aux_weight

    # Position of each routing decision inside its expert's capacity buffer.
    flat_e = expert_idx.reshape(b, s * kk)                # (B, S*k)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (B, S*k, E)
    pos = jnp.cumsum(oh, axis=1) - 1                      # (B, S*k, E)
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # (B, S*k)
    keep = (pos < cap).astype(jnp.float32) * gate_w.reshape(b, s * kk)

    # Scatter tokens into (E, C, D) buffers per group.
    xr = jnp.repeat(xc, kk, axis=1)                        # (B, S*k, D)
    pos_c = jnp.clip(pos, 0, cap - 1)

    def scatter_group(xg, eg, pg, keepg):
        buf = jnp.zeros((e, cap, d), L.COMPUTE_DTYPE)
        return buf.at[eg, pg].add(xg * (keepg[:, None] > 0))

    buf = jax.vmap(scatter_group)(xr, flat_e, pos_c, keep)  # (B,E,C,D)

    c = lambda w: w.astype(L.COMPUTE_DTYPE)
    h1 = jnp.einsum("becd,edf->becf", buf, c(p["w1"]),
                    preferred_element_type=L.COMPUTE_DTYPE)
    h3 = jnp.einsum("becd,edf->becf", buf, c(p["w3"]),
                    preferred_element_type=L.COMPUTE_DTYPE)
    h = jax.nn.silu(h1.astype(jnp.float32)).astype(L.COMPUTE_DTYPE) * h3
    out_buf = jnp.einsum("becf,efd->becd", h, c(p["w2"]),
                         preferred_element_type=L.COMPUTE_DTYPE)  # (B,E,C,D)
    if cfg.rs_output:
        # The w2 contraction runs over the model-sharded d_ff dim; pinning
        # the output D dim to "model" turns GSPMD's fp32 all-reduce of the
        # whole capacity buffer into a bf16 reduce-scatter (the combine
        # gather below is pointwise in D, so it composes).
        from repro.distributed.sharding import constrain as _constrain

        out_buf = _constrain(out_buf, (None, None, None, "model"))

    def gather_group(bufg, eg, pg, keepg):
        return bufg[eg, pg] * keepg[:, None].astype(L.COMPUTE_DTYPE)

    y = jax.vmap(gather_group)(out_buf, flat_e, pos_c, keep)  # (B,S*k,D)
    y = y.reshape(b, s, kk, d).sum(axis=2)

    if cfg.d_ff_shared:
        g = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", xc, p["shared_gate"].astype(L.COMPUTE_DTYPE),
                       preferred_element_type=jnp.float32)
        ).astype(L.COMPUTE_DTYPE)
        y = y + g * L.apply_glu(p["shared"], xc)
    return y.astype(x.dtype), aux


def moe_active_params(cfg: MoEConfig) -> int:
    """Per-token active parameter count (for MODEL_FLOPS = 6·N_active·D)."""

    expert = 3 * cfg.d_model * cfg.d_ff_expert
    n = cfg.top_k * expert + cfg.d_model * cfg.n_experts
    if cfg.d_ff_shared:
        n += 3 * cfg.d_model * cfg.d_ff_shared + cfg.d_model
    return n


__all__ = ["MoEConfig", "init_moe", "apply_moe", "moe_active_params"]

"""Family dispatch: one uniform API over all ten architectures.

  * ``init_params(key, cfg)``
  * ``make_loss_fn(cfg)``        -> (params, batch) -> (loss, metrics)
  * ``make_prefill_fn(cfg)``     -> (params, batch) -> logits
  * ``make_decode_fn(cfg)``      -> (params, batch, state, pos) -> (logits, state)
  * ``init_decode_state(cfg, batch, seq_len)``
  * ``batch_spec(cfg, shape)``   -> ShapeDtypeStruct inputs for that cell
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeSpec
from repro.models import encdec as E
from repro.models import layers as L
from repro.models import transformer as T


def init_params(key, cfg: ArchConfig):
    if cfg.family == "encdec":
        return E.init_encdec(key, cfg)
    return T.init_lm(key, cfg)


def make_loss_fn(cfg: ArchConfig, *, remat: bool = True):
    if cfg.family == "encdec":
        def f(params, batch):
            return E.loss_fn(params, cfg, batch, remat=remat)
    else:
        def f(params, batch):
            return T.loss_fn(params, cfg, batch, remat=remat)
    return f


def make_prefill_fn(cfg: ArchConfig):
    if cfg.family == "encdec":
        def f(params, batch):
            return E.forward_encdec(params, cfg, batch, remat=False)[0]
    else:
        def f(params, batch):
            return T.prefill(params, cfg, batch)
    return f


def make_decode_fn(cfg: ArchConfig):
    if cfg.family == "encdec":
        def f(params, batch, state, pos):
            return E.decode_step(params, cfg, batch, state, pos)
    else:
        def f(params, batch, state, pos):
            return T.decode_step(params, cfg, batch, state, pos)
    return f


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int):
    if cfg.family == "encdec":
        return E.init_decode_state(None, cfg, batch, seq_len)
    return T.init_decode_state(cfg, batch, seq_len)


def batch_spec(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct inputs for one (arch × shape) cell."""

    b, s = shape.global_batch, shape.seq_len
    tok = lambda ss: jax.ShapeDtypeStruct((b, ss), jnp.int32)
    emb = lambda ss: jax.ShapeDtypeStruct((b, ss, cfg.d_model), L.COMPUTE_DTYPE)

    if shape.kind == "decode":
        batch = {"embeds": emb(1)} if cfg.embed_inputs else {"tokens": tok(1)}
        return batch

    if cfg.family == "encdec":
        out = {"frames": emb(s), "tokens": tok(s)}
    elif cfg.embed_inputs:
        out = {"embeds": emb(s)}
    else:
        out = {"tokens": tok(s)}
    if shape.kind == "train":
        out["labels"] = tok(s)
    return out


def decode_state_spec(cfg: ArchConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, seq_len))


__all__ = [
    "init_params",
    "make_loss_fn",
    "make_prefill_fn",
    "make_decode_fn",
    "init_decode_state",
    "decode_state_spec",
    "batch_spec",
]

"""Family dispatch: one uniform API over all ten architectures.

  * ``init_params(key, cfg)``
  * ``make_loss_fn(cfg)``        -> (params, batch) -> (loss, metrics)
  * ``make_prefill_fn(cfg)``     -> (params, batch) -> logits
    (``with_cache=True``: the fused bulk prefill,
    (params, batch, state, pos0) -> (last_logits, state))
  * ``make_decode_fn(cfg)``      -> (params, batch, state, pos) -> (logits, state)
  * ``init_decode_state(cfg, batch, seq_len)``
  * ``batch_spec(cfg, shape)``   -> ShapeDtypeStruct inputs for that cell
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeSpec
from repro.models import encdec as E
from repro.models import layers as L
from repro.models import transformer as T


def init_params(key, cfg: ArchConfig):
    if cfg.family == "encdec":
        return E.init_encdec(key, cfg)
    return T.init_lm(key, cfg)


def make_loss_fn(cfg: ArchConfig, *, remat: bool = True):
    if cfg.family == "encdec":
        def f(params, batch):
            return E.loss_fn(params, cfg, batch, remat=remat)
    else:
        def f(params, batch):
            return T.loss_fn(params, cfg, batch, remat=remat)
    return f


def make_prefill_fn(cfg: ArchConfig, *, with_cache: bool = False):
    """Prefill forward.

    ``with_cache=False`` (default): the full-sequence training-style
    forward, ``(params, batch) -> logits`` — the throughput path for
    logits-only prefill (dry-run, scoring).

    ``with_cache=True``: the **fused bulk prefill** the serving stack
    uses, ``(params, batch, state, pos0) -> (last_logits, state)`` — one
    jitted forward over the whole prompt that writes the decode state
    (KV caches / SSM states) in one shot.  See
    :func:`bulk_prefill_from_decode` for the exactness contract (the
    written state is bit-identical to the token-by-token decode replay,
    which the one-shot host loop never was going to get from the chunked
    training forward).
    """

    if with_cache:
        return bulk_prefill_from_decode(make_decode_fn(cfg))
    if cfg.family == "encdec":
        def f(params, batch):
            return E.forward_encdec(params, cfg, batch, remat=False)[0]
    else:
        def f(params, batch):
            return T.prefill(params, cfg, batch)
    return f


def bulk_prefill_from_decode(decode_fn):
    """Build the fused bulk prefill from any decode-step-compatible fn.

    ``decode_fn(params, {"tokens": (B,1)}, state, pos) -> (logits, state)``
    becomes ``(params, {"tokens": (B,P)}, state, pos0) -> (logits, state)``:
    the whole prompt is consumed inside a single jitted program (a
    ``lax.scan`` over prompt positions), so the host dispatches **one**
    call per prompt instead of P — and, donated, the decode state updates
    in place instead of being copied P times through the host loop.

    The scan body *is* the decode recurrence, which makes the resulting
    cache **bit-identical** to the token-by-token replay — the property
    the slot-table serving engine needs (a prefilled slot must be
    indistinguishable from one that decoded those tokens), and one no
    chunked full-sequence forward can provide: its attention/SSD
    reductions are associativity-reordered relative to the recurrent
    form, so its cache agrees only to tolerance.  Bit-identity for every
    token-in zoo arch is asserted in tests/test_serving.py.

    ``pos0`` is the absolute position of the first prompt token — a
    scalar, or a (B,) vector of per-slot positions.  Accepts the wrapped
    ``decode_fn`` so callers can prefill through a class-sharded mixed
    step (``AsymmetricMesh.class_sharded``) as well as the plain zoo fn.

    Every batch key besides ``"tokens"`` (``"page_table"``, ``"live"``)
    is passed through to each decode step unchanged — the paged serving
    path prefills through the same page tables it decodes through.

    ``plens`` (optional, (B,) int32) supports **mixed-length prompts in
    one fused call**: prompts are right-padded to the batch's max length,
    every row runs all padded steps (pad writes land past each row's
    live positions, where the decode mask already hides them — the same
    argument that makes stale cache content invisible), and each row's
    *returned* logits are the ones from its own last real token
    ``t == plens[row] - 1`` instead of the final padded step.  ``None``
    keeps the single-length behavior bit-for-bit.
    """

    def f(params, batch, state, pos0, plens=None):
        if "tokens" not in batch:
            raise ValueError("bulk prefill needs a token-in batch ({'tokens': (B,P)})")
        tokens = batch["tokens"]
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        pos0 = jnp.asarray(pos0, jnp.int32)
        plen = tokens.shape[1]

        def step(state, tok, p):
            return decode_fn(params, dict(extras, tokens=tok), state, p)

        def select(sel, lg, t):
            if plens is None:
                return lg
            keep = (jnp.asarray(plens, jnp.int32) - 1 == t)[:, None, None]
            return jnp.where(keep, lg, sel)

        logits, state = step(state, tokens[:, :1], pos0)
        logits = select(logits, logits, 0)
        if plen > 1:
            def body(carry, t):
                st, sel = carry
                tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
                lg, st = step(st, tok, pos0 + t)
                return (st, select(sel, lg, t)), None

            (state, logits), _ = jax.lax.scan(
                body, (state, logits), jnp.arange(1, plen)
            )
        return logits, state

    return f


def make_decode_fn(cfg: ArchConfig):
    if cfg.family == "encdec":
        def f(params, batch, state, pos):
            return E.decode_step(params, cfg, batch, state, pos)
    else:
        def f(params, batch, state, pos):
            return T.decode_step(params, cfg, batch, state, pos)
    return f


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int):
    if cfg.family == "encdec":
        return E.init_decode_state(None, cfg, batch, seq_len)
    return T.init_decode_state(cfg, batch, seq_len)


def init_decode_state_paged(cfg: ArchConfig, n_pages: int, page_size: int):
    """Paged decode cache (pure KV-cache families only; see transformer)."""

    if cfg.family == "encdec":
        raise ValueError("paged KV state does not cover the encdec cross-KV cache")
    return T.init_decode_state_paged(cfg, n_pages, page_size)


def batch_spec(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct inputs for one (arch × shape) cell."""

    b, s = shape.global_batch, shape.seq_len
    tok = lambda ss: jax.ShapeDtypeStruct((b, ss), jnp.int32)
    emb = lambda ss: jax.ShapeDtypeStruct((b, ss, cfg.d_model), L.COMPUTE_DTYPE)

    if shape.kind == "decode":
        batch = {"embeds": emb(1)} if cfg.embed_inputs else {"tokens": tok(1)}
        return batch

    if cfg.family == "encdec":
        out = {"frames": emb(s), "tokens": tok(s)}
    elif cfg.embed_inputs:
        out = {"embeds": emb(s)}
    else:
        out = {"tokens": tok(s)}
    if shape.kind == "train":
        out["labels"] = tok(s)
    return out


def decode_state_spec(cfg: ArchConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, seq_len))


__all__ = [
    "init_params",
    "make_loss_fn",
    "make_prefill_fn",
    "bulk_prefill_from_decode",
    "make_decode_fn",
    "init_decode_state",
    "init_decode_state_paged",
    "decode_state_spec",
    "batch_spec",
]

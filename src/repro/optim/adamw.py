"""AdamW with learning-rate schedules, global-norm clipping, and
micro-batch gradient accumulation — pure pytree transforms, no deps.

State layout mirrors param sharding (the trainer shards ``m``/``v`` with
the same PartitionSpecs as their parameters, ZeRO-style when FSDP mode is
on), so optimizer memory scales down with the data axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""

    step = state["step"] + 1
    grad_norm = global_norm(grads)
    if cfg.clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        # Decoupled weight decay on matrices only (ndim >= 2).
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + wd * p)
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"lr": lr, "grad_norm": grad_norm},
    )


def accumulate_gradients(loss_fn: Callable, params, batch, n_micro: int):
    """Scan over micro-batches; returns (mean_loss, metrics, mean_grads).

    Batch tensors are split along axis 0; ``n_micro`` must divide the
    (per-shard) batch.  Accumulation is fp32.
    """

    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(acc, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_g, acc_l = acc
        acc_g = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
        return (acc_g, acc_l + loss), metrics

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc_g, acc_l), metrics = jax.lax.scan(body, (zero_g, jnp.float32(0)), micro)
    grads = jax.tree.map(lambda g: g / n_micro, acc_g)
    metrics = jax.tree.map(lambda x: x[-1], metrics)
    return acc_l / n_micro, metrics, grads


__all__ = [
    "AdamWConfig",
    "lr_at",
    "init_opt_state",
    "adamw_update",
    "accumulate_gradients",
    "global_norm",
    "clip_by_global_norm",
]

"""optim substrate."""

"""Zamba2-2.7B [arXiv:2411.15242; hf].

Hybrid: Mamba2 backbone with a weight-shared attention+MLP block applied
every 6 layers (the paper's shared-block design, simplified to a single
shared set without the LoRA adapters; see DESIGN.md).
"""

from repro.configs import ArchConfig
from repro.models.ssm import SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_model=2560, d_state=64, headdim=64, expand=2, chunk=256),
    shared_attn_every=6,
    notes="ssm hybrid -> long_500k runs (constant-size recurrent state + shared attn over window)",
)

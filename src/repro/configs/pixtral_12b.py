"""Pixtral-12B backbone [hf:mistralai/Pixtral-12B-2409; unverified].

VLM: Pixtral-ViT frontend is a STUB per the assignment — ``input_specs``
supplies precomputed patch embeddings (B, S, d_model); this config is the
Mistral-NeMo-style decoder backbone only.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e6,
    embed_inputs=True,
    notes="vlm backbone; patch embeddings from stub frontend; full attention -> long_500k skipped",
)

"""Mamba2-1.3B [arXiv:2405.21060; unverified]. Attention-free SSD."""

from repro.configs import ArchConfig
from repro.models.ssm import SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_head=64,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_model=2048, d_state=128, headdim=64, expand=2, chunk=256),
    notes="attention-free -> long_500k runs (constant-size recurrent state); no decode KV cache",
)

"""InternLM2-1.8B [arXiv:2403.17297; hf]. Dense GQA decoder."""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92544,
    rope_theta=1e6,
    notes="full attention -> long_500k skipped",
)

"""Architecture configuration registry.

One module per assigned architecture (``src/repro/configs/<id>.py``), each
exporting ``CONFIG: ArchConfig`` with the exact published dimensions.
``get_config(name)`` resolves either the registry id (e.g.
``"qwen2.5-32b"``) or the module name (``"qwen2p5_32b"``).

Every config also knows how to produce a *reduced* variant
(:meth:`ArchConfig.reduced`) for the CPU smoke tests — same family and
block structure, tiny dims.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned LM shape set (identical across the ten architectures).
LM_SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    qkv_bias: bool = False
    swa_window: Optional[int] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    norm: str = "rms"           # rms | layer
    embed_inputs: bool = False  # pixtral: backbone consumes patch embeddings
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0  # zamba2: shared attn+mlp block cadence
    enc_layers: int = 0         # whisper: encoder depth (decoder = n_layers)
    enc_frames: int = 1500      # whisper: cross-attention KV length at decode
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: SSM state / hybrid / sliding window."""

        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    def shapes(self, include_skipped: bool = False):
        out = []
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.subquadratic and not include_skipped:
                continue
            out.append(s)
        return out

    def param_count(self) -> int:
        """Analytic total parameter count (used by MODEL_FLOPS)."""

        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        glu = 3 * d * f
        n = 0
        if not self.embed_inputs:
            n += v * d
        n += d * v  # lm head
        if self.family == "dense":
            n += L * (attn + glu + 2 * d)
        elif self.family == "moe":
            m = self.moe
            per = attn + 2 * d + d * m.n_experts + 3 * m.n_experts * d * m.d_ff_expert
            if m.d_ff_shared:
                per += 3 * d * m.d_ff_shared + d
            n += L * per
        elif self.family == "ssm":
            n += L * self._mamba_params()
        elif self.family == "hybrid":
            n += L * self._mamba_params()
            n += attn + glu + 2 * d  # one shared block
        elif self.family == "encdec":
            mlp = 2 * d * f
            n += self.enc_layers * (attn + mlp + 2 * d)
            n += L * (attn + (d * hkv * 2 + d * hq + hq * d) + mlp + 3 * d)
        return n

    def _mamba_params(self) -> int:
        s = self.ssm
        di = s.d_inner
        gn2 = 2 * s.n_groups * s.d_state
        return (
            2 * self.d_model * di          # wz, wx
            + self.d_model * gn2           # wbc
            + self.d_model * s.n_heads     # wdt
            + s.d_conv * (di + gn2)        # convs
            + 3 * s.n_heads + di           # dt_bias, A_log, D, norm
            + di * self.d_model            # out_proj
        )

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k only)."""

        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.n_layers
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        per = attn + 2 * d + d * m.n_experts + 3 * m.top_k * d * m.d_ff_expert
        if m.d_ff_shared:
            per += 3 * d * m.d_ff_shared + d
        return self.vocab * d * 2 + L * per

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""

        kw = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            d_head=16,
            qkv_bias=self.qkv_bias,
            swa_window=8 if self.swa_window else None,
            embed_inputs=self.embed_inputs,
            norm=self.norm,
            shared_attn_every=2 if self.shared_attn_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_frames=16,
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                d_model=64,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=32,
                d_ff_shared=64 if self.moe.d_ff_shared else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_model=64, d_state=16, headdim=16, expand=2, chunk=8)
        return ArchConfig(**kw)


_REGISTRY = {
    "pixtral-12b": "pixtral_12b",
    "zamba2-2.7b": "zamba2_2p7b",
    "whisper-small": "whisper_small",
    "internlm2-1.8b": "internlm2_1p8b",
    "qwen2.5-32b": "qwen2p5_32b",
    "minitron-4b": "minitron_4b",
    "deepseek-7b": "deepseek_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "mamba2-1.3b": "mamba2_1p3b",
}


def list_configs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    mod_name = _REGISTRY.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


__all__ = ["ArchConfig", "ShapeSpec", "LM_SHAPES", "get_config", "list_configs"]

"""Whisper-small [arXiv:2212.04356; unverified].

Enc-dec; the 2x conv1d audio frontend is a STUB per the assignment —
``input_specs`` supplies precomputed frame embeddings (B, S, d_model).
Decoder: causal self-attention + cross-attention over encoder states.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,        # decoder layers
    enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab=51865,
    norm="layer",
    embed_inputs=False,
    enc_frames=1500,
    notes="enc-dec; frontend stubbed; decode shapes use self-cache=seq_len, cross-cache=1500 frames; full attention -> long_500k skipped",
)

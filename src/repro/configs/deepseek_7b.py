"""DeepSeek-7B [arXiv:2401.02954; hf]. LLaMA-architecture dense decoder (MHA)."""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab=102400,
    notes="full attention -> long_500k skipped",
)

"""Mixtral-8x7B [arXiv:2401.04088; hf]. 8-expert top-2 MoE with SWA.

The 4096-token sliding window bounds the decode KV cache (ring buffer),
so long_500k RUNS for this architecture.
"""

from repro.configs import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=32000,
    swa_window=4096,
    rope_theta=1e6,
    moe=MoEConfig(d_model=4096, n_experts=8, top_k=2, d_ff_expert=14336),
    notes="SWA ring cache -> long_500k runs with window=4096",
)

"""Qwen2-MoE-A2.7B (Qwen1.5-MoE-A2.7B) [hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts top-4 + 4 shared experts (4 x 1408 = 5632 aggregated
shared width, implemented as a single gated GLU of width 5632 —
mathematically identical to four parallel 1408 experts always active).
"""

from repro.configs import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    moe=MoEConfig(d_model=2048, n_experts=60, top_k=4, d_ff_expert=1408,
                  d_ff_shared=5632, rs_output=False),
    notes="full attention -> long_500k skipped",
)

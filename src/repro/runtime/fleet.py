"""Fault-tolerant multi-engine fleet: the paper's scheduling story, one
level up.

A :class:`Fleet` fronts N :class:`~repro.runtime.serving.ServingEngine`\\ s
(possibly with *different* class mixes) behind one submit/stream API.
Engines play the role the paper gives cores: each engine's calibrated
tokens-per-second (:meth:`ServingEngine.calibrated_tps`) is its
``rel_throughput``, and the very same :class:`DynamicScheduler`
EMA/drift/hysteresis machinery (via :func:`~repro.core.schedule.fleet_scheduler`)
balances *requests* over engines the way it balances rows over pods —
routing by the shared largest-remainder
:func:`~repro.core.schedule.deficit_route`, re-deriving shares only past
the drift threshold, shedding load from an engine whose observed
per-tick times inflate (a fleet-level straggler).

Fault tolerance is by construction, not by after-the-fact recovery
heuristics:

* **Deterministic fault injection** — ``runtime.faults`` schedules named
  faults (engine stall, pod death, admission failure, latency spike) at
  exact ticks; the fleet consults :func:`faults.fault_active` at each
  fault point.  No plan armed ⇒ one module-global ``None`` check.
* **Health checks with hysteresis** — ``unhealthy_after`` consecutive
  bad ticks (stall / admission failure symptoms) route new work away
  and drain an engine's queue; ``healthy_after`` consecutive good ticks
  restore it.  The double threshold is the scheduler's rebalance
  hysteresis applied to liveness: a single hiccup must not thrash
  placement.
* **Queued-request migration** — *not-yet-admitted* requests move away
  from dead, unhealthy, parked, or saturated engines
  (:meth:`ServingEngine.withdraw` / :meth:`~ServingEngine.export_queued`
  roll back the engine router's counts).  Admitted work never migrates:
  a decode slot's tokens are already flowing, and exactness comes from
  letting them finish or retrying from scratch.
* **Deadlines with retry-and-backoff** — a request queued past its
  deadline migrates; a request in flight on a dying engine is
  re-submitted after an exponential backoff (``retry_backoff · 2^(k-1)``
  ticks).
* **Fleet-level parking** — under ``objective="energy"|"edp"`` the
  fleet drains and gates whole *engines* the load does not need,
  reusing PR 9's pod-parking protocol one level up: park the least
  energy-efficient engine while offered load fits the remaining
  capacity with hysteresis margin (``n_work ≤ remaining·(1−h)``,
  ``h`` = the scheduler's ``rebalance_threshold``), re-admit most
  efficient first, never park the most efficient or last engine.
  Parking only blocks new routing — in-flight work drains naturally.

**Exactness contract** (tested): every submitted request completes
*exactly once*, with tokens bit-identical to a fault-free single-engine
run — regardless of which engine served it, whether it was migrated
while queued, or whether it was retried after an engine death.  This
holds because greedy decode is a deterministic function of the prompt
(for row-local archs — the fleet does not change jitted programs), and
because faults only ever perturb *control flow*: which engine runs,
when it admits, what the scheduler observes.

The tick loop is cooperative and deterministic: :meth:`tick` runs one
scheduling round (faults → admit → step → observe → harvest → deadlines
→ retries → parking → migration) over every live engine.  ``async``
surfaces (:meth:`submit_async`, :meth:`stream`, :meth:`run_async`) wrap
the same loop for streaming clients.
"""

from __future__ import annotations

import asyncio
import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.schedule import deficit_route, fleet_scheduler
from repro.observability import metrics as MET
from repro.observability import trace as T
from repro.runtime import faults
from repro.runtime.serving import Request, ServingEngine

_M = None


def _metrics():
    """Fleet metric families, registered once on first enabled use."""

    global _M
    if _M is None:
        _M = {
            "engines_alive": MET.gauge(
                "fleet_engines_alive",
                "Engines alive (not killed), including parked ones"),
            "engines_parked": MET.gauge(
                "fleet_engines_parked",
                "Engines drained and gated by the energy objective"),
            "queue_depth": MET.gauge(
                "fleet_queue_depth", "Queued requests per engine",
                labels=("engine",)),
            "inflight": MET.gauge(
                "fleet_inflight", "Admitted in-flight requests per engine",
                labels=("engine",)),
            "migrations": MET.counter(
                "fleet_migrations_total",
                "Queued requests migrated between engines"),
            "retries": MET.counter(
                "fleet_retries_total",
                "Requests re-submitted after an engine failure"),
            "completions": MET.counter(
                "fleet_completions_total", "Requests completed by the fleet"),
        }
    return _M


@dataclasses.dataclass
class FleetStats:
    """Fleet-level counters; conservation must reconcile: ``submitted ==
    completed`` after a drained run, ``duplicate_completions == 0``
    always, and the migration/retry counters match their trace
    instants."""

    submitted: int = 0
    completed: int = 0
    duplicate_completions: int = 0   # structurally impossible; asserted 0
    migrated: int = 0                # queued-request moves between engines
    retries: int = 0                 # in-flight work re-submitted after a death
    deadline_requeues: int = 0       # migrations triggered by a deadline
    engine_kills: int = 0
    stalled_ticks: int = 0
    admission_faults: int = 0
    latency_spikes: int = 0
    engine_parks: int = 0
    engine_unparks: int = 0
    health_trips: int = 0            # healthy -> unhealthy transitions
    health_recoveries: int = 0
    ticks: int = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FleetCompletion:
    """A finished fleet request, with its placement history."""

    rid: int                  # fleet-level rid (submission order)
    tokens: np.ndarray        # (P + n_generated,) int32
    prompt_len: int
    engine: int               # engine that completed it
    stop: str                 # "budget" | "eos"
    attempts: int = 1         # placements that reached an engine (1 = no retry)
    migrations: int = 0       # queued-request moves before admission


@dataclasses.dataclass
class _Pending:
    """Fleet-side bookkeeping for one not-yet-completed request."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    deadline: Optional[int]   # absolute fleet tick, or None
    engine: int = -1          # current placement (-1 = unplaced)
    erid: int = -1            # rid on that engine
    attempts: int = 0
    migrations: int = 0
    retry_at: int = 0         # earliest tick for re-placement (backoff)


class Fleet:
    """N serving engines behind one submit/stream API.

    Parameters
    ----------
    engines : the serving engines (heterogeneous class mixes welcome).
    rel_throughput : per-engine calibrated tokens/s; defaults to each
        engine's :meth:`~ServingEngine.calibrated_tps`.
    powers : per-engine modeled active watts (for the energy/edp routing
        discount and parking order); defaults to the sum of each
        engine's per-pod active watts.
    objective : "perf" | "energy" | "edp" — non-perf objectives discount
        inefficient engines' routing shares and enable engine parking.
    ema, rebalance_threshold : forwarded to the fleet scheduler
        (hysteresis governs both share re-derivation and parking).
    unhealthy_after, healthy_after : health hysteresis in ticks.
    retry_backoff : base backoff (ticks) before retrying a request lost
        to an engine death; doubles per attempt.
    max_attempts : hard cap on placements per request (a request that
        cannot complete in this many placements raises — conservation
        failures must be loud).
    """

    def __init__(
        self,
        engines: Sequence[ServingEngine],
        *,
        rel_throughput: Optional[Sequence[float]] = None,
        powers: Optional[Sequence[float]] = None,
        objective: str = "perf",
        ema: float = 0.5,
        rebalance_threshold: float = 0.05,
        unhealthy_after: int = 2,
        healthy_after: int = 2,
        retry_backoff: int = 1,
        max_attempts: int = 8,
    ):
        engines = list(engines)
        if not engines:
            raise ValueError("need at least one engine")
        self.engines = engines
        self.n_engines = len(engines)
        if rel_throughput is None:
            rel_throughput = [e.calibrated_tps() for e in engines]
        self.rel_throughput = [float(r) for r in rel_throughput]
        if powers is None:
            powers = [float(sum(e.asym.pod_active_watts())) for e in engines]
        self.powers = [float(p) for p in powers]
        self.objective = objective
        self.scheduler = fleet_scheduler(
            self.rel_throughput,
            ema=ema,
            rebalance_threshold=rebalance_threshold,
            objective=objective,
            powers=self.powers,
        )
        self.unhealthy_after = int(unhealthy_after)
        self.healthy_after = int(healthy_after)
        self.retry_backoff = max(0, int(retry_backoff))
        self.max_attempts = int(max_attempts)

        self._routed = [0] * self.n_engines   # requests currently assigned
        self._alive = [True] * self.n_engines
        self._unhealthy = [False] * self.n_engines
        self._bad = [0] * self.n_engines      # consecutive bad ticks
        self._good = [0] * self.n_engines     # consecutive good ticks
        self._parked: set[int] = set()
        # frid bookkeeping: at most one live placement per fleet rid.
        self._pending: dict[int, _Pending] = {}
        self._rid_map: list[dict[int, int]] = [dict() for _ in engines]
        self._harvested = [len(e.completions) for e in engines]
        self._completed_rids: set[int] = set()
        self._done_events: dict[int, asyncio.Event] = {}
        self._next_rid = 0
        self._tick = 0
        self.completions: list[FleetCompletion] = []
        self.stats = FleetStats()

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *, deadline: Optional[int] = None) -> int:
        """Queue one request fleet-wide; returns its fleet rid.

        ``deadline`` (ticks from now) bounds *queueing*: a request still
        unadmitted past it migrates to another engine.  Admitted work is
        never preempted — exactness over latency.
        """

        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        p = _Pending(
            rid=rid,
            prompt=prompt,
            max_new_tokens=int(max_new_tokens),
            deadline=None if deadline is None else self._tick + int(deadline),
        )
        self._pending[rid] = p
        self.stats.submitted += 1
        self._place(p)
        return rid

    def _candidates(self, exclude: frozenset = frozenset()) -> list[int]:
        """Routable engines, in degradation order: prefer healthy live
        unparked engines, fall back to parked/unhealthy ones (graceful
        degradation beats rejecting work), never a dead engine."""

        def pick(pred):
            return [
                i for i in range(self.n_engines)
                if self._alive[i] and i not in exclude and pred(i)
            ]

        cands = pick(lambda i: i not in self._parked and not self._unhealthy[i])
        if not cands:
            cands = pick(lambda i: not self._unhealthy[i])
        if not cands:
            cands = pick(lambda i: True)
        return cands

    def _routing_weights(self, cands: list[int]) -> list[float]:
        """Per-candidate shares from the scheduler's hysteresis-cached
        chunk table (re-derived only past the drift threshold — jitter in
        observed rates does not thrash routing), falling back to raw
        rates when the table gives every candidate a zero share."""

        resolution = max(sum(e.n_slots for e in self.engines), self.n_engines)
        sizes = self.scheduler.table(resolution).sizes()
        w = [float(sizes[i]) for i in cands]
        if sum(w) <= 0:
            w = [float(self.scheduler.rates[i]) for i in cands]
        return w

    def _place(self, p: _Pending, *, exclude: frozenset = frozenset()) -> int:
        """Route ``p`` onto an engine; returns the engine index."""

        cands = self._candidates(exclude)
        if not cands:
            raise RuntimeError("no live engine to route to")
        if p.attempts >= self.max_attempts:
            raise RuntimeError(
                f"request {p.rid} exceeded max_attempts={self.max_attempts}"
            )
        routed = [self._routed[i] for i in cands]
        e = cands[deficit_route(self._routing_weights(cands), routed)]
        erid = self.engines[e].submit(p.prompt, p.max_new_tokens)
        self._rid_map[e][erid] = p.rid
        self._routed[e] += 1
        p.engine, p.erid = e, erid
        p.attempts += 1
        return e

    def _can_migrate(self, p: _Pending) -> bool:
        """Optional migrations (deadline, park drain, saturation) skip
        rather than burn the last placement attempts — a request that has
        moved a lot stays queued where it is and completes there; only a
        *mandatory* re-place (engine death) may exhaust the cap and
        raise."""

        return p.attempts < self.max_attempts - 1

    def _withdraw(self, p: _Pending) -> Optional[Request]:
        """Pull ``p`` back out of its engine's queue (None if admitted)."""

        if p.engine < 0:
            return None
        req = self.engines[p.engine].withdraw(p.erid)
        if req is not None:
            self._rid_map[p.engine].pop(p.erid, None)
            self._routed[p.engine] -= 1
            p.engine, p.erid = -1, -1
        return req

    def _migrate(self, p: _Pending, src: int, reason: str) -> None:
        p.migrations += 1
        self.stats.migrated += 1
        dst = self._place(p, exclude=frozenset({src}))
        if T.enabled():
            _metrics()["migrations"].inc()
            T.instant(
                "fleet.migrate", cat="fleet",
                rid=p.rid, src=src, dst=dst, reason=reason,
            )

    # -- the tick loop -------------------------------------------------------

    def tick(self) -> int:
        """One cooperative scheduling round; returns tokens decoded.

        Order matters and is deterministic: faults gate each engine's
        admit/step, the scheduler observes the tick's per-engine
        progress on the modeled clock, completions are harvested
        (exactly-once bookkeeping), then the control actions — deadline
        requeues, backoff retries, parking, saturation migration — run
        on the post-step state.
        """

        self._tick += 1
        self.stats.ticks += 1
        t = self._tick
        produced = 0
        units = [0] * self.n_engines
        times = [0.0] * self.n_engines
        for e, eng in enumerate(self.engines):
            if not self._alive[e]:
                continue
            if faults.fault_active("pod_death", engine=e, tick=t) is not None:
                self._kill_engine(e)
                continue
            if faults.fault_active("engine_stall", engine=e, tick=t) is not None:
                self.stats.stalled_ticks += 1
                self._note_health(e, bad=True)
                continue
            blocked = faults.fault_active("admission_fail", engine=e, tick=t)
            if blocked is not None:
                self.stats.admission_faults += 1
            elif any(eng.queues):
                eng.admit()
            tok0, m0 = eng.stats.tokens, eng.stats.modeled_decode_s
            if (eng.slot_rid >= 0).any():
                produced += eng.step()
            units[e] = eng.stats.tokens - tok0
            dt = eng.stats.modeled_decode_s - m0
            spike = faults.fault_active("latency_spike", engine=e, tick=t)
            if spike is not None:
                # The engine ran fine; what degrades is the *observed*
                # time — DAS sheds share exactly as it would for a
                # thermally throttled core.  No correctness event.
                dt *= spike.factor
                self.stats.latency_spikes += 1
            times[e] = dt
            self._note_health(e, bad=blocked is not None)
        if any(u > 0 for u in units):
            # Engines-as-classes calibration on the modeled clock:
            # observe() skips zero-unit entries, EMAs the rest.
            self.scheduler.observe(units, times)
        self._harvest()
        self._check_deadlines()
        self._retry_due()
        self._update_parking()
        self._migrate_from_saturated()
        if T.enabled():
            self._record_tick_telemetry()
        return produced

    def _note_health(self, e: int, *, bad: bool) -> None:
        if bad:
            self._bad[e] += 1
            self._good[e] = 0
            if (
                not self._unhealthy[e]
                and self._bad[e] >= self.unhealthy_after
            ):
                self._unhealthy[e] = True
                self.stats.health_trips += 1
                if T.enabled():
                    T.instant(
                        "fleet.engine_unhealthy", cat="fleet",
                        engine=e, bad_ticks=self._bad[e],
                    )
        else:
            self._good[e] += 1
            self._bad[e] = 0
            if self._unhealthy[e] and self._good[e] >= self.healthy_after:
                self._unhealthy[e] = False
                self.stats.health_recoveries += 1
                if T.enabled():
                    T.instant(
                        "fleet.engine_recovered", cat="fleet",
                        engine=e, good_ticks=self._good[e],
                    )

    def _kill_engine(self, e: int) -> None:
        """Permanent engine loss: migrate its queue, retry its in-flight.

        One SPMD step spans all of an engine's pods, so a pod death
        takes the engine's whole program — there is no partial
        survival.  Queued requests (never admitted) migrate losslessly;
        in-flight requests lost mid-decode retry *from scratch* after a
        backoff — greedy decode is deterministic in the prompt, so the
        retry reproduces the exact tokens the lost decode would have.
        """

        self._alive[e] = False
        self._parked.discard(e)
        self._unhealthy[e] = False
        self.stats.engine_kills += 1
        eng = self.engines[e]
        migrated = retried = 0
        for req in eng.export_queued():
            rid = self._rid_map[e].pop(req.rid, None)
            if rid is None:
                continue
            p = self._pending[rid]
            self._routed[e] -= 1
            p.engine, p.erid = -1, -1
            self._migrate(p, e, reason="engine_kill")
            migrated += 1
        for erid, rid in list(self._rid_map[e].items()):
            del self._rid_map[e][erid]
            p = self._pending[rid]
            self._routed[e] -= 1
            p.engine, p.erid = -1, -1
            p.retry_at = self._tick + self.retry_backoff * (
                2 ** max(0, p.attempts - 1)
            )
            retried += 1
        if T.enabled():
            _metrics()["engines_alive"].set(sum(self._alive))
            T.instant(
                "fleet.engine_kill", cat="fleet",
                engine=e, migrated=migrated, retrying=retried,
            )

    def _harvest(self) -> None:
        """Collect engine completions into fleet completions exactly once."""

        for e, eng in enumerate(self.engines):
            if self._harvested[e] == len(eng.completions):
                continue
            new = eng.completions[self._harvested[e]:]
            self._harvested[e] = len(eng.completions)
            for c in new:
                rid = self._rid_map[e].pop(c.rid, None)
                if rid is None or rid in self._completed_rids:
                    # Structurally unreachable (a rid has one live
                    # placement); counted so conservation tests can
                    # assert it stayed that way.
                    self.stats.duplicate_completions += 1
                    continue
                self._completed_rids.add(rid)
                p = self._pending.pop(rid)
                self._routed[e] -= 1
                self.completions.append(
                    FleetCompletion(
                        rid=rid,
                        tokens=c.tokens,
                        prompt_len=c.prompt_len,
                        engine=e,
                        stop=c.stop,
                        attempts=p.attempts,
                        migrations=p.migrations,
                    )
                )
                self.stats.completed += 1
                if T.enabled():
                    _metrics()["completions"].inc()
                ev = self._done_events.get(rid)
                if ev is not None:
                    ev.set()

    def _check_deadlines(self) -> None:
        """A request queued past its deadline migrates (admitted work is
        never preempted — the deadline bounds queueing, not decode)."""

        for p in list(self._pending.values()):
            if p.deadline is None or self._tick <= p.deadline or p.engine < 0:
                continue
            src = p.engine
            if not self._can_migrate(p):
                continue
            if len(self._candidates(frozenset({src}))) == 0:
                continue  # nowhere better to go
            if self._withdraw(p) is not None:
                self.stats.deadline_requeues += 1
                p.deadline = None  # one requeue per request; no thrash
                self._migrate(p, src, reason="deadline")

    def _retry_due(self) -> None:
        """Re-place requests lost to an engine death, past their backoff."""

        for p in list(self._pending.values()):
            if p.engine >= 0 or self._tick < p.retry_at:
                continue
            self.stats.retries += 1
            e = self._place(p)
            if T.enabled():
                _metrics()["retries"].inc()
                T.instant(
                    "fleet.retry", cat="fleet",
                    rid=p.rid, dst=e, attempt=p.attempts,
                )

    # -- fleet-level parking (PR 9's pod protocol, one level up) -------------

    def _capacity(self, engines: Sequence[int]) -> int:
        return sum(self.engines[i].n_slots for i in engines)

    def _offered_load(self) -> int:
        n = sum(
            1 for p in self._pending.values() if p.engine < 0
        )  # unplaced retries still need a seat
        for e, eng in enumerate(self.engines):
            if self._alive[e]:
                n += sum(len(q) for q in eng.queues)
                n += int((eng.slot_rid >= 0).sum())
        return n

    def _engines_by_efficiency(self) -> list[int]:
        """Alive engines, most energy-efficient first (modeled active
        watts per unit of calibrated throughput, ascending)."""

        alive = [i for i in range(self.n_engines) if self._alive[i]]
        return sorted(
            alive,
            key=lambda i: (self.powers[i] / max(self.scheduler.rates[i], 1e-12), i),
        )

    def _update_parking(self) -> None:
        if self.objective == "perf" or self.n_engines < 2:
            return
        h = self.scheduler.rebalance_threshold
        n_work = self._offered_load()
        order = self._engines_by_efficiency()
        if not order:
            return
        unparked = [i for i in order if i not in self._parked]
        # Re-admit most efficient first while capacity is short.
        for i in order:
            if self._capacity(unparked) >= n_work:
                break
            if i in self._parked:
                self._unpark(i)
                unparked = [j for j in order if j not in self._parked]
        # Park least efficient while the rest holds the load with margin.
        for i in reversed(order):
            if i in self._parked or len(unparked) <= 1 or i == order[0]:
                continue
            remaining = [j for j in unparked if j != i]
            if n_work <= self._capacity(remaining) * (1.0 - h):
                self._park(i)
                unparked = remaining
            else:
                break

    def _park(self, e: int) -> None:
        """Drain and gate one engine: queued requests migrate, routing
        excludes it, in-flight work finishes (parking never preempts)."""

        self._parked.add(e)
        self.stats.engine_parks += 1
        drained = 0
        for req in self.engines[e].export_queued():
            rid = self._rid_map[e].pop(req.rid, None)
            if rid is None:
                continue
            p = self._pending[rid]
            self._routed[e] -= 1
            p.engine, p.erid = -1, -1
            if self._can_migrate(p):
                self._migrate(p, e, reason="engine_park")
                drained += 1
            else:
                # Hand it back under a fresh engine rid: a parked engine
                # still admits what it kept (parking blocks routing, not
                # progress).
                erid = self.engines[e].submit(p.prompt, p.max_new_tokens)
                self._rid_map[e][erid] = rid
                self._routed[e] += 1
                p.engine, p.erid = e, erid
        if T.enabled():
            _metrics()["engines_parked"].set(len(self._parked))
            T.instant(
                "fleet.engine_park", cat="fleet", engine=e, drained=drained,
            )

    def _unpark(self, e: int) -> None:
        self._parked.discard(e)
        self.stats.engine_unparks += 1
        if T.enabled():
            _metrics()["engines_parked"].set(len(self._parked))
            T.instant("fleet.engine_unpark", cat="fleet", engine=e)

    # -- queued-request migration off saturated engines ----------------------

    def _migrate_from_saturated(self) -> None:
        """Move queued work from engines with a full slot table to
        engines with free budgeted capacity and an empty queue.

        "Saturated" is deliberately strict — queue behind a *full* slot
        table while another engine idles — so noise never thrashes
        requests back and forth; the deficit router already keeps the
        steady-state split proportional.  Unhealthy engines' queues
        drain wholesale (they are excluded from routing anyway).
        """

        cands = self._candidates()
        for e, eng in enumerate(self.engines):
            if not self._alive[e]:
                continue
            queued = [r for q in eng.queues for r in q]
            if not queued:
                continue
            drain_all = self._unhealthy[e] or e in self._parked
            if not drain_all:
                full = int((eng.slot_rid >= 0).sum()) >= eng.n_slots
                idle_room = sum(
                    max(
                        0,
                        self.engines[i].n_slots
                        - int((self.engines[i].slot_rid >= 0).sum())
                        - sum(len(q) for q in self.engines[i].queues),
                    )
                    for i in cands
                    if i != e
                )
                if not full or idle_room <= 0:
                    continue
                queued = queued[-min(len(queued), idle_room):]  # newest first out
            for req in queued:
                rid = self._rid_map[e].get(req.rid)
                if rid is None:
                    continue
                p = self._pending[rid]
                if not self._can_migrate(p):
                    continue
                if len(self._candidates(frozenset({e}))) == 0:
                    return
                if self._withdraw(p) is not None:
                    self._migrate(p, e, reason="saturation")

    # -- telemetry -----------------------------------------------------------

    def _record_tick_telemetry(self) -> None:
        m = _metrics()
        m["engines_alive"].set(sum(self._alive))
        m["engines_parked"].set(len(self._parked))
        for e, eng in enumerate(self.engines):
            if not self._alive[e]:
                continue
            m["queue_depth"].labels(engine=str(e)).set(
                sum(len(q) for q in eng.queues)
            )
            m["inflight"].labels(engine=str(e)).set(
                int((eng.slot_rid >= 0).sum())
            )

    # -- health surface ------------------------------------------------------

    def health(self) -> dict:
        """Fleet + per-engine health, one poll away."""

        return {
            "tick": self._tick,
            "alive": sum(self._alive),
            "parked": sorted(self._parked),
            "unhealthy": [
                i for i in range(self.n_engines) if self._unhealthy[i]
            ],
            "pending": len(self._pending),
            "engines": [
                self.engines[i].health() if self._alive[i] else {"dead": True}
                for i in range(self.n_engines)
            ],
        }

    # -- drive to completion -------------------------------------------------

    def run(self, *, max_ticks: Optional[int] = None) -> list[FleetCompletion]:
        """Tick until every pending request completes (exactly once).

        Returns the completions this call produced; cumulative history
        stays on :attr:`completions`.  Raises if every engine is dead
        with work pending, or if the fleet stops making progress —
        conservation failures must be loud, never silent drops.
        """

        start = len(self.completions)
        idle = 0
        while self._pending:
            if not any(self._alive):
                raise RuntimeError("all engines dead with requests pending")
            before = self.stats.completed
            self.tick()
            idle = 0 if self.stats.completed > before else idle + 1
            if idle > 10_000:
                raise RuntimeError(
                    "fleet made no progress for 10000 ticks "
                    f"({len(self._pending)} requests pending)"
                )
            if max_ticks is not None and self.stats.ticks >= max_ticks:
                break
        return self.completions[start:]

    def generate(self, prompts: np.ndarray, gen_len: int) -> np.ndarray:
        """Batch convenience mirroring :meth:`ServingEngine.generate`:
        returns ``(B, P + gen_len)`` tokens in submission order (rows
        stopped early by ``eos_id`` zero-padded)."""

        prompts = np.asarray(prompts, np.int32)
        rids = [self.submit(p, gen_len) for p in prompts]
        self.run()
        by_rid = {c.rid: c for c in self.completions}
        out = np.zeros((len(rids), prompts.shape[1] + gen_len), np.int32)
        for i, rid in enumerate(rids):
            toks = by_rid[rid].tokens
            out[i, : len(toks)] = toks
        return out

    # -- async surface -------------------------------------------------------

    async def submit_async(
        self, prompt, max_new_tokens: int, *, deadline: Optional[int] = None
    ) -> int:
        """Async twin of :meth:`submit` (placement is synchronous; the
        await point is for API symmetry with streaming clients)."""

        rid = self.submit(prompt, max_new_tokens, deadline=deadline)
        await asyncio.sleep(0)
        return rid

    async def complete_async(self, rid: int) -> FleetCompletion:
        """Wait for one request's completion (someone must be ticking —
        :meth:`run_async` or a driver loop)."""

        ev = self._done_events.setdefault(rid, asyncio.Event())
        if rid in self._completed_rids:
            ev.set()
        await ev.wait()
        return next(c for c in self.completions if c.rid == rid)

    async def stream(self, rid: int):
        """Async token stream: yields ``np.int32`` chunks of *generated*
        tokens as they appear, across migrations and retries — a retried
        request re-produces the identical prefix, so the stream never
        contradicts itself.  Ends when the request completes."""

        sent = 0
        while True:
            if rid in self._completed_rids:
                c = next(c for c in self.completions if c.rid == rid)
                gen = c.tokens[c.prompt_len:]
                if sent < len(gen):
                    yield gen[sent:]
                return
            p = self._pending.get(rid)
            if p is not None and p.engine >= 0 and self._alive[p.engine]:
                part = self.engines[p.engine].partial_tokens(p.erid)
                if part is not None and len(part) > sent:
                    yield part[sent:]
                    sent = len(part)
            await asyncio.sleep(0)

    async def run_async(
        self, *, max_ticks: Optional[int] = None
    ) -> list[FleetCompletion]:
        """Async twin of :meth:`run`, yielding to streamers between ticks."""

        start = len(self.completions)
        idle = 0
        while self._pending:
            if not any(self._alive):
                raise RuntimeError("all engines dead with requests pending")
            before = self.stats.completed
            self.tick()
            idle = 0 if self.stats.completed > before else idle + 1
            if idle > 10_000:
                raise RuntimeError("fleet made no progress for 10000 ticks")
            if max_ticks is not None and self.stats.ticks >= max_ticks:
                break
            await asyncio.sleep(0)
        return self.completions[start:]


__all__ = ["Fleet", "FleetCompletion", "FleetStats"]

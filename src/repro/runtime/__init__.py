"""runtime substrate: the training loop and the persistent serving engine."""

"""runtime substrate."""

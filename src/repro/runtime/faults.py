"""Deterministic fault injection for the serving fleet.

The fleet's fault tolerance is tested, not hoped for: a :class:`FaultPlan`
schedules named faults at exact fleet ticks, so every failure scenario is
a seed away and every test is reproducible bit-for-bit.  Fault *points*
are a closed vocabulary (:data:`FAULT_POINTS`) guarded by the static
verifier (RPR006, the RPR005 backend-drift pattern) — a typo'd point name
in a test or the fleet loop is a lint error, not a silently-never-firing
fault.

Off is free, mirroring ``observability.trace``'s contract: with no plan
armed, the hot path is one module-global ``None`` check
(:func:`fault_active`).  Injection never touches jitted token
computation — every fault is a *control-flow* perturbation (skip a tick,
kill an engine, suppress admission, inflate an observed time), which is
what lets the fleet keep its exactness contract: greedy decode is a
deterministic function of the prompt, so a retried or migrated request
reproduces the exact tokens a fault-free run would have produced.

Fault semantics (enforced by the fleet loop, documented here because the
vocabulary lives here):

``engine_stall``
    The engine neither admits nor steps for ``duration`` ticks — a hung
    host or a GC pause.  In-flight work freezes and resumes.
``pod_death``
    Permanent engine loss from ``tick`` on — one SPMD step spans all of
    an engine's pods, so losing a pod kills the whole engine's program.
    Queued requests migrate; in-flight requests retry from scratch on
    survivors.
``admission_fail``
    ``admit()`` is suppressed for ``duration`` ticks — an allocator or
    pool failure.  Decode of already-admitted work continues.
``latency_spike``
    The engine runs normally but the per-tick time the fleet scheduler
    observes is multiplied by ``factor`` — thermal throttling as seen by
    the calibration loop; DAS sheds share without any correctness event.
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
from typing import Iterable, Iterator, Optional, Sequence

FAULT_POINTS: dict[str, str] = {
    "engine_stall": "engine skips admission and decode for `duration` ticks",
    "pod_death": "permanent engine loss from `tick` on (SPMD program dies)",
    "admission_fail": "admit() suppressed for `duration` ticks",
    "latency_spike": "observed per-tick time multiplied by `factor`",
}


def validate_point(point: str) -> str:
    """Funnel for fault-point names; unknown names raise.

    Every runtime string that selects a fault point should pass through
    here (or appear as a literal the RPR006 lint can check).
    """

    if point not in FAULT_POINTS:
        raise ValueError(
            f"unknown fault point {point!r}; known: {sorted(FAULT_POINTS)}"
        )
    return point


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``point`` fires on ``engine`` at fleet ``tick``.

    ``duration`` covers ticks ``[tick, tick+duration)`` for transient
    points; ``pod_death`` is permanent and ignores it.  ``factor`` only
    matters for ``latency_spike``.
    """

    point: str
    engine: int
    tick: int
    duration: int = 1
    factor: float = 8.0

    def __post_init__(self):
        validate_point(self.point)
        if self.engine < 0:
            raise ValueError(f"engine must be >= 0, got {self.engine}")
        if self.tick < 0:
            raise ValueError(f"tick must be >= 0, got {self.tick}")
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")
        if not self.factor > 0:
            raise ValueError(f"factor must be > 0, got {self.factor}")

    def covers(self, tick: int) -> bool:
        if self.point == "pod_death":
            return tick >= self.tick
        return self.tick <= tick < self.tick + self.duration


class FaultPlan:
    """An immutable schedule of :class:`FaultEvent`\\ s.

    Arm with :func:`arm` (or the :func:`injected` context manager); the
    fleet consults :func:`fault_active` each tick.  Plans are data — the
    same plan against the same trace reproduces the same run exactly.
    """

    def __init__(self, events: Iterable[FaultEvent]):
        events = tuple(events)
        for ev in events:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"expected FaultEvent, got {type(ev).__name__}")
        self.events = tuple(
            sorted(events, key=lambda e: (e.tick, e.engine, e.point))
        )

    @classmethod
    def seeded(
        cls,
        seed: int,
        *,
        n_engines: int,
        horizon: int,
        n_events: int = 4,
        points: Optional[Sequence[str]] = None,
        keep_alive: bool = True,
    ) -> "FaultPlan":
        """A deterministic pseudo-random schedule (property-test fodder).

        ``keep_alive`` designates one engine that never receives a
        ``pod_death`` — the conservation property needs a survivor to
        drain onto.  Same ``seed`` and shape parameters ⇒ same plan.
        """

        if n_engines < 1:
            raise ValueError(f"n_engines must be >= 1, got {n_engines}")
        rng = random.Random(seed)
        pts = tuple(points) if points is not None else tuple(FAULT_POINTS)
        for p in pts:
            validate_point(p)
        survivor = rng.randrange(n_engines)
        events = []
        for _ in range(n_events):
            point = rng.choice(pts)
            engine = rng.randrange(n_engines)
            if point == "pod_death" and keep_alive and engine == survivor:
                if n_engines == 1:
                    continue  # sole engine is the survivor: drop the death
                engine = (engine + 1) % n_engines
            events.append(
                FaultEvent(
                    point=point,
                    engine=engine,
                    tick=rng.randrange(1, max(horizon, 2)),
                    duration=rng.randint(1, 3),
                    factor=float(rng.choice([4.0, 8.0, 16.0])),
                )
            )
        return cls(events)

    def active(self, point: str, engine: int, tick: int) -> Optional[FaultEvent]:
        """The event covering ``(point, engine, tick)``, or ``None``."""

        validate_point(point)
        for ev in self.events:
            if ev.point == point and ev.engine == engine and ev.covers(tick):
                return ev
        return None

    def __repr__(self):
        return f"FaultPlan({list(self.events)!r})"


# One module-global slot, mirroring trace._BUFFER: `_PLAN is None` is the
# entire disabled-path cost at every fault point.
_PLAN: Optional[FaultPlan] = None


def arm(plan: FaultPlan) -> FaultPlan:
    """Install ``plan`` as the active fault schedule."""

    global _PLAN
    if not isinstance(plan, FaultPlan):
        raise TypeError(f"expected FaultPlan, got {type(plan).__name__}")
    _PLAN = plan
    return plan


def disarm() -> Optional[FaultPlan]:
    """Remove the active plan (back to off-is-free); returns it."""

    global _PLAN
    plan, _PLAN = _PLAN, None
    return plan


def armed() -> bool:
    return _PLAN is not None


def fault_active(point: str, *, engine: int, tick: int) -> Optional[FaultEvent]:
    """The hot-path check: the covering event, or ``None``.

    With no plan armed this is a single module-global ``None`` test —
    the off-is-free contract the benchmarks gate.
    """

    plan = _PLAN
    if plan is None:
        return None
    return plan.active(point, engine, tick)


@contextlib.contextmanager
def injected(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Arm ``plan`` for the duration of a ``with`` block, then disarm."""

    arm(plan)
    try:
        yield plan
    finally:
        disarm()


__all__ = [
    "FAULT_POINTS",
    "FaultEvent",
    "FaultPlan",
    "arm",
    "armed",
    "disarm",
    "fault_active",
    "injected",
    "validate_point",
]

"""Fault-tolerant asymmetric training loop.

Composes every substrate:

  * model zoo loss fn (+ masked loss for padded asymmetric batches),
  * class-routed execution: on a multi-class mesh with a pod axis the
    step runs *class-sharded* — one shard_map program in which every
    pod's batch shard executes under its own class's control tree
    simultaneously (true CA-SAS, DESIGN.md §2) with a mask-weighted
    gradient psum keeping the update exact; otherwise the whole step
    traces under a single :class:`~repro.core.execution.ExecutionContext`
    (the asymmetric mesh's primary control tree by default) — either way
    no per-call config threading (DESIGN.md §3),
  * grad accumulation + AdamW (fp32 master params, sharded opt state),
  * checkpoint/restart: periodic async snapshots; any exception classified
    as a *node failure* triggers restore-from-latest and continue (the
    1000-node story: a failed host re-joins from the last committed step),
  * straggler mitigation: per-pod step-time observations feed the
    CA-DAS :class:`~repro.core.schedule.DynamicScheduler`, which re-derives
    the per-pod batch shares — the paper's dynamic scheduling at step
    granularity (Section 5.4 adapted to SPMD, see DESIGN.md),
  * elastic scaling: :meth:`Trainer.reshard` re-places state onto a new
    mesh (pods joining/leaving between steps).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ArchConfig
from repro.core.asymmetric import AsymmetricMesh
from repro.core.execution import ClassShardedFn, ExecutionContext
from repro.data.pipeline import AsymmetricBatcher, SyntheticLM
from repro.distributed import sharding as SH
from repro.models import model_zoo as Z
from repro.observability import metrics as MET
from repro.observability import trace as T
from repro.optim import adamw as O

_M = None


def _metrics():
    global _M
    if _M is None:
        _M = {
            "steps": MET.counter("trainer_steps_total", "Training steps completed"),
            "step_seconds": MET.histogram(
                "trainer_step_seconds", "Train step wall time (incl. compile)"),
        }
    return _M


class SimulatedFailure(RuntimeError):
    """Raised by failure-injection hooks to model a node loss."""


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    n_micro: int = 1
    fsdp: bool = True
    strategy: str = "ca-das"
    log_every: int = 10
    # True CA-SAS: per-class programs within one SPMD step (shard_map over
    # the pod axis).  None = auto (on when the asym mesh has >1 class and
    # the jax mesh has a matching pod axis); False = always the legacy
    # single-primary-class context; True = required (raises if the mesh
    # cannot support it).
    class_sharded: Optional[bool] = None


def _shard_weight(batch) -> jnp.ndarray:
    """Valid-token weight of a batch (or micro-batch): mask sum, or the
    row count when the batch carries no mask (every row valid)."""

    if "mask" in batch:
        return batch["mask"].sum().astype(jnp.float32)
    return jnp.float32(jax.tree.leaves(batch)[0].shape[0])


def _masked_micro_grads(loss_fn, params, batch, n_micro: int):
    """Micro-batch accumulation weighted by per-micro valid tokens.

    Returns the shard's *exact* masked mean ``(loss, metrics, grads)`` —
    ``Σ_j w_j·x_j / Σ_j w_j`` over micro-batches — so a fully-padded
    micro-batch contributes nothing and the cross-pod ``w_i/W`` scaling
    composes to the global masked mean.  (The plain
    ``accumulate_gradients`` takes the unweighted micro mean, which is
    only exact when every micro-batch has the same valid count.)
    """

    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(acc, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        w = _shard_weight(mb)
        acc_g, acc_l, acc_w = acc
        acc_g = jax.tree.map(lambda a, g: a + w * g.astype(jnp.float32), acc_g, grads)
        return (acc_g, acc_l + w * loss, acc_w + w), (metrics, w)

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc_g, acc_l, acc_w), (ms, ws) = jax.lax.scan(
        body, (zero_g, jnp.float32(0), jnp.float32(0)), micro
    )
    denom = jnp.maximum(acc_w, 1.0)
    grads = jax.tree.map(lambda g: g / denom, acc_g)
    metrics = jax.tree.map(lambda x: jnp.sum(x * ws) / denom, ms)
    return acc_l / denom, metrics, grads


def build_class_sharded_grad_step(
    loss_fn,
    asym: AsymmetricMesh,
    mesh,
    *,
    n_micro: int = 1,
    axis: str = "pod",
) -> ClassShardedFn:
    """``(params, batch) -> (loss, metrics, grads)`` with per-class programs.

    Each pod shard computes its *local* loss/grads under its own class's
    control tree (the switch branch traced under that class's execution
    context); the shared epilogue — outside the switch, so every pod
    participates — does the weighted cross-pod reduction that makes the
    result exactly the global masked mean: with ``w_i`` the shard's valid
    tokens and ``W = Σ w_i``, ``loss = Σ (w_i/W)·loss_i`` and likewise for
    the gradients (a pod with no valid rows contributes zero).

    With ``n_micro > 1`` the local accumulation weights each micro-batch
    by *its* valid tokens (``_masked_micro_grads``) rather than the plain
    unweighted micro mean: a shard's padding concentrates in its tail
    micro-batches, and the unweighted mean would deflate that shard's
    loss/grads before the ``w_i/W`` scaling double-counted the deficit.
    ``n_micro`` must divide the per-shard (not global) row count.
    """

    def local_grads(params, batch):
        if n_micro <= 1:
            return O.accumulate_gradients(loss_fn, params, batch, 1)
        return _masked_micro_grads(loss_fn, params, batch, n_micro)

    def weighted_mean_epilogue(out, shard_args, ax):
        if ax is None:  # single-class fallback: already the global mean
            return out
        loss, metrics, grads = out
        _, batch = shard_args
        w = _shard_weight(batch)
        total = jax.lax.psum(w, ax)
        scale = jnp.where(total > 0, w / jnp.maximum(total, 1.0), 0.0)
        loss, metrics = jax.tree.map(
            lambda x: jax.lax.psum(x * scale, ax), (loss, metrics)
        )
        grads = jax.tree.map(
            lambda g: jax.lax.psum((g * scale).astype(g.dtype), ax), grads
        )
        return loss, metrics, grads

    from jax.sharding import PartitionSpec as P

    return asym.class_sharded(
        local_grads,
        mesh=mesh,
        in_specs=(P(), P(axis)),          # params replicated, batch rows per pod
        out_specs=(P(), P(), P()),        # psum'd: replicated across pods
        axis=axis,
        epilogue=weighted_mean_epilogue,
    )


class Trainer:
    def __init__(
        self,
        arch: ArchConfig,
        mesh,
        *,
        tcfg: TrainerConfig,
        opt_cfg: Optional[O.AdamWConfig] = None,
        asym: Optional[AsymmetricMesh] = None,
        exec_ctx: Optional[ExecutionContext] = None,
        failure_hook: Optional[Callable[[int], None]] = None,
        pod_time_hook: Optional[Callable[[int], list]] = None,
        seed: int = 0,
    ):
        self.arch = arch
        self.mesh = mesh
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or O.AdamWConfig(total_steps=tcfg.steps)
        self.asym = asym
        # Ambient context for the *non*-class-sharded paths (init, and the
        # whole step when the mixed path is off): the asymmetric mesh's
        # primary (fastest) class, which anchors the shared B panel; with
        # no asym mesh the pre-context defaults apply unchanged.  Under
        # the class-sharded step each shard_map branch activates its own
        # class's context on top of this one (innermost wins).
        self.exec_ctx = exec_ctx if exec_ctx is not None else (
            asym.execution_context() if asym is not None else None
        )
        self.failure_hook = failure_hook
        self.pod_time_hook = pod_time_hook
        self.ckpt = Checkpointer(tcfg.ckpt_dir)
        self.restarts = 0
        self.seed = seed

        self.data = SyntheticLM(vocab=arch.vocab, seed=seed)
        self.batcher = AsymmetricBatcher(self.data, asym) if asym else None

        self._build()

    def _execution(self):
        """The ambient execution context for tracing/running the step."""

        return self.exec_ctx if self.exec_ctx is not None else contextlib.nullcontext()

    def class_sharded_enabled(self) -> bool:
        """Is the per-class-programs (shard_map) step path active?

        Auto mode requires a multi-class asym mesh *and* a jax mesh whose
        ``pod`` axis matches the pod count; ``class_sharded=True`` makes a
        mismatch an error instead of a silent fallback.
        """

        flag = self.tcfg.class_sharded
        if flag is False or self.asym is None:
            return False
        shape = dict(getattr(self.mesh, "shape", {}))
        ok = (
            len(self.asym.classes) > 1
            and shape.get("pod") == self.asym.n_pods
        )
        if flag is True and not ok:
            raise ValueError(
                "class_sharded=True requires a multi-class AsymmetricMesh "
                f"and a mesh pod axis of size {self.asym.n_pods if self.asym else '?'}; "
                f"mesh axes={shape}"
            )
        if flag is None:
            # Auto mode only takes the fully-manual shard_map when it is
            # free: non-pod axes of extent 1 (one device per pod).  Wider
            # pods would replicate each pod's program across its devices
            # (correct but redundant) — require the explicit flag for that.
            intra = 1
            for a, s in shape.items():
                if a != "pod":
                    intra *= s
            ok = ok and intra == 1
        return ok

    # -- compilation --------------------------------------------------------

    def _make_train_step(self):
        """The (un-jitted) step fn; per-class-sharded when the mesh allows."""

        loss_fn = Z.make_loss_fn(self.arch)
        opt_cfg, n_micro = self.opt_cfg, self.tcfg.n_micro

        if self.class_sharded_enabled():
            # True CA-SAS: every pod's shard of the batch runs under its
            # own class's control tree inside one shard_map step; the
            # weighted psum epilogue keeps gradients exactly the global
            # masked mean.  The optimizer update happens outside the
            # shard_map on the already-reduced gradients.
            grad_fn = build_class_sharded_grad_step(
                loss_fn, self.asym, self.mesh, n_micro=n_micro
            )
            self.class_sharded_step = grad_fn

            def train_step(params, opt_state, batch):
                loss, metrics, grads = grad_fn(params, batch)
                params, opt_state, om = O.adamw_update(params, grads, opt_state, opt_cfg)
                metrics = dict(metrics)
                metrics.update(om)
                metrics["loss"] = loss
                return params, opt_state, metrics

            return train_step

        self.class_sharded_step = None

        def train_step(params, opt_state, batch):
            loss, metrics, grads = O.accumulate_gradients(loss_fn, params, batch, n_micro)
            params, opt_state, om = O.adamw_update(params, grads, opt_state, opt_cfg)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, metrics

        return train_step

    def _build(self):
        arch, mesh = self.arch, self.mesh
        abstract = jax.eval_shape(
            lambda k: Z.init_params(k, arch), jax.random.PRNGKey(self.seed)
        )
        self.param_sharding = SH.shard_params(abstract, mesh, fsdp=self.tcfg.fsdp)
        self.opt_sharding = SH.shard_opt_state(None, self.param_sharding, mesh)

        with mesh, self._execution():
            self.params = jax.jit(
                lambda k: Z.init_params(k, arch), out_shardings=self.param_sharding
            )(jax.random.PRNGKey(self.seed))
            self.opt_state = jax.jit(
                O.init_opt_state, out_shardings=self.opt_sharding
            )(self.params)

        self.train_step = jax.jit(
            self._make_train_step(),
            out_shardings=(self.param_sharding, self.opt_sharding, None),
            donate_argnums=(0, 1),
        )
        self.step = 0

    # -- data ---------------------------------------------------------------

    def _next_batch(self, step: int):
        if self.batcher is not None:
            bw = self.batcher.batch(step, self.tcfg.global_batch, self.tcfg.seq_len)
            arrays, layout = bw.arrays, bw.layout
        else:
            arrays = self.data.batch(step, self.tcfg.global_batch, self.tcfg.seq_len)
            layout = None
        shardings = SH.batch_sharding(self.mesh, arrays)
        batch = jax.tree.map(lambda a, s: jax.device_put(a, s), dict(arrays), shardings)
        return batch, layout

    # -- fault tolerance ------------------------------------------------------

    def _checkpoint(self):
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"restarts": self.restarts},
        )

    def _restart(self):
        """Restore the latest committed state (node-failure recovery)."""

        self.restarts += 1
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": self.params, "opt": self.opt_state},
        )
        tree, manifest = self.ckpt.restore(
            target,
            shardings={"params": self.param_sharding, "opt": self.opt_sharding},
        )
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = int(manifest["step"])

    def reshard(self, new_mesh):
        """Elastic scaling: re-place all state onto a new mesh."""

        host = jax.tree.map(np.asarray, {"params": self.params, "opt": self.opt_state})
        self.mesh = new_mesh
        self.param_sharding = SH.shard_params(host["params"], new_mesh, fsdp=self.tcfg.fsdp)
        self.opt_sharding = SH.shard_opt_state(None, self.param_sharding, new_mesh)
        self.params = jax.tree.map(jax.device_put, host["params"], self.param_sharding)
        self.opt_state = jax.tree.map(
            jax.device_put, host["opt"],
            {"m": self.param_sharding, "v": self.param_sharding,
             "step": SH.replicated(new_mesh)},
        )
        self._build_step_only()

    def _build_step_only(self):
        self.train_step = jax.jit(
            self._make_train_step(),
            out_shardings=(self.param_sharding, self.opt_sharding, None),
            donate_argnums=(0, 1),
        )

    # -- main loop ------------------------------------------------------------

    def run(self, steps: Optional[int] = None):
        steps = steps if steps is not None else self.tcfg.steps
        history = []
        self._checkpoint()  # step-0 baseline so any failure can restore
        while self.step < steps:
            try:
                if self.failure_hook is not None:
                    self.failure_hook(self.step)
                batch, layout = self._next_batch(self.step)
                t0 = time.perf_counter()
                # The context is active while jit traces (first call) — that
                # is when ops.gemm resolves its backend and block shapes.
                with self.mesh, self._execution():
                    self.params, self.opt_state, metrics = self.train_step(
                        self.params, self.opt_state, batch
                    )
                metrics = jax.tree.map(float, metrics)
                dt = time.perf_counter() - t0
                if T.enabled():
                    m = _metrics()
                    T.complete("trainer.step", t0, dt, cat="trainer",
                               step=self.step, loss=metrics.get("loss"))
                    m["steps"].inc()
                    m["step_seconds"].observe(dt)

                # Straggler feedback: measured (or injected) per-pod times
                # re-derive the next step's chunk table (CA-DAS).
                if self.asym is not None and layout is not None:
                    times = (
                        self.pod_time_hook(self.step)
                        if self.pod_time_hook is not None
                        else [dt] * len(layout.sizes)
                    )
                    self.asym.observe_step(layout.sizes, times)

                self.step += 1
                history.append(metrics)
                if self.step % self.tcfg.ckpt_every == 0:
                    self._checkpoint()
            except SimulatedFailure:
                self._restart()
        self.ckpt.wait()
        return history


__all__ = [
    "Trainer",
    "TrainerConfig",
    "SimulatedFailure",
    "build_class_sharded_grad_step",
]

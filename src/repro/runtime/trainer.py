"""Fault-tolerant asymmetric training loop.

Composes every substrate:

  * model zoo loss fn (+ masked loss for padded asymmetric batches),
  * class-routed execution: the whole step traces under an
    :class:`~repro.core.execution.ExecutionContext` (the asymmetric
    mesh's primary control tree by default), so every projection/FFN/
    lm-head matmul resolves its backend and block config from the
    paper's per-class mechanism — no per-call threading (DESIGN.md §3),
  * grad accumulation + AdamW (fp32 master params, sharded opt state),
  * checkpoint/restart: periodic async snapshots; any exception classified
    as a *node failure* triggers restore-from-latest and continue (the
    1000-node story: a failed host re-joins from the last committed step),
  * straggler mitigation: per-pod step-time observations feed the
    CA-DAS :class:`~repro.core.schedule.DynamicScheduler`, which re-derives
    the per-pod batch shares — the paper's dynamic scheduling at step
    granularity (Section 5.4 adapted to SPMD, see DESIGN.md),
  * elastic scaling: :meth:`Trainer.reshard` re-places state onto a new
    mesh (pods joining/leaving between steps).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ArchConfig
from repro.core.asymmetric import AsymmetricMesh
from repro.core.execution import ExecutionContext
from repro.data.pipeline import AsymmetricBatcher, SyntheticLM
from repro.distributed import sharding as SH
from repro.models import model_zoo as Z
from repro.optim import adamw as O


class SimulatedFailure(RuntimeError):
    """Raised by failure-injection hooks to model a node loss."""


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    n_micro: int = 1
    fsdp: bool = True
    strategy: str = "ca-das"
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        arch: ArchConfig,
        mesh,
        *,
        tcfg: TrainerConfig,
        opt_cfg: Optional[O.AdamWConfig] = None,
        asym: Optional[AsymmetricMesh] = None,
        exec_ctx: Optional[ExecutionContext] = None,
        failure_hook: Optional[Callable[[int], None]] = None,
        pod_time_hook: Optional[Callable[[int], list]] = None,
        seed: int = 0,
    ):
        self.arch = arch
        self.mesh = mesh
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or O.AdamWConfig(total_steps=tcfg.steps)
        self.asym = asym
        # Every matmul in the step runs under this context (paper §5.3:
        # the executing class's control tree).  Defaults to the asymmetric
        # mesh's primary (fastest) class — the single SPMD program is
        # configured for the class that anchors the shared B panel; with
        # no asym mesh the pre-context defaults apply unchanged.
        self.exec_ctx = exec_ctx if exec_ctx is not None else (
            asym.execution_context() if asym is not None else None
        )
        self.failure_hook = failure_hook
        self.pod_time_hook = pod_time_hook
        self.ckpt = Checkpointer(tcfg.ckpt_dir)
        self.restarts = 0
        self.seed = seed

        self.data = SyntheticLM(vocab=arch.vocab, seed=seed)
        self.batcher = AsymmetricBatcher(self.data, asym) if asym else None

        self._build()

    def _execution(self):
        """The ambient execution context for tracing/running the step."""

        return self.exec_ctx if self.exec_ctx is not None else contextlib.nullcontext()

    # -- compilation --------------------------------------------------------

    def _build(self):
        arch, mesh = self.arch, self.mesh
        abstract = jax.eval_shape(
            lambda k: Z.init_params(k, arch), jax.random.PRNGKey(self.seed)
        )
        self.param_sharding = SH.shard_params(abstract, mesh, fsdp=self.tcfg.fsdp)
        self.opt_sharding = SH.shard_opt_state(None, self.param_sharding, mesh)

        with mesh, self._execution():
            self.params = jax.jit(
                lambda k: Z.init_params(k, arch), out_shardings=self.param_sharding
            )(jax.random.PRNGKey(self.seed))
            self.opt_state = jax.jit(
                O.init_opt_state, out_shardings=self.opt_sharding
            )(self.params)

        loss_fn = Z.make_loss_fn(arch)
        opt_cfg, n_micro = self.opt_cfg, self.tcfg.n_micro

        def train_step(params, opt_state, batch):
            loss, metrics, grads = O.accumulate_gradients(loss_fn, params, batch, n_micro)
            params, opt_state, om = O.adamw_update(params, grads, opt_state, opt_cfg)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, metrics

        self.train_step = jax.jit(
            train_step,
            out_shardings=(self.param_sharding, self.opt_sharding, None),
            donate_argnums=(0, 1),
        )
        self.step = 0

    # -- data ---------------------------------------------------------------

    def _next_batch(self, step: int):
        if self.batcher is not None:
            bw = self.batcher.batch(step, self.tcfg.global_batch, self.tcfg.seq_len)
            arrays, layout = bw.arrays, bw.layout
        else:
            arrays = self.data.batch(step, self.tcfg.global_batch, self.tcfg.seq_len)
            layout = None
        shardings = SH.batch_sharding(self.mesh, arrays)
        batch = jax.tree.map(lambda a, s: jax.device_put(a, s), dict(arrays), shardings)
        return batch, layout

    # -- fault tolerance ------------------------------------------------------

    def _checkpoint(self):
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"restarts": self.restarts},
        )

    def _restart(self):
        """Restore the latest committed state (node-failure recovery)."""

        self.restarts += 1
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": self.params, "opt": self.opt_state},
        )
        tree, manifest = self.ckpt.restore(
            target,
            shardings={"params": self.param_sharding, "opt": self.opt_sharding},
        )
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = int(manifest["step"])

    def reshard(self, new_mesh):
        """Elastic scaling: re-place all state onto a new mesh."""

        host = jax.tree.map(np.asarray, {"params": self.params, "opt": self.opt_state})
        self.mesh = new_mesh
        self.param_sharding = SH.shard_params(host["params"], new_mesh, fsdp=self.tcfg.fsdp)
        self.opt_sharding = SH.shard_opt_state(None, self.param_sharding, new_mesh)
        self.params = jax.tree.map(jax.device_put, host["params"], self.param_sharding)
        self.opt_state = jax.tree.map(
            jax.device_put, host["opt"],
            {"m": self.param_sharding, "v": self.param_sharding,
             "step": SH.replicated(new_mesh)},
        )
        self._build_step_only()

    def _build_step_only(self):
        loss_fn = Z.make_loss_fn(self.arch)
        opt_cfg, n_micro = self.opt_cfg, self.tcfg.n_micro

        def train_step(params, opt_state, batch):
            loss, metrics, grads = O.accumulate_gradients(loss_fn, params, batch, n_micro)
            params, opt_state, om = O.adamw_update(params, grads, opt_state, opt_cfg)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, metrics

        self.train_step = jax.jit(
            train_step,
            out_shardings=(self.param_sharding, self.opt_sharding, None),
            donate_argnums=(0, 1),
        )

    # -- main loop ------------------------------------------------------------

    def run(self, steps: Optional[int] = None):
        steps = steps if steps is not None else self.tcfg.steps
        history = []
        self._checkpoint()  # step-0 baseline so any failure can restore
        while self.step < steps:
            try:
                if self.failure_hook is not None:
                    self.failure_hook(self.step)
                batch, layout = self._next_batch(self.step)
                t0 = time.perf_counter()
                # The context is active while jit traces (first call) — that
                # is when ops.gemm resolves its backend and block shapes.
                with self.mesh, self._execution():
                    self.params, self.opt_state, metrics = self.train_step(
                        self.params, self.opt_state, batch
                    )
                metrics = jax.tree.map(float, metrics)
                dt = time.perf_counter() - t0

                # Straggler feedback: measured (or injected) per-pod times
                # re-derive the next step's chunk table (CA-DAS).
                if self.asym is not None and layout is not None:
                    times = (
                        self.pod_time_hook(self.step)
                        if self.pod_time_hook is not None
                        else [dt] * len(layout.sizes)
                    )
                    self.asym.observe_step(layout.sizes, times)

                self.step += 1
                history.append(metrics)
                if self.step % self.tcfg.ckpt_every == 0:
                    self._checkpoint()
            except SimulatedFailure:
                self._restart()
        self.ckpt.wait()
        return history


__all__ = ["Trainer", "TrainerConfig", "SimulatedFailure"]

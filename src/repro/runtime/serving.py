"""Persistent asymmetric serving runtime: slot table + per-class queues.

The serving-side analogue of the trainer's class-sharded step, and the
direct transplant of the paper's §5.4 insight: workers *keep* their
assignments between micro-kernel grabs instead of re-partitioning the
whole problem every iteration.  The one-shot path (``launch/serve.py
--one-shot``) does the opposite — it re-pads the request batch per the
chunk table on every generate call and replays prompts token-by-token
through per-call jit dispatches, each of which copies the full decode
state.  This engine amortizes all of it:

  * **Fixed pod-major slot table** — ``n_pods × c_max`` decode slots.
    Pod *i* owns the contiguous slot region ``[i·c_max, (i+1)·c_max)``;
    on a multi-class mesh the jitted step runs class-sharded
    (``AsymmetricMesh.class_sharded``), so each pod decodes its region
    under its own class's control tree — two micro-kernel programs in one
    SPMD step, ``ShardProvenance``-proven, exactly as in training.
  * **Paged KV pool** (``paged="auto"|"on"``) — instead of one dense
    ``seq_cap`` KV lane per slot, the engine owns a fixed arena of
    fixed-size pages (:mod:`repro.runtime.paging`) and each slot holds a
    page-index list; memory scales with live tokens.  Pages are reserved
    all-or-nothing at admission (``ceil(min(prompt+max_new, s_cache) /
    page_size)`` — no mid-stream exhaustion; admission defers instead)
    and returned the moment a slot retires, EOS- or budget-stopped.  The
    page size is a per-class tunable defaulting from the classes' tuned
    block configs (min ``bm``), the granularity the paper's §3.3
    configuration step already derived for the memory hierarchy.
  * **Continuous batching** — one admission round takes *mixed-length*
    prompts from every queue head: prompts are right-padded to the round
    maximum and the fused bulk prefill selects each row's logits at its
    own last real token (``plens``), so heterogeneous requests admit in
    a single fused call instead of one round per length.
  * **Per-token EOS stopping** (``eos_id``) — a slot emitting EOS retires
    mid-stream (its pages return to the pool immediately); stats count
    ``completed_eos`` and ``completed_budget`` separately.
  * **Per-class request queues + admission router** — requests are routed
    to a class queue at submit time (largest-remainder over calibrated
    throughput shares, so the split tracks the chunk table), and admitted
    into free slots of that class's region between steps.  Once running,
    a request never moves: steady-state decode performs **zero host
    relayout** (no ``pad_requests``, no chunk-table re-derivation in the
    loop — asserted by tests).
  * **Donated decode state** — the slot state (dense lanes or page
    arena) is threaded through the jitted step with ``donate_argnums``,
    so the caches update in place instead of being copied every token.
  * **Fused bulk prefill** — one jitted program consumes the whole
    (padded) prompt batch and writes the admitted slots' cache lanes,
    bit-identical to the token-by-token replay.  The paged engine
    prefills *in place* through the same page tables it decodes through
    (donated arena; busy slots' rows are pointed at phantom pages so
    their live pages cannot be touched).
  * **Rebalance hysteresis** — per-pod step timings feed
    ``DynamicScheduler.observe``; slot-region budgets re-derive *only*
    past the scheduler's drift threshold, and only between steps.
  * **Load-adaptive parking** (``AsymmetricMesh(objective="energy"|"edp")``)
    — at low offered load the engine parks the least energy-efficient
    pods (zero slot budget, modeled gated watts) and serves from the
    efficient ones; past a hysteresis threshold on offered load the
    parked pods re-admit.  Modeled ``energy_j`` / ``tokens_per_j``
    accumulate per decode step from the class specs' PowerModels —
    deterministic, host-independent figures the serving bench gates on.
    The default ``perf`` objective never parks and stays bit-identical.

Exactness contract (tested in tests/test_paged_serving.py): the paged
engine's tokens are **bit-identical** to the dense slot-table engine's
for the same requests.  Free-but-refreshed lanes decode the same pad
streams as the dense engine's phantom rows through *phantom pages* — one
shared lane per pod for row-local archs (all pad writers are identical),
one private lane per slot for MoE archs (capacity routing can
differentially drop identical pad rows, so they must own their content
exactly as dense lanes do).  Retired-but-not-refreshed lanes are marked
dead via the ``live`` mask in *both* engines (their attention output is
zeroed, making their rows cache-independent), which is what lets the
paged engine free a retired slot's pages immediately without its dead
lane diverging from the dense engine's.
"""

from __future__ import annotations

import collections
import dataclasses
import inspect
import time
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig
from repro.core.asymmetric import AsymmetricMesh
from repro.core.schedule import deficit_route
from repro.distributed import sharding as SH
from repro.models import model_zoo as Z
from repro.models import transformer as TX
from repro.observability import metrics as MET
from repro.observability import trace as T
from repro.runtime.paging import PagePool, PageSpec, SENTINEL, divisor_page_size

_M = None


def _metrics():
    """Engine metric families, registered once on first enabled use."""

    global _M
    if _M is None:
        _M = {
            "queue_depth": MET.gauge(
                "engine_queue_depth", "Requests waiting per class queue",
                labels=("device_class",)),
            "slot_occupancy": MET.gauge(
                "engine_slot_occupancy", "Active decode slots per pod",
                labels=("pod",)),
            "admissions": MET.counter(
                "engine_admissions_total", "Requests admitted into slots",
                labels=("device_class",)),
            "tokens": MET.counter(
                "engine_tokens_total", "Tokens generated by decode steps"),
            "tokens_per_s": MET.gauge(
                "engine_tokens_per_s", "Decode throughput EMA (tokens/s)"),
            "step_seconds": MET.histogram(
                "engine_decode_step_seconds", "Decode step wall time"),
            "rebalances": MET.counter(
                "engine_rebalances_total",
                "Slot-budget re-derivations past the drift hysteresis"),
            "kv_pages_free": MET.gauge(
                "engine_kv_pool_pages_free",
                "Unallocated pages in the KV page pool"),
            "kv_pages_live": MET.gauge(
                "engine_kv_pool_pages_live",
                "Allocated pages in the KV page pool"),
            "page_allocs": MET.counter(
                "engine_page_allocs_total",
                "KV pages allocated at admission",
                labels=("device_class",)),
            "modeled_watts": MET.gauge(
                "engine_modeled_watts",
                "Modeled power draw over the last decode step (W)"),
            "pods_parked": MET.gauge(
                "engine_pods_parked",
                "Pods currently parked (power-gated) by the energy objective"),
        }
    return _M


# Modeled wall seconds for one slot-row of decode work on a pod of unit
# aggregate throughput (``rel_throughput × chips_per_pod == 1``).  The
# absolute scale is arbitrary — only ratios between pods matter for the
# modeled energy/throughput columns — but a fixed constant keeps the
# figures deterministic across hosts (unlike wall clocks).
MODELED_ROW_S = 1e-3


def _hook_takes_units(hook) -> bool:
    """Does a pod_time_hook accept ``(step, pod_units)`` (new style) or
    just ``(step)`` (the legacy test-lambda signature)?"""

    try:
        sig = inspect.signature(hook)
    except (TypeError, ValueError):
        return False
    n = 0
    for p in sig.parameters.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n += 1
        elif p.kind == p.VAR_POSITIONAL:
            return True
    return n >= 2


def _paged_supported(cfg: ArchConfig) -> tuple[bool, str]:
    """Can this arch's decode state page?  (pure KV-cache families only)"""

    if TX.block_kind(cfg) == "mamba":
        return False, "recurrent (Mamba2) state has no KV pages to allocate"
    if cfg.shared_attn_every:
        return False, "the hybrid shared-attention cache is not paged"
    return True, ""


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued generation request."""

    rid: int
    prompt: np.ndarray        # (P,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    """A finished request: prompt + generated tokens, and where it ran."""

    rid: int
    tokens: np.ndarray        # (P + n_generated,) int32
    prompt_len: int
    slot: int                 # global slot id (pod-major)
    pod: int
    device_class: str
    stop: str = "budget"      # "budget" | "eos"


@dataclasses.dataclass
class EngineStats:
    """Timing/behavior counters (compile vs steady state split out)."""

    compile_s: float = 0.0        # first prefill + first decode step (tracing+XLA)
    prefill_s: float = 0.0        # steady-state bulk prefill seconds
    decode_s: float = 0.0         # steady-state decode seconds (warmup excluded)
    decode_steps: int = 0         # steady-state steps counted in decode_s
    tokens: int = 0               # tokens generated in steady-state steps
    admitted: int = 0
    completed: int = 0
    completed_eos: int = 0        # retired by emitting eos_id
    completed_budget: int = 0     # retired by exhausting max_new_tokens
    admission_rounds: int = 0
    admission_deferrals: int = 0  # admissions deferred by page-pool exhaustion
    # Host relayouts performed by the decode loop.  Structurally zero: the
    # engine has no relayout site after admission (requests keep their
    # slot), which tests/test_serving.py enforces by *poisoning*
    # pad_requests / chunk_table / batch_layout and running the loop — the
    # counter exists for the JSON reporting contract, not as the guard.
    host_relayouts: int = 0
    rebalances: int = 0           # slot-budget re-derivations past hysteresis
    # Modeled (power-model clock, not wall clock) energy accounting over
    # the steady-state decode steps; deterministic across hosts.
    energy_j: float = 0.0         # modeled joules burned by decode steps
    modeled_decode_s: float = 0.0 # modeled decode seconds those joules cover
    pod_parks: int = 0            # pods parked by the energy objective
    pod_unparks: int = 0          # pods re-admitted as load ramped

    @property
    def tokens_per_s(self) -> float:
        """Steady-state decode throughput (compile/warmup excluded)."""

        return self.tokens / self.decode_s if self.decode_s > 0 else 0.0

    @property
    def tokens_per_j(self) -> float:
        """Modeled energy efficiency of steady-state decode."""

        return self.tokens / self.energy_j if self.energy_j > 0 else 0.0

    @property
    def modeled_tokens_per_s(self) -> float:
        """Throughput on the modeled clock (deterministic across hosts)."""

        return self.tokens / self.modeled_decode_s if self.modeled_decode_s > 0 else 0.0

    def snapshot(self) -> dict:
        """Every counter plus the derived throughput, JSON-serializable —
        the one reporting surface (serve.py and the metrics snapshot both
        consume this instead of mirroring the field list by hand)."""

        out = dataclasses.asdict(self)
        out["tokens_per_s"] = round(self.tokens_per_s, 3)
        out["tokens_per_j"] = round(self.tokens_per_j, 3)
        out["modeled_tokens_per_s"] = round(self.modeled_tokens_per_s, 3)
        return out


class ServingEngine:
    """Persistent slot-table serving engine over an :class:`AsymmetricMesh`.

    Parameters
    ----------
    cfg, params : the model (token-in archs only — serving contract).
    asym : the asymmetric mesh (scheduling state; per-class control trees).
    seq_cap : per-slot cache length (prompt + generation must fit).
    slots_per_pod : ``c_max`` — each pod's fixed slot-region size.
    mesh : jax Mesh with a ``pod`` axis for the class-sharded mixed step;
        built automatically (host mesh) when class_sharded resolves on.
    class_sharded : "auto" | "on" | "off" — as in launch/serve.py.
    donate : donate the decode state through the jitted step (in-place
        cache updates).  Off only for the A/B test of the donation path.
    paged : "off" (default) | "auto" | "on" — replace the dense per-slot
        KV lanes with the paged pool.  "auto" pages every pure KV-cache
        family and silently stays dense where paging is unsupported
        (Mamba2 / hybrid shared-attention state); "on" raises there.
    page_size : tokens per page.  Default: the min tuned ``block.bm``
        across the mesh's classes, rounded down to a divisor of the
        logical cache length (the table width must satisfy
        ``W · page_size == s_cache`` exactly — the bit-identity contract).
    pool_pages : physical pages per pod partition.  Default: enough for
        every slot's full lane plus the phantom lanes (never defers);
        size it below that to trade admission deferrals for memory.
    eos_id : token id that stops a request mid-stream (its slot retires
        and — paged — its pages free immediately).  None disables.
    pod_time_hook : feeds the scheduler's straggler calibration.  The
        default ``"auto"`` installs a
        :class:`~repro.observability.probe.StepTimeProbe` that measures
        each class's real per-row cost — but only while observability is
        enabled (otherwise it returns ``None`` and the calibration stays
        frozen, keeping the disabled path free).  A callable may take
        ``(step)`` (legacy) or ``(step, pod_units)`` and may return
        ``None`` to skip a step; ``None`` disables the feedback entirely
        — one SPMD step cannot be attributed per pod from the host.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        asym: AsymmetricMesh,
        *,
        seq_cap: int,
        slots_per_pod: int = 4,
        mesh=None,
        class_sharded: str = "auto",
        donate: bool = True,
        paged: Union[str, bool] = "off",
        page_size: Optional[int] = None,
        pool_pages: Optional[int] = None,
        eos_id: Optional[int] = None,
        pod_time_hook: Union[str, None, Callable[..., Optional[Sequence[float]]]] = "auto",
    ):
        if cfg.embed_inputs or cfg.family == "encdec":
            raise ValueError(f"{cfg.name}: the serving engine targets token-in archs")
        if class_sharded not in ("auto", "on", "off"):
            raise ValueError(f"class_sharded={class_sharded!r}")
        if isinstance(paged, bool):
            paged = "on" if paged else "off"
        if paged not in ("auto", "on", "off"):
            raise ValueError(f"paged={paged!r}")
        self.cfg = cfg
        self.params = params
        self.asym = asym
        self.seq_cap = int(seq_cap)
        self.c_max = int(slots_per_pod)
        self.n_pods = asym.n_pods
        self.n_slots = self.n_pods * self.c_max
        self.donate = bool(donate)
        self.eos_id = None if eos_id is None else int(eos_id)
        if pod_time_hook == "auto":
            from repro.observability.probe import StepTimeProbe

            pod_time_hook = StepTimeProbe(asym)
        self.pod_time_hook = pod_time_hook
        self._hook_takes_units = (
            _hook_takes_units(pod_time_hook) if pod_time_hook is not None else False
        )
        self._tps_ema: Optional[float] = None
        self._shard_tags_cache: Optional[list[dict]] = None

        self.mixed = (
            class_sharded != "off"
            and len(asym.classes) > 1
            and jax.device_count() >= asym.n_pods
        )
        if class_sharded == "on" and not self.mixed:
            raise ValueError(
                f"class_sharded='on' needs {asym.n_pods} devices, "
                f"have {jax.device_count()}"
            )
        if self.mixed and mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(pod=asym.n_pods)
        self.mesh = mesh

        # -- per-class request queues fed by the admission router ----------
        self.queues: list[collections.deque] = [
            collections.deque() for _ in asym.classes
        ]
        self._routed = [0] * len(asym.classes)  # total ever routed per class
        self._next_rid = 0
        self._pod_class = asym.pod_class_indices()

        # -- host-side slot bookkeeping (the device never sees it) ---------
        self.slot_rid = np.full(self.n_slots, -1, np.int64)     # -1 = free
        self.slot_pos = np.zeros(self.n_slots, np.int64)        # next abs position
        self.slot_remaining = np.zeros(self.n_slots, np.int64)
        self._slot_req: dict[int, Request] = {}
        self._slot_toks: dict[int, list[int]] = {}
        self.budgets = [0] * self.n_pods
        self.completions: list[Completion] = []
        self.stats = EngineStats()
        self._rebalances0 = asym.scheduler.rebalances
        # -- load-adaptive parking + modeled power (energy objective) ------
        # Parked pods draw a zero slot budget and model gated watts; the
        # ``perf`` objective never parks, keeping today's behavior
        # bit-identical.  Per-pod watts are precomputed from the class
        # specs' PowerModels (see core/blocking.py).
        self._parked: set[int] = set()
        self._active_w = asym.pod_active_watts()
        self._idle_w = asym.pod_idle_watts()
        self._poll_w = asym.pod_poll_watts()
        self._gated_w = asym.pod_gated_watts()
        self._pod_agg = [
            asym.class_of_pod(p).rel_throughput * asym.class_of_pod(p).chips_per_pod
            for p in range(self.n_pods)
        ]
        # Lane liveness: True for busy slots and for free lanes refreshed
        # as pad streams at the last admission; False for retired-but-not-
        # refreshed lanes, whose attention output both engines zero.
        self._live = np.zeros(self.n_slots, bool)
        self._pod_of_row = np.arange(self.n_slots) // self.c_max

        # -- KV storage: dense per-slot lanes or the paged pool ------------
        supported, why = _paged_supported(cfg)
        if paged == "on" and not supported:
            raise ValueError(f"paged='on': {cfg.name}: {why}")
        self.paged = paged == "on" or (paged == "auto" and supported)
        self.s_cache = TX.cache_len(cfg, self.seq_cap) if supported else self.seq_cap
        if self.paged:
            if page_size is None:
                try:
                    page_size = min(
                        t.block.bm for t in asym.control_trees().values()
                    )
                except Exception:
                    page_size = 16
            ps = divisor_page_size(self.s_cache, page_size)
            w = self.s_cache // ps
            # MoE capacity routing couples batch rows: pad lanes must own
            # their phantom content like dense lanes do (see paging.py).
            per_slot_phantom = TX.block_kind(cfg) == "attn_moe"
            phantom_per_pod = self.c_max if per_slot_phantom else 1
            if pool_pages is None:
                pool_pages = (self.c_max + phantom_per_pod) * w
            spec = PageSpec(
                page_size=ps, pages_per_slot=w,
                pages_per_pod=int(pool_pages), n_pods=self.n_pods,
            )
            self.pool: Optional[PagePool] = PagePool(spec, self.c_max)
            self.phantom = self.pool.alloc_phantom(per_slot=per_slot_phantom)
            self._phantom_rows_idx = (
                np.arange(self.n_slots) if per_slot_phantom else self._pod_of_row
            )
            self.state = Z.init_decode_state_paged(cfg, spec.n_pages, ps)
        else:
            self.pool = None
            self.phantom = None
            self.state = Z.init_decode_state(cfg, self.n_slots, self.seq_cap)

        # -- device state: allocated once, donated every step --------------
        self.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        self._pos = np.zeros(self.n_slots, np.int64)  # device copy passed per step
        self._step_calls = 0
        self._prefill_compiled: set[int] = set()
        self._build()

    # -- compiled programs --------------------------------------------------

    def _build(self):
        cfg, asym = self.cfg, self.asym
        decode = Z.make_decode_fn(cfg)
        if self.paged:
            spec = self.pool.spec
            state_spec = jax.eval_shape(
                lambda: Z.init_decode_state_paged(cfg, spec.n_pages, spec.page_size)
            )
            batch_keys = ("tokens", "page_table", "live")
        else:
            state_spec = Z.decode_state_spec(cfg, self.n_slots, self.seq_cap)
            batch_keys = ("tokens", "live")

        if self.mixed:
            in_specs, out_specs = SH.pod_decode_specs(
                state_spec, batch_keys=batch_keys
            )
            core = asym.class_sharded(
                decode,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=out_specs,
            )
            self.provenance = core.provenance
        else:
            ctx = asym.execution_context()

            def core(params, batch, state, pos):
                with ctx:
                    return decode(params, batch, state, pos)

            self.provenance = None
        self._core = core

        def step_fn(params, batch, state, pos):
            logits, state = core(params, batch, state, pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return nxt, state

        donate = (2,) if self.donate else ()
        self._step = jax.jit(step_fn, donate_argnums=donate)

        bulk = Z.bulk_prefill_from_decode(core)

        if self.paged:
            def prefill_fn(params, batch, state, plens):
                # In-place prefill through the page tables: the arena is
                # donated; busy slots' table rows point at phantom pages,
                # so their live pages flow through untouched.
                pos0 = jnp.zeros((self.n_slots,), jnp.int32)
                logits, state = bulk(params, batch, state, pos0, plens=plens)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
                return nxt, state

            self._prefill = jax.jit(prefill_fn, donate_argnums=donate)
        else:
            def prefill_fn(params, batch, plens):
                # Fresh zero state traced inside the program: the fused
                # prefill writes every admitted lane from scratch in one
                # shot; the merge below keeps busy lanes.
                fresh = Z.init_decode_state(cfg, self.n_slots, self.seq_cap)
                pos0 = jnp.zeros((self.n_slots,), jnp.int32)
                logits, state = bulk(params, batch, fresh, pos0, plens=plens)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
                return nxt, state

            self._prefill = jax.jit(prefill_fn)

        def merge_fn(old_state, new_state, old_tokens, new_tokens, take_new):
            # Lanes in ``take_new`` — the admitted slots plus every free
            # (phantom) lane — take their freshly prefilled lane wholesale
            # (full-row replace: stale cache tails from the previous tenant
            # vanish); busy slots keep their lane bit-for-bit.  Refreshing
            # the phantom lanes keeps them identical to the one-shot padded
            # batch's rows, which MoE capacity routing (cross-row coupling)
            # requires for output bit-identity.  The batch (slot) dim of
            # every state leaf is dim 1.
            def pick(o, n):
                shape = [1] * o.ndim
                shape[1] = o.shape[1]
                return jnp.where(take_new.reshape(shape), n, o)

            state = jax.tree.map(pick, old_state, new_state)
            tokens = jnp.where(take_new[:, None], new_tokens, old_tokens)
            return state, tokens

        self._merge = jax.jit(merge_fn, donate_argnums=(0,) if self.donate else ())

    # -- page-table assembly (paged mode only; host-side, O(B·W)) -----------

    def _localize(self, table: np.ndarray) -> np.ndarray:
        if not self.mixed:
            return table
        return self.pool.localize(table, self._pod_of_row)

    def _step_table(self) -> np.ndarray:
        """The decode step's (B, W) page table: busy slots read their own
        pages, live pad lanes their phantom row, dead lanes SENTINEL
        (writes dropped, reads masked by the zeroed ``live`` output)."""

        busy = self.slot_rid >= 0
        table = self.phantom[self._phantom_rows_idx].copy()
        table[busy] = self.pool.table[busy]
        table[~busy & ~self._live] = SENTINEL
        return self._localize(table)

    # -- admission router ----------------------------------------------------

    def _class_weights(self) -> np.ndarray:
        rates = np.zeros(len(self.asym.classes), np.float64)
        for pod, ci in enumerate(self._pod_class):
            rates[ci] += self.asym.scheduler.rates[pod]
        return rates

    def submit(self, prompt, max_new_tokens: int, *, route_class: Optional[int] = None) -> int:
        """Queue one request; returns its rid.

        The router assigns the request to a class queue by largest
        remainder over the calibrated per-class throughput shares — the
        cumulative routed counts track the chunk table's split, so a batch
        of N submits lands exactly on ``chunk_table(N)`` aggregated by
        class.  ``route_class`` overrides (the batch path routes per an
        explicit layout so it reproduces ``pad_requests`` placement).
        """

        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + int(max_new_tokens) > self.seq_cap:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
                f"seq_cap={self.seq_cap}"
            )
        if len(prompt) == 0 or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens >= 1")
        rid = self._next_rid
        self._next_rid += 1
        if route_class is None:
            route_class = deficit_route(self._class_weights(), self._routed)
        self.queues[route_class].append(
            Request(rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens))
        )
        self._routed[route_class] += 1
        return rid

    # -- fleet surface: drain/export, health, calibration --------------------

    def withdraw(self, rid: int) -> Optional[Request]:
        """Remove one *queued* (not yet admitted) request; returns it.

        The router's cumulative count is rolled back so future routing
        reflects only the work the engine kept.  ``None`` if ``rid`` is
        not queued (already admitted, completed, or unknown) — admitted
        work cannot be withdrawn; it runs to completion.
        """

        for ci, q in enumerate(self.queues):
            for i, req in enumerate(q):
                if req.rid == rid:
                    del q[i]
                    self._routed[ci] -= 1
                    return req
        return None

    def export_queued(self) -> list[Request]:
        """Drain every class queue, in submission (rid) order.

        The fleet's migration path: a saturated, parked, or dead engine
        hands its not-yet-admitted requests back so they can be re-routed
        elsewhere.  Router counts roll back as in :meth:`withdraw`.
        """

        out: list[Request] = []
        for ci, q in enumerate(self.queues):
            while q:
                out.append(q.popleft())
                self._routed[ci] -= 1
        out.sort(key=lambda r: r.rid)
        return out

    def partial_tokens(self, rid: int) -> Optional[np.ndarray]:
        """Tokens generated so far for an in-flight request (else None).

        The fleet's streaming surface: completed tokens come from
        :attr:`completions`; mid-decode progress comes from here.
        """

        for slot, req in self._slot_req.items():
            if req.rid == rid:
                return np.asarray(self._slot_toks[slot], np.int32)
        return None

    def calibrated_tps(self) -> float:
        """Aggregate calibrated throughput (sum of per-pod EMA rates).

        Dimensionless rows-per-modeled-second units — exactly what the
        fleet scheduler needs as this engine's ``rel_throughput``.
        """

        return float(np.sum(self.asym.scheduler.rates))

    def health(self) -> dict:
        """The engine health surface a fleet front polls each tick."""

        return {
            "queued": sum(len(q) for q in self.queues),
            "active": int((self.slot_rid >= 0).sum()),
            "slots": self.n_slots,
            "parked_pods": sorted(self._parked),
            "calibrated_tps": self.calibrated_tps(),
            "completed": self.stats.completed,
            "admission_deferrals": self.stats.admission_deferrals,
        }

    # -- slot-region budgets (resize between steps only) ---------------------

    def _refresh_budgets(self):
        old_budgets = list(self.budgets)
        old_count = self.stats.rebalances
        n_work = int((self.slot_rid >= 0).sum()) + sum(len(q) for q in self.queues)
        self._update_parking(n_work)
        self.budgets = self.asym.slot_budgets(
            self.c_max, n_work, parked=sorted(self._parked)
        )
        # The scheduler re-derives its table (counting a rebalance) only
        # past the hysteresis threshold — whether the trigger was a budget
        # refresh or the batch path's routing table.
        self.stats.rebalances = self.asym.scheduler.rebalances - self._rebalances0
        if T.enabled() and self.stats.rebalances > old_count:
            _metrics()["rebalances"].inc(self.stats.rebalances - old_count)
            T.instant(
                "engine.rebalance", cat="engine",
                before=old_budgets, after=list(self.budgets),
                n_work=n_work, drift=self.asym.scheduler.drift(),
                rebalances=self.stats.rebalances,
            )

    # -- load-adaptive pod parking (energy objective only) --------------------

    def _update_parking(self, n_work: int):
        """Park/unpark pods against the offered load, with hysteresis.

        The energy objective's serving move: at low queue depth the
        engine parks the least energy-efficient pods (big, under the
        default power models) — zero slot budget, modeled gated watts —
        and serves from the efficient ones; as offered load ramps past
        what the unparked capacity covers, parked pods re-admit, most
        efficient first.  The hysteresis margin reuses the scheduler's
        drift threshold: a pod parks only when the load sits below the
        *remaining* capacity by that margin (``n_work <= cap·(1-h)``)
        and unparks as soon as capacity falls short — the gap between
        the two prevents park/unpark thrash at the boundary.  The most
        efficient pod never parks; existing requests on a freshly parked
        pod run to completion (parking only blocks new admissions).
        ``perf`` never parks — today's behavior stays bit-identical.
        """

        if self.asym.objective == "perf" or self.n_pods < 2:
            return
        h = self.asym.scheduler.rebalance_threshold
        order = self.asym.pods_by_efficiency()  # most efficient first
        for p in order:
            if (self.n_pods - len(self._parked)) * self.c_max >= n_work:
                break
            if p in self._parked:
                self._unpark(p, n_work)
        for p in reversed(order):
            if p in self._parked:
                continue
            if len(self._parked) >= self.n_pods - 1:
                break
            remaining = (self.n_pods - len(self._parked) - 1) * self.c_max
            if n_work <= remaining * (1.0 - h):
                self._park(p, n_work)
            else:
                break

    def _park(self, pod: int, n_work: int):
        self._parked.add(pod)
        self.stats.pod_parks += 1
        if T.enabled():
            _metrics()["pods_parked"].set(len(self._parked))
            T.instant(
                "engine.pod_park", cat="engine", pod=pod,
                device_class=self.asym.class_of_pod(pod).name,
                n_work=n_work, parked=sorted(self._parked),
            )

    def _unpark(self, pod: int, n_work: int):
        self._parked.discard(pod)
        self.stats.pod_unparks += 1
        if T.enabled():
            _metrics()["pods_parked"].set(len(self._parked))
            T.instant(
                "engine.pod_unpark", cat="engine", pod=pod,
                device_class=self.asym.class_of_pod(pod).name,
                n_work=n_work, parked=sorted(self._parked),
            )

    def _admission_pods(self, ci: int) -> list[int]:
        """The pods class ``ci``'s queue may admit into: the class's
        unparked pods; when the whole class is parked, the unparked pods
        of other classes, most efficient first (the queue must not starve
        behind a parked class — nor silently defeat parking by admitting
        into it)."""

        pods = [
            p for p, c in enumerate(self._pod_class)
            if c == ci and p not in self._parked
        ]
        if not pods:
            pods = [
                p for p in self.asym.pods_by_efficiency() if p not in self._parked
            ]
        return pods

    def _pod_active(self) -> list[int]:
        act = (self.slot_rid >= 0).reshape(self.n_pods, self.c_max)
        return [int(a.sum()) for a in act]

    def _free_slot(self, pod: int) -> Optional[int]:
        if self._pod_active()[pod] >= self.budgets[pod]:
            return None
        return self._any_free_slot(pod)

    def _any_free_slot(self, pod: int) -> Optional[int]:
        lo = pod * self.c_max
        for s in range(lo, lo + self.c_max):
            if self.slot_rid[s] < 0:
                return s
        return None

    # -- admission (bulk prefill into free slots) -----------------------------

    def admit(self) -> int:
        """Admit queued requests into free budgeted slots; returns count.

        Continuous batching: one round takes *mixed-length* prompts from
        every queue head — right-padded to the round maximum, each row's
        first generated token selected at its own last real prompt token
        (``plens``).  The fused prefill runs over the full slot table
        (free lanes see zero prompts — the same phantom rows the one-shot
        padded batch carries).  In paged mode every page a request can
        ever touch is reserved all-or-nothing first; a pod partition that
        cannot cover the head request defers it (FIFO) untouched.
        """

        self._refresh_budgets()
        busy_before = self.slot_rid >= 0
        if not any(self.queues):
            return 0

        def take(budgeted: bool) -> list[tuple[int, "Request"]]:
            out = []
            for ci, q in enumerate(self.queues):
                pods = self._admission_pods(ci)
                while q:
                    req = q[0]
                    slot = None
                    for pod in pods:
                        slot = (
                            self._free_slot(pod)
                            if budgeted
                            else self._any_free_slot(pod)
                        )
                        if slot is not None:
                            break
                    if slot is None:
                        break
                    if self.pool is not None:
                        need = min(
                            len(req.prompt) + req.max_new_tokens, self.s_cache
                        )
                        if not self.pool.alloc(slot, need):
                            # Pod partition exhausted: defer the head (it
                            # keeps its FIFO turn; the pool and every live
                            # slot are untouched — all-or-nothing alloc).
                            self.stats.admission_deferrals += 1
                            break
                        self._note_page_alloc(slot, need)
                    q.popleft()
                    out.append((slot, req))
                    self.slot_rid[slot] = req.rid  # reserve before next _free_slot
            return out

        batch = take(budgeted=True)
        if not batch and not busy_before.any():
            # Starvation guard: a queue whose class drew a zero budget at
            # low load must still make progress when nothing is running
            # (the scheduler's starvation floor, at admission granularity).
            batch = take(budgeted=False)
        if not batch:
            return 0

        rp = max(len(req.prompt) for _, req in batch)
        prompts = np.zeros((self.n_slots, rp), np.int32)
        plens = np.full(self.n_slots, rp, np.int32)
        for slot, req in batch:
            prompts[slot, : len(req.prompt)] = req.prompt
            plens[slot] = len(req.prompt)
        # Admitted slots plus every phantom (free) lane take the fresh
        # prefill — see merge_fn.
        take_new = ~busy_before

        t0 = time.perf_counter()
        live_all = jnp.ones((self.n_slots,), bool)
        if self.pool is not None:
            table = self.phantom[self._phantom_rows_idx].copy()
            for slot, _ in batch:
                table[slot] = self.pool.table[slot]
            pbatch = {
                "tokens": jnp.asarray(prompts),
                "page_table": jnp.asarray(self._localize(table)),
                "live": live_all,
            }
            nxt, self.state = self._prefill(
                self.params, pbatch, self.state, jnp.asarray(plens)
            )
            self.tokens = jnp.where(
                jnp.asarray(take_new)[:, None], nxt, self.tokens
            )
        else:
            pbatch = {"tokens": jnp.asarray(prompts), "live": live_all}
            nxt, fresh_state = self._prefill(
                self.params, pbatch, jnp.asarray(plens)
            )
            self.state, self.tokens = self._merge(
                self.state, fresh_state, self.tokens, nxt, jnp.asarray(take_new)
            )
        first = np.asarray(nxt)  # blocks; first generated token per lane
        dt = time.perf_counter() - t0
        compiling = rp not in self._prefill_compiled
        if compiling:
            self._prefill_compiled.add(rp)
            self.stats.compile_s += dt
        else:
            self.stats.prefill_s += dt
        if T.enabled():
            self._record_admit_telemetry(t0, dt, rp, batch, compiling)

        self._live[take_new] = True
        self._pos[take_new] = plens[take_new]
        for slot, req in batch:
            self.slot_pos[slot] = len(req.prompt)
            self._slot_req[slot] = req
            self._slot_toks[slot] = [int(first[slot, 0])]
            self.slot_remaining[slot] = req.max_new_tokens - 1
            self.stats.admitted += 1
            if self.eos_id is not None and int(first[slot, 0]) == self.eos_id:
                self._retire(slot, stop="eos")
            elif self.slot_remaining[slot] == 0:
                self._retire(slot, stop="budget")
        self.stats.admission_rounds += 1
        return len(batch)

    def _retire(self, slot: int, stop: str = "budget"):
        req = self._slot_req.pop(slot)
        pod = slot // self.c_max
        self.completions.append(
            Completion(
                rid=req.rid,
                tokens=np.concatenate(
                    [req.prompt, np.asarray(self._slot_toks.pop(slot), np.int32)]
                ),
                prompt_len=len(req.prompt),
                slot=slot,
                pod=pod,
                device_class=self.asym.class_of_pod(pod).name,
                stop=stop,
            )
        )
        self.slot_rid[slot] = -1
        self.slot_remaining[slot] = 0
        self._live[slot] = False
        self.stats.completed += 1
        if stop == "eos":
            self.stats.completed_eos += 1
        else:
            self.stats.completed_budget += 1
        if self.pool is not None:
            freed = self.pool.free_slot(slot)
            if T.enabled() and freed:
                m = _metrics()
                m["kv_pages_free"].set(self.pool.pages_free)
                m["kv_pages_live"].set(self.pool.pages_live)
                T.instant(
                    "engine.page_free", cat="engine", slot=slot, pages=freed,
                    stop=stop, pages_live=self.pool.pages_live,
                    pages_free=self.pool.pages_free,
                )

    def _note_page_alloc(self, slot: int, n_tokens: int):
        if not T.enabled():
            return
        m = _metrics()
        pages = self.pool.spec.pages_for(n_tokens)
        name = self.asym.class_of_pod(slot // self.c_max).name
        m["page_allocs"].labels(device_class=name).inc(pages)
        m["kv_pages_free"].set(self.pool.pages_free)
        m["kv_pages_live"].set(self.pool.pages_live)
        T.instant(
            "engine.page_alloc", cat="engine", slot=slot, pages=pages,
            pages_live=self.pool.pages_live, pages_free=self.pool.pages_free,
        )

    # -- steady-state decode ---------------------------------------------------

    def step(self) -> int:
        """One decode step over the whole slot table; returns active count.

        No host relayout: the step consumes the resident token/position
        vectors, the lane-liveness mask, (paged) the page table assembled
        from pool state, and the donated slot state.  Every slot advances
        (freed slots as phantom rows), matching the one-shot padded batch
        program exactly.
        """

        active = self.slot_rid >= 0
        n_active = int(active.sum())
        if n_active == 0:
            return 0
        units = self._pod_active_before(active)
        t0 = time.perf_counter()
        batch = {"tokens": self.tokens, "live": jnp.asarray(self._live)}
        if self.pool is not None:
            batch["page_table"] = jnp.asarray(self._step_table())
        nxt, self.state = self._step(
            self.params, batch, self.state, jnp.asarray(self._pos, jnp.int32)
        )
        self.tokens = nxt
        toks = np.asarray(nxt)  # blocks: the step's wall time is real
        dt = time.perf_counter() - t0
        if self._step_calls == 0:
            self.stats.compile_s += dt
        else:
            self.stats.decode_s += dt
            self.stats.decode_steps += 1
            self.stats.tokens += n_active
            self._account_energy(units)
        self._step_calls += 1
        self._pos += 1  # every slot ages (phantom rows match one-shot padding)

        for slot in np.nonzero(active)[0]:
            slot = int(slot)
            tok = int(toks[slot, 0])
            self._slot_toks[slot].append(tok)
            self.slot_remaining[slot] -= 1
            if self.eos_id is not None and tok == self.eos_id:
                self._retire(slot, stop="eos")
            elif self.slot_remaining[slot] == 0:
                self._retire(slot, stop="budget")

        if T.enabled():
            self._record_step_telemetry(t0, dt, n_active, active,
                                        self._step_calls - 1)

        # Straggler feedback: per-pod timings re-calibrate the scheduler
        # (budgets only re-derive at admission, past hysteresis).  One
        # SPMD step yields one wall time, not per-pod times — without a
        # hook there is no per-pod signal, and fabricating equal times
        # would read occupancy as speed and erode the calibrated ratios
        # (at full occupancy every pod shows the same units/dt), so the
        # calibration comes only from a hook (the default StepTimeProbe
        # measures each class's real per-row cost, and stays inert —
        # returning None — while observability is off).
        if self.pod_time_hook is not None:
            times = (
                self.pod_time_hook(self._step_calls - 1, units)
                if self._hook_takes_units
                else self.pod_time_hook(self._step_calls - 1)
            )
            if times is not None:
                self.asym.observe_step(units, list(times))
        return n_active

    def _pod_active_before(self, active_mask: np.ndarray) -> list[int]:
        act = active_mask.reshape(self.n_pods, self.c_max)
        return [int(a.sum()) for a in act]

    def _account_energy(self, units: Sequence[int]):
        """Modeled joules for one steady-state decode step.

        The step's modeled span is the slowest pod's row count over its
        aggregate throughput (× :data:`MODELED_ROW_S` — the SPMD barrier
        means every pod waits for the straggler).  Per-pod draw over the
        span: a pod with rows interpolates idle→active by occupancy; an
        empty parked pod draws gated watts; an empty unparked pod polls
        (the paper's idle-but-active cores).  Deterministic — no wall
        clocks — so the bench's energy column is host-independent.
        """

        span = MODELED_ROW_S * max(
            (u / agg for u, agg in zip(units, self._pod_agg) if agg > 0),
            default=0.0,
        )
        if span <= 0:
            return
        watts = 0.0
        for p, u in enumerate(units):
            if u > 0:
                watts += self._idle_w[p] + (
                    self._active_w[p] - self._idle_w[p]
                ) * u / self.c_max
            elif p in self._parked:
                watts += self._gated_w[p]
            else:
                watts += self._poll_w[p]
        self.stats.energy_j += watts * span
        self.stats.modeled_decode_s += span
        if T.enabled():
            _metrics()["modeled_watts"].set(watts)

    # -- KV memory accounting ---------------------------------------------------

    def kv_stats(self) -> dict:
        """KV memory accounting for reporting (serve.py / bench_serving).

        Dense mode reports the lanes' actual byte size.  Paged mode adds
        the pool occupancy counters and the headline comparison:
        ``peak_kv_bytes`` (peak live pages × bytes per page — the arena an
        operator could have provisioned) vs ``dense_kv_bytes`` (what the
        dense engine allocates for the same slot table).
        """

        arena = int(sum(x.nbytes for x in jax.tree.leaves(self.state)))
        if self.pool is None:
            return {"paged": False, "kv_bytes": arena}
        spec = self.pool.spec
        itemsize = self.state["pages_k"].dtype.itemsize
        per_tok = 2 * self.cfg.n_layers * self.cfg.n_kv_heads * self.cfg.head_dim
        page_bytes = per_tok * spec.page_size * itemsize
        return {
            "paged": True,
            "page_size": spec.page_size,
            "pages_per_slot": spec.pages_per_slot,
            "n_pages": spec.n_pages,
            "pages_live": self.pool.pages_live,
            "pages_free": self.pool.pages_free,
            "peak_live_pages": self.pool.peak_live,
            "phantom_pages": int(self.phantom.size),
            "page_bytes": page_bytes,
            "peak_kv_bytes": self.pool.peak_live * page_bytes,
            "arena_kv_bytes": arena,
            "dense_kv_bytes": per_tok * self.n_slots * self.s_cache * itemsize,
        }

    # -- telemetry (every method below only runs while tracing is enabled) ----

    def _shard_tags(self) -> list[dict]:
        """Per-class provenance tags for decode-shard spans: device class,
        backend variant, block_source, and the pods running it."""

        if self._shard_tags_cache is None:
            by_class: dict[str, dict] = {}
            if self.mixed and self.provenance:
                for p in self.provenance:
                    t = by_class.setdefault(p.device_class, {
                        "device_class": p.device_class,
                        "backend": p.backend,
                        "block_source": p.block_source,
                        "pods": [],
                    })
                    t["pods"].append(p.pod)
            else:
                ctx = self.asym.execution_context()
                by_class[ctx.device_class] = {
                    "device_class": ctx.device_class,
                    "backend": ctx.backend(),
                    "block_source": ctx.tree.block_source,
                    "pods": list(range(self.n_pods)),
                }
            self._shard_tags_cache = list(by_class.values())
        return self._shard_tags_cache

    def _record_step_telemetry(self, t0, dt, n_active, active_mask, step_idx):
        m = _metrics()
        T.complete("engine.decode_step", t0, dt, cat="engine",
                   step=step_idx, active=n_active)
        per_pod = self._pod_active_before(active_mask)
        for tags in self._shard_tags():
            T.complete("engine.decode_shard", t0, dt, cat="engine",
                       device_class=tags["device_class"],
                       backend=tags["backend"],
                       block_source=tags["block_source"],
                       slots=int(sum(per_pod[p] for p in tags["pods"])))
        for ci, c in enumerate(self.asym.classes):
            m["queue_depth"].labels(device_class=c.name).set(len(self.queues[ci]))
        for pod, occ in enumerate(per_pod):
            m["slot_occupancy"].labels(pod=str(pod)).set(occ)
        m["tokens"].inc(n_active)
        m["step_seconds"].observe(dt)
        if dt > 0:
            inst = n_active / dt
            self._tps_ema = (
                inst if self._tps_ema is None
                else 0.8 * self._tps_ema + 0.2 * inst
            )
            m["tokens_per_s"].set(self._tps_ema)

    def _record_admit_telemetry(self, t0, dt, plen, batch, compiling):
        m = _metrics()
        T.complete("engine.prefill", t0, dt, cat="engine", plen=plen,
                   admitted=len(batch), compiled=compiling)
        per_class: dict[str, int] = {}
        for slot, _ in batch:
            name = self.asym.class_of_pod(slot // self.c_max).name
            per_class[name] = per_class.get(name, 0) + 1
        for name, n in per_class.items():
            m["admissions"].labels(device_class=name).inc(n)
        for ci, c in enumerate(self.asym.classes):
            m["queue_depth"].labels(device_class=c.name).set(len(self.queues[ci]))

    # -- driver ----------------------------------------------------------------

    def run(self, *, max_steps: Optional[int] = None) -> list[Completion]:
        """Admit + decode until queues and slots drain.

        Returns the completions produced by *this* call (the cumulative
        history stays available as ``self.completions``).
        """

        start = len(self.completions)
        steps = 0
        while True:
            if any(self.queues):
                admitted = self.admit()
                if admitted == 0 and not (self.slot_rid >= 0).any():
                    raise RuntimeError(
                        "admission made no progress with an empty slot table "
                        "(a queued request's page reservation exceeds its pod's "
                        "pool partition?)"
                    )
            if not (self.slot_rid >= 0).any():
                break
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.completions[start:]

    def generate(self, prompts: np.ndarray, gen_len: int) -> np.ndarray:
        """Batch convenience: decode ``prompts`` (B, P) for ``gen_len`` tokens.

        Routes per the scheduler's chunk table in request order —
        reproducing exactly the ``pad_requests`` pod-major placement of
        the one-shot path, which is what makes the outputs bit-identical
        to it (same slot layout, same phantom rows).  Returns
        ``(B, P + gen_len)`` tokens in submission order (rows of requests
        stopped early by ``eos_id`` are zero-padded past their last
        token).
        """

        prompts = np.asarray(prompts, np.int32)
        n = prompts.shape[0]
        sizes = self.asym.chunk_table(n).sizes()
        rid_of = {}
        pos = 0
        for pod, size in enumerate(sizes):
            ci = self._pod_class[pod]
            for r in range(pos, pos + size):
                rid_of[self.submit(prompts[r], gen_len, route_class=ci)] = r
            pos += size
        done = self.run()
        out = np.zeros((n, prompts.shape[1] + gen_len), np.int32)
        for c in done:
            if c.rid in rid_of:
                out[rid_of[c.rid], : len(c.tokens)] = c.tokens
        return out


__all__ = ["ServingEngine", "Request", "Completion", "EngineStats"]

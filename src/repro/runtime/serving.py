"""Persistent asymmetric serving runtime: slot table + per-class queues.

The serving-side analogue of the trainer's class-sharded step, and the
direct transplant of the paper's §5.4 insight: workers *keep* their
assignments between micro-kernel grabs instead of re-partitioning the
whole problem every iteration.  The one-shot path (``launch/serve.py
--one-shot``) does the opposite — it re-pads the request batch per the
chunk table on every generate call and replays prompts token-by-token
through per-call jit dispatches, each of which copies the full decode
state.  This engine amortizes all of it:

  * **Fixed pod-major slot table** — ``n_pods × c_max`` decode slots,
    each slot one KV-cache lane of a decode state allocated **once**.
    Pod *i* owns the contiguous slot region ``[i·c_max, (i+1)·c_max)``;
    on a multi-class mesh the jitted step runs class-sharded
    (``AsymmetricMesh.class_sharded``), so each pod decodes its region
    under its own class's control tree — two micro-kernel programs in one
    SPMD step, ``ShardProvenance``-proven, exactly as in training.
  * **Per-class request queues + admission router** — requests are routed
    to a class queue at submit time (largest-remainder over calibrated
    throughput shares, so the split tracks the chunk table), and admitted
    into free slots of that class's region between steps.  Once running,
    a request never moves: steady-state decode performs **zero host
    relayout** (no ``pad_requests``, no chunk-table re-derivation in the
    loop — asserted by tests).
  * **Donated decode state** — the slot state is threaded through the
    jitted step with ``donate_argnums``, so the KV caches update in place
    instead of being copied every token (the copy is the dominant
    per-token cost of the one-shot loop at real cache sizes).
  * **Fused bulk prefill** — ``model_zoo.make_prefill_fn(cfg,
    with_cache=True)`` consumes the whole prompt in one jitted program
    and bulk-writes the admitted slots' cache lanes, bit-identical to the
    token-by-token replay (the property that makes a prefilled slot
    indistinguishable from one that decoded its prompt).
  * **Rebalance hysteresis** — per-pod step timings feed
    ``DynamicScheduler.observe``; slot-region budgets are re-derived
    *only* when the calibrated ratio drifts past the scheduler's
    threshold, and only between steps (admission time), never mid-step.

Per-slot positions (a ``(B,)`` position vector through the decode step —
see ``layers.decode_attention``) are what make the slot table persistent:
slots age independently, so a freed slot can be re-admitted while its
neighbours keep decoding.  Retired slots keep stepping as phantom rows
(row-local math, discarded tokens), which keeps the engine's program
identical to the one-shot padded batch — the engine's tokens are
bit-identical to the one-shot mixed ``class_sharded`` path for the same
prompts (tested, including through MoE capacity routing, which couples
batch rows and therefore requires the phantom rows to match too).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig
from repro.core.asymmetric import AsymmetricMesh
from repro.distributed import sharding as SH
from repro.models import model_zoo as Z


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued generation request."""

    rid: int
    prompt: np.ndarray        # (P,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    """A finished request: prompt + generated tokens, and where it ran."""

    rid: int
    tokens: np.ndarray        # (P + n_generated,) int32
    prompt_len: int
    slot: int                 # global slot id (pod-major)
    pod: int
    device_class: str


@dataclasses.dataclass
class EngineStats:
    """Timing/behavior counters (compile vs steady state split out)."""

    compile_s: float = 0.0        # first prefill + first decode step (tracing+XLA)
    prefill_s: float = 0.0        # steady-state bulk prefill seconds
    decode_s: float = 0.0         # steady-state decode seconds (warmup excluded)
    decode_steps: int = 0         # steady-state steps counted in decode_s
    tokens: int = 0               # tokens generated in steady-state steps
    admitted: int = 0
    completed: int = 0
    admission_rounds: int = 0
    # Host relayouts performed by the decode loop.  Structurally zero: the
    # engine has no relayout site after admission (requests keep their
    # slot), which tests/test_serving.py enforces by *poisoning*
    # pad_requests / chunk_table / batch_layout and running the loop — the
    # counter exists for the JSON reporting contract, not as the guard.
    host_relayouts: int = 0
    rebalances: int = 0           # slot-budget re-derivations past hysteresis

    @property
    def tokens_per_s(self) -> float:
        """Steady-state decode throughput (compile/warmup excluded)."""

        return self.tokens / self.decode_s if self.decode_s > 0 else 0.0


class ServingEngine:
    """Persistent slot-table serving engine over an :class:`AsymmetricMesh`.

    Parameters
    ----------
    cfg, params : the model (token-in archs only — serving contract).
    asym : the asymmetric mesh (scheduling state; per-class control trees).
    seq_cap : per-slot cache length (prompt + generation must fit).
    slots_per_pod : ``c_max`` — each pod's fixed slot-region size.
    mesh : jax Mesh with a ``pod`` axis for the class-sharded mixed step;
        built automatically (host mesh) when class_sharded resolves on.
    class_sharded : "auto" | "on" | "off" — as in launch/serve.py.
    donate : donate the decode state through the jitted step (in-place
        cache updates).  Off only for the A/B test of the donation path.
    pod_time_hook : optional ``step -> [per-pod seconds]`` feeding the
        scheduler's straggler calibration (tests / external per-pod
        telemetry).  Without it the calibration is left untouched — one
        SPMD step cannot be attributed per pod from the host.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        asym: AsymmetricMesh,
        *,
        seq_cap: int,
        slots_per_pod: int = 4,
        mesh=None,
        class_sharded: str = "auto",
        donate: bool = True,
        pod_time_hook: Optional[Callable[[int], Sequence[float]]] = None,
    ):
        if cfg.embed_inputs or cfg.family == "encdec":
            raise ValueError(f"{cfg.name}: the serving engine targets token-in archs")
        if class_sharded not in ("auto", "on", "off"):
            raise ValueError(f"class_sharded={class_sharded!r}")
        self.cfg = cfg
        self.params = params
        self.asym = asym
        self.seq_cap = int(seq_cap)
        self.c_max = int(slots_per_pod)
        self.n_pods = asym.n_pods
        self.n_slots = self.n_pods * self.c_max
        self.donate = bool(donate)
        self.pod_time_hook = pod_time_hook

        self.mixed = (
            class_sharded != "off"
            and len(asym.classes) > 1
            and jax.device_count() >= asym.n_pods
        )
        if class_sharded == "on" and not self.mixed:
            raise ValueError(
                f"class_sharded='on' needs {asym.n_pods} devices, "
                f"have {jax.device_count()}"
            )
        if self.mixed and mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(pod=asym.n_pods)
        self.mesh = mesh

        # -- per-class request queues fed by the admission router ----------
        self.queues: list[collections.deque] = [
            collections.deque() for _ in asym.classes
        ]
        self._routed = [0] * len(asym.classes)  # total ever routed per class
        self._next_rid = 0
        self._pod_class = asym.pod_class_indices()

        # -- host-side slot bookkeeping (the device never sees it) ---------
        self.slot_rid = np.full(self.n_slots, -1, np.int64)     # -1 = free
        self.slot_pos = np.zeros(self.n_slots, np.int64)        # next abs position
        self.slot_remaining = np.zeros(self.n_slots, np.int64)
        self._slot_req: dict[int, Request] = {}
        self._slot_toks: dict[int, list[int]] = {}
        self.budgets = [0] * self.n_pods
        self.completions: list[Completion] = []
        self.stats = EngineStats()
        self._rebalances0 = asym.scheduler.rebalances

        # -- device state: allocated once, donated every step --------------
        self.state = Z.init_decode_state(cfg, self.n_slots, self.seq_cap)
        self.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        self._pos = np.zeros(self.n_slots, np.int64)  # device copy passed per step
        self._step_calls = 0
        self._prefill_compiled: set[int] = set()
        self._build()

    # -- compiled programs --------------------------------------------------

    def _build(self):
        cfg, asym = self.cfg, self.asym
        decode = Z.make_decode_fn(cfg)
        state_spec = Z.decode_state_spec(cfg, self.n_slots, self.seq_cap)

        if self.mixed:
            in_specs, out_specs = SH.pod_decode_specs(state_spec)
            core = asym.class_sharded(
                decode,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=out_specs,
            )
            self.provenance = core.provenance
        else:
            ctx = asym.execution_context()

            def core(params, batch, state, pos):
                with ctx:
                    return decode(params, batch, state, pos)

            self.provenance = None
        self._core = core

        def step_fn(params, tokens, state, pos):
            logits, state = core(params, {"tokens": tokens}, state, pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return nxt, state

        donate = (2,) if self.donate else ()
        self._step = jax.jit(step_fn, donate_argnums=donate)

        bulk = Z.bulk_prefill_from_decode(core)

        def prefill_fn(params, prompts):
            # Fresh zero state traced inside the program: the fused prefill
            # writes every admitted lane from scratch in one shot.
            fresh = Z.init_decode_state(cfg, self.n_slots, self.seq_cap)
            pos0 = jnp.zeros((self.n_slots,), jnp.int32)
            logits, state = bulk(params, {"tokens": prompts}, fresh, pos0)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return nxt, state

        self._prefill = jax.jit(prefill_fn)

        def merge_fn(old_state, new_state, old_tokens, new_tokens, take_new):
            # Lanes in ``take_new`` — the admitted slots plus every free
            # (phantom) lane — take their freshly prefilled lane wholesale
            # (full-row replace: stale cache tails from the previous tenant
            # vanish); busy slots keep their lane bit-for-bit.  Refreshing
            # the phantom lanes keeps them identical to the one-shot padded
            # batch's rows, which MoE capacity routing (cross-row coupling)
            # requires for output bit-identity.  The batch (slot) dim of
            # every state leaf is dim 1.
            def pick(o, n):
                shape = [1] * o.ndim
                shape[1] = o.shape[1]
                return jnp.where(take_new.reshape(shape), n, o)

            state = jax.tree.map(pick, old_state, new_state)
            tokens = jnp.where(take_new[:, None], new_tokens, old_tokens)
            return state, tokens

        self._merge = jax.jit(merge_fn, donate_argnums=(0,) if self.donate else ())

    # -- admission router ----------------------------------------------------

    def _class_weights(self) -> np.ndarray:
        rates = np.zeros(len(self.asym.classes), np.float64)
        for pod, ci in enumerate(self._pod_class):
            rates[ci] += self.asym.scheduler.rates[pod]
        return rates

    def submit(self, prompt, max_new_tokens: int, *, route_class: Optional[int] = None) -> int:
        """Queue one request; returns its rid.

        The router assigns the request to a class queue by largest
        remainder over the calibrated per-class throughput shares — the
        cumulative routed counts track the chunk table's split, so a batch
        of N submits lands exactly on ``chunk_table(N)`` aggregated by
        class.  ``route_class`` overrides (the batch path routes per an
        explicit layout so it reproduces ``pad_requests`` placement).
        """

        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + int(max_new_tokens) > self.seq_cap:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
                f"seq_cap={self.seq_cap}"
            )
        if len(prompt) == 0 or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens >= 1")
        rid = self._next_rid
        self._next_rid += 1
        if route_class is None:
            w = self._class_weights()
            total = sum(self._routed) + 1
            quota = w / w.sum() * total
            base = np.floor(quota).astype(np.int64)
            rem = total - int(base.sum())
            order = np.argsort(-(quota - base), kind="stable")
            base[order[:rem]] += 1
            deficits = base - np.asarray(self._routed)
            route_class = int(np.argmax(deficits))
        self.queues[route_class].append(
            Request(rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens))
        )
        self._routed[route_class] += 1
        return rid

    # -- slot-region budgets (resize between steps only) ---------------------

    def _refresh_budgets(self):
        n_work = int((self.slot_rid >= 0).sum()) + sum(len(q) for q in self.queues)
        self.budgets = self.asym.slot_budgets(self.c_max, n_work)
        # The scheduler re-derives its table (counting a rebalance) only
        # past the hysteresis threshold — whether the trigger was a budget
        # refresh or the batch path's routing table.
        self.stats.rebalances = self.asym.scheduler.rebalances - self._rebalances0

    def _pod_active(self) -> list[int]:
        act = (self.slot_rid >= 0).reshape(self.n_pods, self.c_max)
        return [int(a.sum()) for a in act]

    def _free_slot(self, pod: int) -> Optional[int]:
        if self._pod_active()[pod] >= self.budgets[pod]:
            return None
        return self._any_free_slot(pod)

    def _any_free_slot(self, pod: int) -> Optional[int]:
        lo = pod * self.c_max
        for s in range(lo, lo + self.c_max):
            if self.slot_rid[s] < 0:
                return s
        return None

    # -- admission (bulk prefill into free slots) -----------------------------

    def admit(self) -> int:
        """Admit queued requests into free budgeted slots; returns count.

        One admission round prefills one prompt length (the head of each
        queue gates what joins the round — mixed lengths admit over
        successive rounds).  The fused prefill runs over the full slot
        table (free lanes see zero prompts — the same phantom rows the
        one-shot padded batch carries) and the merge writes only the
        admitted lanes, donated, so running slots are untouched in place.
        """

        self._refresh_budgets()
        busy_before = self.slot_rid >= 0
        plen = None
        for q in self.queues:
            if q:
                plen = len(q[0].prompt) if plen is None else min(plen, len(q[0].prompt))
        if plen is None:
            return 0

        def take(budgeted: bool) -> list[tuple[int, "Request"]]:
            out = []
            for ci, q in enumerate(self.queues):
                pods = [p for p, c in enumerate(self._pod_class) if c == ci]
                while q and len(q[0].prompt) == plen:
                    slot = None
                    for pod in pods:
                        slot = (
                            self._free_slot(pod)
                            if budgeted
                            else self._any_free_slot(pod)
                        )
                        if slot is not None:
                            break
                    if slot is None:
                        break
                    req = q.popleft()
                    out.append((slot, req))
                    self.slot_rid[slot] = req.rid  # reserve before next _free_slot
            return out

        batch = take(budgeted=True)
        if not batch and not busy_before.any():
            # Starvation guard: a queue whose class drew a zero budget at
            # low load must still make progress when nothing is running
            # (the scheduler's starvation floor, at admission granularity).
            batch = take(budgeted=False)
        if not batch:
            return 0

        prompts = np.zeros((self.n_slots, plen), np.int32)
        for slot, req in batch:
            prompts[slot] = req.prompt
        # Admitted slots plus every phantom (free) lane take the fresh
        # prefill — see merge_fn.
        take_new = ~busy_before

        t0 = time.perf_counter()
        nxt, fresh_state = self._prefill(self.params, jnp.asarray(prompts))
        self.state, self.tokens = self._merge(
            self.state, fresh_state, self.tokens, nxt, jnp.asarray(take_new)
        )
        first = np.asarray(nxt)  # blocks; first generated token per lane
        dt = time.perf_counter() - t0
        if plen in self._prefill_compiled:
            self.stats.prefill_s += dt
        else:
            self._prefill_compiled.add(plen)
            self.stats.compile_s += dt

        for slot, req in batch:
            self.slot_pos[slot] = plen
            self._slot_req[slot] = req
            self._slot_toks[slot] = [int(first[slot, 0])]
            self.slot_remaining[slot] = req.max_new_tokens - 1
            self.stats.admitted += 1
            if self.slot_remaining[slot] == 0:
                self._retire(slot)
        self._pos[take_new] = plen
        self.stats.admission_rounds += 1
        return len(batch)

    def _retire(self, slot: int):
        req = self._slot_req.pop(slot)
        pod = slot // self.c_max
        self.completions.append(
            Completion(
                rid=req.rid,
                tokens=np.concatenate(
                    [req.prompt, np.asarray(self._slot_toks.pop(slot), np.int32)]
                ),
                prompt_len=len(req.prompt),
                slot=slot,
                pod=pod,
                device_class=self.asym.class_of_pod(pod).name,
            )
        )
        self.slot_rid[slot] = -1
        self.slot_remaining[slot] = 0
        self.stats.completed += 1

    # -- steady-state decode ---------------------------------------------------

    def step(self) -> int:
        """One decode step over the whole slot table; returns active count.

        No host relayout: the step consumes the resident token/position
        vectors and the donated slot state.  Every slot advances (freed
        slots as phantom rows), matching the one-shot padded batch
        program exactly.
        """

        active = self.slot_rid >= 0
        n_active = int(active.sum())
        if n_active == 0:
            return 0
        t0 = time.perf_counter()
        nxt, self.state = self._step(
            self.params, self.tokens, self.state, jnp.asarray(self._pos, jnp.int32)
        )
        self.tokens = nxt
        toks = np.asarray(nxt)  # blocks: the step's wall time is real
        dt = time.perf_counter() - t0
        if self._step_calls == 0:
            self.stats.compile_s += dt
        else:
            self.stats.decode_s += dt
            self.stats.decode_steps += 1
            self.stats.tokens += n_active
        self._step_calls += 1
        self._pos += 1  # every slot ages (phantom rows match one-shot padding)

        for slot in np.nonzero(active)[0]:
            self._slot_toks[int(slot)].append(int(toks[slot, 0]))
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] == 0:
                self._retire(int(slot))

        # Straggler feedback: per-pod timings re-calibrate the scheduler
        # (budgets only re-derive at admission, past hysteresis).  One
        # SPMD step yields one wall time, not per-pod times — without a
        # hook there is no per-pod signal, and fabricating equal times
        # would read occupancy as speed and erode the calibrated ratios
        # (at full occupancy every pod shows the same units/dt), so the
        # calibration is left untouched.
        if self.pod_time_hook is not None:
            times = list(self.pod_time_hook(self._step_calls - 1))
            self.asym.observe_step(self._pod_active_before(active), times)
        return n_active

    def _pod_active_before(self, active_mask: np.ndarray) -> list[int]:
        act = active_mask.reshape(self.n_pods, self.c_max)
        return [int(a.sum()) for a in act]

    # -- driver ----------------------------------------------------------------

    def run(self, *, max_steps: Optional[int] = None) -> list[Completion]:
        """Admit + decode until queues and slots drain.

        Returns the completions produced by *this* call (the cumulative
        history stays available as ``self.completions``).
        """

        start = len(self.completions)
        steps = 0
        while True:
            if any(self.queues):
                admitted = self.admit()
                if admitted == 0 and not (self.slot_rid >= 0).any():
                    raise RuntimeError(
                        "admission made no progress with an empty slot table"
                    )  # unreachable: the starvation guard admits something
            if not (self.slot_rid >= 0).any():
                break
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.completions[start:]

    def generate(self, prompts: np.ndarray, gen_len: int) -> np.ndarray:
        """Batch convenience: decode ``prompts`` (B, P) for ``gen_len`` tokens.

        Routes per the scheduler's chunk table in request order —
        reproducing exactly the ``pad_requests`` pod-major placement of
        the one-shot path, which is what makes the outputs bit-identical
        to it (same slot layout, same phantom rows).  Returns
        ``(B, P + gen_len)`` tokens in submission order.
        """

        prompts = np.asarray(prompts, np.int32)
        n = prompts.shape[0]
        sizes = self.asym.chunk_table(n).sizes()
        rid_of = {}
        pos = 0
        for pod, size in enumerate(sizes):
            ci = self._pod_class[pod]
            for r in range(pos, pos + size):
                rid_of[self.submit(prompts[r], gen_len, route_class=ci)] = r
            pos += size
        done = self.run()
        out = np.zeros((n, prompts.shape[1] + gen_len), np.int32)
        for c in done:
            if c.rid in rid_of:
                out[rid_of[c.rid], : len(c.tokens)] = c.tokens
        return out


__all__ = ["ServingEngine", "Request", "Completion", "EngineStats"]

"""Paged KV-cache pool: fixed page arena + per-slot page-index tables.

The paper's configuration discipline — work at the granularity the
memory hierarchy can actually hold (§3.3) — applied to serving memory:
instead of one dense ``seq_cap`` KV lane per slot (``n_slots × max_len``
bytes regardless of load), the engine owns a fixed arena of fixed-size
**pages** and each slot holds a small index list mapping its logical
cache positions onto arena pages.  Memory then scales with *live
tokens*: a slot allocates only the pages its request actually needs
(``ceil(min(prompt + max_new, s_cache) / page_size)``) and returns them
to the free list the moment it retires — EOS-stopped requests free
mid-stream, budget-stopped at their last token — so the next admission
reuses them immediately.

Host-side only: the device never sees this object.  The engine passes a
fresh ``(B, W)`` int32 page-table array into every jitted step (exactly
like the per-slot position vector from PR 5), and the arena itself is a
donated decode-state leaf ``(L, n_pages, page_size, Hkv, Dh)``.

Layout invariants the decode path relies on:

  * ``W · page_size == s_cache`` exactly — the gathered per-slot view
    reshapes to the dense cache lane shape, which is what makes the XLA
    gather fallback *bit-identical* to the dense slot-table path.
  * Pages are **pod-partitioned**: pod ``p`` allocates only from
    ``[p · pages_per_pod, (p+1) · pages_per_pod)``, so under the
    class-sharded mixed step the arena shards on its page dim exactly
    like a dense cache shards on its slot dim, with no cross-pod
    gathers.  (The engine localizes table entries per shard.)
  * Unallocated table entries hold :data:`SENTINEL` — far out of range,
    so jit scatters drop the write (``mode="drop"``) and jit gathers
    clip to an arbitrary page whose values are always masked off.
  * One shared **phantom page set per pod**: every free-but-refreshed
    lane points at the same pages, so phantom rows (which all carry the
    identical zero-prompt content — required for MoE cross-row
    bit-identity with the dense engine) cost one lane of pages per pod
    instead of one per slot.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Far beyond any real arena: scatters drop it, gathers clip it, and it
# survives per-pod localization (subtracting a pod offset) still
# out-of-range.  int32 to match the device table dtype.
SENTINEL = np.int32(1 << 30)


def divisor_page_size(s_cache: int, requested: int) -> int:
    """The largest divisor of ``s_cache`` that is ``<= requested``.

    The table width must satisfy ``W · page_size == s_cache`` exactly
    (the gathered view reshapes to the dense lane — the bit-identity
    contract), so a requested page size that does not divide the cache
    length rounds *down* to the nearest divisor.
    """

    ps = max(1, min(int(requested), int(s_cache)))
    while s_cache % ps:
        ps -= 1
    return ps


@dataclasses.dataclass(frozen=True)
class PageSpec:
    """Static shape of one pool: page granularity and arena capacity."""

    page_size: int       # tokens per page (divides s_cache)
    pages_per_slot: int  # W — table width; W * page_size == s_cache
    pages_per_pod: int   # physical pages in each pod's arena partition
    n_pods: int

    @property
    def n_pages(self) -> int:
        return self.pages_per_pod * self.n_pods

    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` of cache (capped at the table width)."""

        need = -(-int(n_tokens) // self.page_size)  # ceil
        return min(need, self.pages_per_slot)


class PagePool:
    """Free-list page allocator over a pod-partitioned arena (host side).

    Slots are pod-major (slot ``s`` belongs to pod ``s // c_max``) and
    allocate only from their pod's partition.  Allocation is
    all-or-nothing per request: the engine reserves every page a request
    can touch at admission time, so decode never hits mid-stream
    exhaustion — admission *defers* instead (the pool-exhaustion
    contract: a deferred request never corrupts live slots).
    """

    def __init__(self, spec: PageSpec, c_max: int):
        self.spec = spec
        self.c_max = int(c_max)
        n_slots = spec.n_pods * self.c_max
        self.table = np.full((n_slots, spec.pages_per_slot), SENTINEL, np.int32)
        pp = spec.pages_per_pod
        # LIFO free lists (pop from the end): lowest page ids first.
        self._free = [
            list(range((p + 1) * pp - 1, p * pp - 1, -1))
            for p in range(spec.n_pods)
        ]
        self.allocs = 0          # cumulative pages ever allocated
        self.peak_live = 0
        self.phantom: "np.ndarray | None" = None  # (n_pods, W) shared rows

    # -- accounting --------------------------------------------------------

    @property
    def pages_free(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def pages_live(self) -> int:
        return self.spec.n_pages - self.pages_free

    def pod_of(self, slot: int) -> int:
        return slot // self.c_max

    def _bump(self, n: int):
        self.allocs += n
        self.peak_live = max(self.peak_live, self.pages_live)

    # -- allocation --------------------------------------------------------

    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Reserve pages covering ``n_tokens`` for ``slot`` (all-or-nothing).

        Returns False — leaving the pool and the slot's row untouched —
        when the slot's pod partition cannot cover the request.
        """

        need_cols = self.spec.pages_for(n_tokens)
        row = self.table[slot]
        have = int((row != SENTINEL).sum())
        missing = need_cols - have
        if missing <= 0:
            return True
        free = self._free[self.pod_of(slot)]
        if len(free) < missing:
            return False
        for col in range(have, need_cols):
            row[col] = free.pop()
        self._bump(missing)
        return True

    def free_slot(self, slot: int) -> int:
        """Return every page of ``slot`` to its pod's free list; returns count."""

        row = self.table[slot]
        pages = row[row != SENTINEL]
        if len(pages):
            self._free[self.pod_of(slot)].extend(int(p) for p in pages)
            row[:] = SENTINEL
        return int(len(pages))

    def alloc_phantom(self, *, per_slot: bool = False) -> np.ndarray:
        """Reserve the phantom page set for free-but-live (pad) lanes.

        ``per_slot=False`` (row-local archs): one shared lane per pod —
        every refreshed free lane of pod ``p`` points at row ``p`` of the
        returned ``(n_pods, W)`` table.  Their writes are identical by
        construction (same zero-prompt streams at the same positions), so
        sharing is exact, and the fixed overhead is one lane per pod
        instead of one per free slot.

        ``per_slot=True`` (MoE archs): one lane per *slot* — ``(n_slots,
        W)``, each row drawn from its slot's pod partition.  MoE capacity
        routing ranks tokens by a cumsum over the merged decode group, so
        *identical* pad rows can be dropped differentially when capacity
        binds; their streams then diverge and a shared page would take
        conflicting writes.  A private phantom lane per slot reproduces
        the dense engine's pad lanes exactly (each owns its content), at
        the dense cost for free lanes only.

        Reserved once, never freed.
        """

        if self.phantom is not None:
            return self.phantom
        w = self.spec.pages_per_slot
        n_rows = self.spec.n_pods * self.c_max if per_slot else self.spec.n_pods
        rows = np.full((n_rows, w), SENTINEL, np.int32)
        for r in range(n_rows):
            p = self.pod_of(r) if per_slot else r
            free = self._free[p]
            if len(free) < w:
                raise ValueError(
                    f"pool too small: pod {p} has {len(free)} free pages, "
                    f"phantom lane needs {w} (pages_per_pod="
                    f"{self.spec.pages_per_pod})"
                )
            for col in range(w):
                rows[r, col] = free.pop()
        self._bump(n_rows * w)
        self.phantom = rows
        return rows

    def localize(self, table: np.ndarray, pod_of_row: np.ndarray) -> np.ndarray:
        """Rewrite global page ids as pod-local ids (class-sharded step).

        Under the mixed shard_map each pod's shard holds only its arena
        partition, so entries must index within it.  SENTINEL stays out
        of range after the subtraction (it dwarfs any real offset).
        """

        off = (pod_of_row * self.spec.pages_per_pod).astype(np.int32)
        return table - off[:, None]


__all__ = ["PagePool", "PageSpec", "SENTINEL", "divisor_page_size"]

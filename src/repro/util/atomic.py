"""Durable atomic file writes: tempfile + fsync + rename, once.

Extracted from the tuning cache's save path so every JSON artifact writer
in the repo — tuning cache, checkpoint manifest/commit marker, trace and
metrics savers, bench artifacts — shares one audited implementation
instead of five ad-hoc ones.  A reader racing any of these sees either
the old file or the new file, never a torn write; a crash between write
and publish leaves the old file intact.

The full durability recipe, in order:

1. ``mkstemp`` in the **target's own directory** — same filesystem, so
   the final rename is atomic (a cross-device rename silently degrades
   to copy+delete).
2. write + flush.
3. ``os.fsync(fd)`` — the bytes reach the disk *before* the rename
   publishes them (the fsync-before-rename audit: without it, a crash
   after the rename can expose an empty file under the final name).
4. ``os.replace`` — atomic publication.
5. fsync the directory — the rename itself survives a crash.

This module is stdlib-only on purpose: ``observability.trace`` (which
deliberately imports neither jax nor numpy) adopts it too.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Callable, Optional


def fsync_dir(path: str) -> None:
    """Best-effort fsync of a directory (persists renames within it)."""

    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # platforms/filesystems that refuse O_RDONLY on dirs
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write_text(
    path: str,
    text: str,
    *,
    prefix: str = ".tmp-",
    suffix: str = "",
    durable: bool = True,
) -> str:
    """Atomically publish ``text`` at ``path``; returns ``path``.

    ``durable=False`` skips the fsyncs (atomicity without the disk
    barrier) for callers where a post-crash loss of the *newest* version
    is acceptable as long as no torn file is ever visible.
    """

    path = os.path.abspath(path)
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=prefix, suffix=suffix)
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            if durable:
                os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    if durable:
        fsync_dir(d)
    return path


def atomic_write_json(
    path: str,
    payload: Any,
    *,
    indent: int = 1,
    sort_keys: bool = True,
    default: Optional[Callable[[Any], Any]] = None,
    newline: bool = True,
    prefix: str = ".tmp-",
    durable: bool = True,
) -> str:
    """Atomically publish ``payload`` as JSON at ``path``; returns ``path``."""

    text = json.dumps(payload, indent=indent, sort_keys=sort_keys, default=default)
    if newline:
        text += "\n"
    return atomic_write_text(
        path, text, prefix=prefix, suffix=".json", durable=durable
    )


__all__ = ["atomic_write_json", "atomic_write_text", "fsync_dir"]

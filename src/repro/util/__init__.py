"""Small dependency-free utilities shared across subsystems."""

from repro.util.atomic import atomic_write_json, atomic_write_text, fsync_dir

__all__ = ["atomic_write_json", "atomic_write_text", "fsync_dir"]

"""distributed substrate."""

"""Distributed-optimization collectives.

``compressed_crosspod_mean`` implements int8-quantized gradient reduction
across the ``pod`` axis with error feedback: within a pod gradients reduce
in full precision over ICI (cheap); across pods (DCI — the expensive hop)
each pod exchanges int8 blocks via all_gather and sums locally.  Wire
bytes drop 4× vs fp32 all-reduce; the quantization residual is carried to
the next step (error feedback), keeping convergence unbiased in practice
[Seide et al. 2014; Karimireddy et al. 2019].

Implemented with ``shard_map`` so the collective schedule is explicit —
the HLO the roofline parser sees contains the real int8 all-gather.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.execution import compat_shard_map


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization -> (q, scale)."""

    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def _crosspod_mean_one(g, err, axis: str):
    """Per-shard body: quantize (g + err), all_gather int8, local sum."""

    gf = g.astype(jnp.float32) + err
    q, scale = quantize_int8(gf)
    new_err = gf - dequantize_int8(q, scale)
    qs = jax.lax.all_gather(q, axis)          # (n_pods, ...) int8 on the wire
    scales = jax.lax.all_gather(scale, axis)  # (n_pods,) fp32 (tiny)
    mean = jnp.tensordot(
        scales, qs.astype(jnp.float32), axes=([0], [0])
    ) / jax.lax.psum(1, axis)
    return mean.astype(g.dtype), new_err


def compressed_crosspod_mean(grads, err_tree, mesh: Mesh, *, axis: str = "pod"):
    """Mean gradients across the pod axis with int8 wire format.

    grads: pytree already reduced within pods (i.e. per-pod means);
    err_tree: error-feedback residuals (same structure, fp32).
    Returns (mean_grads, new_err_tree).
    """

    if axis not in mesh.axis_names:
        return grads, err_tree

    other = tuple(a for a in mesh.axis_names if a != axis)

    def one(g, e):
        gspec = P(*([None] * g.ndim))
        # compat_shard_map handles the check_rep→check_vma kwarg rename
        # (the bare check_vma call was a TypeError on jax 0.4.x).
        fn = compat_shard_map(
            functools.partial(_crosspod_mean_one, axis=axis),
            mesh=mesh,
            in_specs=(gspec, gspec),
            out_specs=(gspec, gspec),
        )
        return fn(g, e)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in out]),
        jax.tree.unflatten(tdef, [o[1] for o in out]),
    )


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "compressed_crosspod_mean",
    "init_error_feedback",
]

"""PartitionSpec rules for every parameter / activation / cache tensor.

Axes:

  * ``pod``   — data parallelism *across* pods (multi-pod mesh only);
                gradients all-reduce over DCI, parameters replicated (or
                int8-compressed cross-pod reduction, see collectives.py),
  * ``data``  — within-pod data parallelism; in ``fsdp`` mode parameters
                and optimizer state additionally shard over this axis
                (ZeRO-3 island per pod — all-gathers stay on ICI),
  * ``model`` — tensor parallelism (Megatron col/row split).

This is the fine-grain/symmetric half of the paper's scheme (its Loop 4);
the coarse/asymmetric half partitions the *batch* across pods via
``core.asymmetric`` (its Loops 1/3).

The rules are name-based and rank-generic: ``w1`` is column-parallel
whether it is ``(L, D, F)`` dense or ``(L, E, D, F)`` MoE.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Column-parallel: shard output features on "model", fsdp on input features.
_COL = {"wq", "wk", "wv", "w1", "w3", "wz", "wx", "wdt", "lm_head"}
# Row-parallel: shard input features on "model", fsdp on output features.
_ROW = {"wo", "w2", "out_proj"}
# Feature-sharded vectors (live on the "model"-sharded dim).
_VEC_MODEL = {"bq", "bk", "bv", "b1", "dt_bias", "A_log", "D", "norm_w", "conv_b_x"}
# fsdp-only matrices (output dim too small / must stay replicated for TP).
_NOTP = {"wbc", "router", "shared_gate"}
# Last-dim-model only (no fsdp dim available).
_LASTDIM_MODEL = {"conv_w_x"}


def _data_axis(mesh: Mesh) -> Optional[str]:
    return "data" if "data" in mesh.axis_names else None


def dp_axes(mesh: Mesh):
    """Batch-sharding axes: ("pod","data") on the multi-pod mesh."""

    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def param_pspec(path, leaf, *, fsdp: bool) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    nd = leaf.ndim
    f = "data" if fsdp else None

    if name == "embed":
        return P("model", None)
    # NOTE (refuted experiment, kept for the record — EXPERIMENTS.md §Perf
    # C-2): sharding fine-grained-expert MoE weights FSDP-only removes the
    # capacity-buffer reduction but leaves the model axis idle through the
    # MoE segment — measured 6.4× compute and 5.5× collectives WORSE on
    # qwen2-moe train_4k.  Keep TP on d_ff; true expert parallelism
    # (E % model == 0, all-to-all dispatch) is the structural fix.
    if name in _COL and nd >= 2:
        return P(*([None] * (nd - 2) + [f, "model"]))
    if name in _ROW and nd >= 2:
        return P(*([None] * (nd - 2) + ["model", f]))
    if name in _NOTP and nd >= 2:
        return P(*([None] * (nd - 2) + [f, None]))
    if name in _LASTDIM_MODEL:
        return P(*([None] * (nd - 1) + ["model"]))
    if name in _VEC_MODEL and nd >= 1:
        return P(*([None] * (nd - 1) + ["model"]))
    return P(*([None] * nd))


def _drop_indivisible(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding from dims the mesh axes don't divide (jit requires
    exact divisibility for input shardings — e.g. whisper's vocab 51865)."""

    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= mesh.shape[a]
        out.append(axes if dim % size == 0 else None)
    return P(*out)


def array_sharding(mesh: Mesh, shape, spec: P) -> NamedSharding:
    """NamedSharding with indivisible dims demoted to replication."""

    return NamedSharding(mesh, _drop_indivisible(spec, shape, mesh))


def shard_params(params, mesh: Mesh, *, fsdp: bool = True):
    """NamedSharding tree for a param pytree (works on ShapeDtypeStructs)."""

    def f(path, leaf):
        spec = param_pspec(path, leaf, fsdp=fsdp and _data_axis(mesh) is not None)
        return NamedSharding(mesh, _drop_indivisible(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(f, params)


def shard_opt_state(opt_state, params_sharding, mesh: Mesh):
    """m/v mirror the params; step is replicated."""

    return {
        "m": params_sharding,
        "v": params_sharding,
        "step": NamedSharding(mesh, P()),
    }


def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    """Batch tensors (B, ...). Falls back to replication when B is tiny."""

    axes = dp_axes(mesh)
    if axes is None:
        return P(None)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if batch_size % size != 0:
        # long_500k: B=1 — the batch axis cannot shard; sequence/cache
        # dims carry the parallelism instead (see cache_pspec).
        return P(None)
    return P(axes)


def batch_sharding(mesh: Mesh, batch_tree):
    def f(leaf):
        spec = batch_pspec(mesh, leaf.shape[0])
        pad = [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*(list(spec) + pad)))

    return jax.tree.map(f, batch_tree)


def cache_pspec(mesh: Mesh, shape) -> P:
    """Decode caches (L, B, S, H, Dh) / SSM states (L, B, H, N, P).

    B shards over the dp axes; dim 2 (cache length for KV caches, heads for
    SSM states) additionally shards over "model" — a 64L×32k×B128 KV cache
    is 1.1 TB and must spread over the full mesh, not just the data axis
    (259 GiB/device measured without this; see EXPERIMENTS.md §Dry-run).
    When B cannot shard (B=1 long-context), dim 2 carries the data axes too.
    """

    axes = dp_axes(mesh)
    nd = len(shape)
    if axes is None or nd < 3:
        return P(*([None] * nd))
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    model = mesh.shape.get("model", 1)
    b = shape[1]
    dim2 = []
    if model > 1 and shape[2] % model == 0:
        dim2 = ["model"]
    if b % size == 0:
        return P(*([None, axes] + [tuple(dim2) if dim2 else None] + [None] * (nd - 3)))
    if shape[2] % (size * model) == 0:
        return P(*([None, None, (axes + ("model",)) if dim2 else axes]
                   + [None] * (nd - 3)))
    return P(*([None, None] + [tuple(dim2) if dim2 else None] + [None] * (nd - 3)))


def cache_sharding(mesh: Mesh, cache_tree):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, cache_pspec(mesh, leaf.shape)), cache_tree
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Pod→class mapping as shard_map specs (the class-sharded step's inputs)
# ---------------------------------------------------------------------------
#
# ``execution.class_sharded`` runs one program per device class inside a
# single SPMD step (shard_map over the pod axis).  These helpers express
# the pieces it needs as data + PartitionSpecs: the per-pod class index
# (sharded over the pod axis so each shard reads its own class), and the
# batch/state/replicated specs for the wrapped step function.


def pod_class_indices(asym) -> np.ndarray:
    """``(n_pods,)`` int32 class index per pod — the pod→class mapping."""

    return np.asarray(asym.pod_class_indices(), np.int32)


def pod_class_specs(asym, *, axis: str = "pod") -> tuple[np.ndarray, P]:
    """The pod→class mapping plus the spec that shards it one-per-pod."""

    return pod_class_indices(asym), P(axis)


def pod_batch_specs(batch_tree, *, axis: str = "pod"):
    """Batch tensors shard their leading (row) dim over the pod axis."""

    return jax.tree.map(lambda _: P(axis), batch_tree)


def pod_state_specs(state_tree, *, axis: str = "pod", dim: int = 1):
    """Decode caches / SSM states shard their batch dim (default dim 1)."""

    def f(leaf):
        spec = [None] * leaf.ndim
        spec[dim] = axis
        return P(*spec)

    return jax.tree.map(f, state_tree)


def pod_decode_specs(state_spec, *, axis: str = "pod",
                     batch_keys: Sequence[str] = ("tokens",)):
    """(in_specs, out_specs) for a slot-table decode step over the pod axis.

    The serving engine's step is ``decode(params, batch, state, pos)``
    with ``B = n_pods × c_max`` pod-major slots: params replicated, every
    batch tensor (``"tokens"`` (B,1), and for the paged engine
    ``"page_table"`` (B,W) and ``"live"`` (B,)) sharded one slot region
    per pod, positions likewise, and the decode state sharded on its
    batch dim — the slot dim for dense caches, the *page* dim for the
    paged arena (``pod_state_specs`` dim 1 covers both, since the arena
    is pod-partitioned on pages exactly as the dense cache is on slots).
    The same specs serve the engine's bulk prefill (tokens are then
    ``(B, P)`` — the leading slot dim still shards over pods).
    """

    sspecs = pod_state_specs(state_spec, axis=axis)
    in_specs = (P(), {k: P(axis) for k in batch_keys}, sspecs, P(axis))
    out_specs = (P(axis), sspecs)
    return in_specs, out_specs


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------
#
# With FSDP weight rules (contracting dim sharded on "data"), GSPMD's
# default propagation finds a zero-collective partition that REPLICATES the
# batch and tensor-shards every activation over (data, model) — 129 GiB of
# per-device temps on deepseek-7b train_4k (measured; EXPERIMENTS.md §Perf
# iteration 1).  Pinning the batch axis at layer boundaries forces the
# intended FSDP semantics (weights all-gather; activations stay
# batch-sharded).  Models call :func:`constrain_batch`; the trainer/dry-run
# install the mesh via :func:`use_mesh_for_activations`.

_ACT_MESH: Optional[Mesh] = None
_ACT_SEQ: bool = False
# Axes that are *manual* in the surrounding shard_map body (trace-time
# state): activation constraints must not mention them — their extent is
# already fixed by the manual sharding, and GSPMD rejects constraints over
# manual axes.  Set by execution.class_sharded while tracing its body.
_ACT_MANUAL: frozenset = frozenset()


@contextlib.contextmanager
def activation_manual_axes(axes: Sequence[str]):
    """Trace-time guard: drop these mesh axes from activation constraints.

    Used while tracing inside a shard_map body where ``axes`` are manual
    (the class-sharded pod axis); nests and restores on exit.
    """

    global _ACT_MANUAL
    prev = _ACT_MANUAL
    _ACT_MANUAL = prev | frozenset(axes)
    try:
        yield
    finally:
        _ACT_MANUAL = prev


def _drop_manual(axes):
    """Filter manual axes out of one spec entry (name | tuple | None)."""

    if axes is None or not _ACT_MANUAL:
        return axes
    ax = axes if isinstance(axes, tuple) else (axes,)
    kept = tuple(a for a in ax if a not in _ACT_MANUAL)
    if not kept:
        return None
    return kept if isinstance(axes, tuple) else kept[0]


def use_mesh_for_activations(mesh: Optional[Mesh], *, seq_shard: bool = False):
    """Install (or clear, with None) the mesh for activation constraints.

    ``seq_shard=True`` additionally shards the *sequence* dim of layer-
    boundary activations over the "model" axis (Megatron-style sequence
    parallelism).  The remat'd scan saves layer-input carries — with SP the
    saved carry shrinks by the model-axis size (16×), which on deepseek-7b
    train_4k is the difference between 46.7 and single-digit GiB/device
    (EXPERIMENTS.md §Perf iteration 2).  GSPMD inserts the all-gather
    before attention and the reduce-scatter after the block projections.
    """

    global _ACT_MESH, _ACT_SEQ
    _ACT_MESH = mesh
    _ACT_SEQ = seq_shard


def constrain(x, spec_axes: tuple):
    """Generic activation constraint; indivisible/absent axes are dropped.

    ``spec_axes``: one entry per dim — an axis name, a tuple of names, or
    None.  No-op when no mesh is installed.
    """

    mesh = _ACT_MESH
    if mesh is None:
        return x
    out = []
    for dim, axes in zip(x.shape, spec_axes):
        axes = _drop_manual(axes)
        if axes is None:
            out.append(None)
            continue
        ax = axes if isinstance(axes, tuple) else (axes,)
        if not all(a in mesh.axis_names for a in ax):
            out.append(None)
            continue
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        out.append(axes if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*out)))


def constrain_qkv_context_parallel(q, k, v, n_heads: int):
    """Context-parallel attention for head counts the model axis can't split.

    qwen2.5's 40 query heads don't divide the 16-way model axis; left to
    itself GSPMD reshards every attention reshape with all-to-alls
    (57 s collective term measured on prefill_32k).  Instead: shard the
    *query sequence* over "model" (each rank computes its q-slice against
    the full K/V, which all-gather once per layer) — classic context
    parallelism.  No-op when heads divide the axis or no mesh is installed.
    """

    mesh = _ACT_MESH
    if mesh is None or "model" not in mesh.axis_names:
        return q, k, v
    msize = mesh.shape["model"]
    if msize <= 1 or n_heads % msize == 0:
        return q, k, v
    if q.shape[1] % msize != 0 or q.shape[1] == 1:
        return q, k, v
    axes = dp_axes(mesh)
    q = constrain(q, (axes, "model", None, None))
    k = constrain(k, (axes, None, None, None))
    v = constrain(v, (axes, None, None, None))
    return q, k, v


def constrain_batch(x, *, extra: Optional[tuple] = None, allow_seq: bool = True):
    """Constrain a (B, ...) activation to batch-sharded over the dp axes.

    ``extra``: optional PartitionSpec tail for the trailing dims (e.g.
    ("model",) on the vocab dim of logits).
    """

    mesh = _ACT_MESH
    if mesh is None:
        return x
    axes = _drop_manual(dp_axes(mesh))
    if axes is None:
        return x
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if x.shape[0] % size != 0:
        return x
    tail = list(extra) if extra is not None else []
    mid = [None] * (x.ndim - 1 - len(tail))
    if (
        _ACT_SEQ
        and allow_seq
        and not tail
        and x.ndim >= 3
        and mid
        and x.shape[1] % mesh.shape.get("model", 1) == 0
        and mesh.shape.get("model", 1) > 1
    ):
        mid[0] = "model"
    spec = P(*([axes] + mid + tail))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


__all__ = [
    "param_pspec",
    "shard_params",
    "shard_opt_state",
    "batch_pspec",
    "batch_sharding",
    "cache_pspec",
    "cache_sharding",
    "dp_axes",
    "pod_decode_specs",
    "replicated",
    "use_mesh_for_activations",
    "constrain_batch",
]

"""Ragged paged-attention decode kernels (single-token query, paged KV).

The serving engine's paged KV pool stores each slot's cache as a list of
fixed-size pages in a shared arena (``runtime/paging.py``); decode
attention must gather K/V *through the page table*.  Two routes, both
registered in ``execution.BACKENDS`` (op family ``"paged_attn"``):

  * :func:`paged_attention_xla` — gather + masked softmax in exactly the
    dense decode path's primitive sequence (same einsum contractions,
    same fp32 softmax, same ``-1e30`` masking), so on identical cache
    *values* the result is **bit-identical** to
    ``layers.decode_attention`` over a dense lane.  The CPU/CI route and
    the engine's exactness reference.
  * :func:`paged_attention_pallas` — a Pallas kernel streaming one page
    per grid step with an online-softmax accumulator (the
    ``flash_attention.py`` pattern), the page table scalar-prefetched so
    each step's DMA source address is a *data-dependent* page.  Online
    softmax reorders the reduction, so this route is tolerance-equal,
    not bit-equal (per-dtype tolerances in tests).  ``interpret=True``
    is its CPU twin for the parity harness.

Shapes (one decode token per row):

  q           (B, Hq, Dh)        the new token's query heads
  pages_k/v   (P, ps, Hkv, Dh)   the page arena (one layer's)
  page_table  (B, W)  int32      per-row page ids; ``W * ps == s_cache``
  pos         (B,)    int32      per-row absolute positions

Masking: a row attends its logical cache prefix ``[0, min(pos+1,
s_cache))`` — equivalent to the dense path's linear mask *and* its ring
(sliding-window) mask, since a wrapped ring attends its full buffer.
Unallocated table entries are far-out-of-range sentinels; gathers clip
them to an arbitrary page whose positions the mask always excludes (the
allocator guarantees every in-prefix page is allocated).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _check_shapes(q, pages_k, pages_v, page_table, pos):
    b, hq, d = q.shape
    p, ps, hkv, d2 = pages_k.shape
    if pages_v.shape != pages_k.shape:
        raise ValueError(f"k/v arenas differ: {pages_k.shape} vs {pages_v.shape}")
    if d2 != d or hq % hkv:
        raise ValueError(f"q {q.shape} incompatible with pages {pages_k.shape}")
    if page_table.shape[0] != b or pos.shape != (b,):
        raise ValueError(
            f"table {page_table.shape} / pos {pos.shape} do not cover batch {b}"
        )
    return b, hq, d, p, ps, hkv, page_table.shape[1]


def paged_gather(pages: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """Materialize per-row dense views: (P, ps, H, D) → (B, W·ps, H, D).

    Sentinel entries clip to the last page; the caller's validity mask
    must exclude every position they back (the pool's invariant).
    """

    p, ps, h, d = pages.shape
    b, w = page_table.shape
    idx = jnp.clip(page_table, 0, p - 1)
    view = pages[idx]  # (B, W, ps, H, D)
    return view.reshape(b, w * ps, h, d)


def _valid_mask(pos: jnp.ndarray, s_cache: int) -> jnp.ndarray:
    """(B, s_cache) bool — the logical prefix each row may attend.

    ``k_idx < min(pos+1, s_cache)``: equals the dense linear mask
    (``k_idx <= pos``, with every index valid once ``pos >= s_cache``)
    and the dense ring mask (``k_idx <= pos % s_cache`` until wrapped,
    everything after) on their shared domain ``k_idx ∈ [0, s_cache)``.
    """

    k_idx = jnp.arange(s_cache)
    limit = jnp.minimum(pos[:, None] + 1, s_cache)
    return k_idx[None, :] < limit


def paged_attention_xla(q, pages_k, pages_v, page_table, pos):
    """Gather fallback — the dense decode arithmetic over a paged gather.

    Primitive-for-primitive the same sequence as
    ``layers.decode_attention``'s read side (grouped GQA einsums, fp32
    scores scaled by ``1/sqrt(Dh)``, ``-1e30`` mask, fp32 softmax), so
    given bitwise-equal cache values it is bitwise-equal to the dense
    path: masked lanes contribute exactly ``0.0`` (``exp`` underflow),
    making the output independent of garbage behind sentinel pages.
    """

    b, hq, d, _, ps, hkv, w = _check_shapes(q, pages_k, pages_v, page_table, pos)
    s_cache = w * ps
    g = hq // hkv
    ct = pages_k.dtype  # the cache/compute dtype (bf16 policy)
    view_k = paged_gather(pages_k, page_table)  # (B, s_cache, Hkv, Dh)
    view_v = paged_gather(pages_v, page_table)
    qg = q.reshape(b, 1, hkv, g, d).astype(ct)
    s = jnp.einsum(
        "bqhgd,bshd->bhgqs", qg, view_k.astype(ct),
        preferred_element_type=jnp.float32,
    ) / np.sqrt(d)
    valid = _valid_mask(jnp.asarray(pos, jnp.int32), s_cache)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1).astype(ct)
    o = jnp.einsum(
        "bhgqs,bshd->bqhgd", p_attn, view_v.astype(ct),
        preferred_element_type=jnp.float32,
    )
    return o.astype(q.dtype).reshape(b, hq, d)


# ---------------------------------------------------------------------------
# Pallas kernel: one page per grid step, online softmax
# ---------------------------------------------------------------------------


def _paged_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, ps, s_cache):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # (G, Dh)
    k = k_ref[0, 0]  # (ps, Dh) — this step's page
    v = v_ref[0, 0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, ps)
    idx = j * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    limit = jnp.minimum(pos_ref[b] + 1, s_cache)
    s = jnp.where(idx < limit, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def paged_attention_pallas(q, pages_k, pages_v, page_table, pos, *,
                           interpret: bool = False):
    """Pallas route: grid ``(B, Hkv, W)``, the page dim sequential.

    The page table and positions ride as scalar-prefetch operands
    (``PrefetchScalarGridSpec``), so each grid step's K/V *block index* —
    which arena page to DMA — is computed from the table before the body
    runs: ragged, data-dependent paging without host round-trips.
    Sentinel entries clip to the last page; the in-kernel prefix mask
    zeroes their contribution.
    """

    b, hq, d, p_total, ps, hkv, w = _check_shapes(
        q, pages_k, pages_v, page_table, pos
    )
    g = hq // hkv
    s_cache = w * ps
    q4 = q.reshape(b, hkv, g, d)
    # Page-major → head-major pages so one (page, head) pair is one block.
    kt = pages_k.transpose(0, 2, 1, 3)  # (P, Hkv, ps, Dh)
    vt = pages_v.transpose(0, 2, 1, 3)
    table = jnp.asarray(page_table, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)

    def page_index(bb, h, j, table_ref, pos_ref):
        return (jnp.clip(table_ref[bb, j], 0, p_total - 1), h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, w),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, h, j, t, pp: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, d), page_index),
            pl.BlockSpec((1, 1, ps, d), page_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda bb, h, j, t, pp: (bb, h, 0, 0)
        ),
        scratch_shapes=[
            _VMEM((g, 1), jnp.float32),
            _VMEM((g, 1), jnp.float32),
            _VMEM((g, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_kernel, scale=1.0 / np.sqrt(d), ps=ps, s_cache=s_cache
    )
    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            )
        except Exception:  # pragma: no cover
            pass
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
        **kwargs,
    )(table, pos, q4, kt, vt)
    return out.reshape(b, hq, d)


def paged_attention_pallas_interpret(q, pages_k, pages_v, page_table, pos):
    return paged_attention_pallas(q, pages_k, pages_v, page_table, pos,
                                  interpret=True)


__all__ = [
    "paged_attention_xla",
    "paged_attention_pallas",
    "paged_attention_pallas_interpret",
    "paged_gather",
]

"""Pallas TPU kernels for the performance-critical compute layers.

``gemm.py`` is the paper's contribution (GotoBLAS five-loop blocking
mapped onto BlockSpec VMEM tiling); ``flash_attention.py`` applies the
same insight to attention. ``ops.py`` wraps both behind control-tree-aware
dispatch; ``ref.py`` holds the pure-jnp oracles.
"""

from repro.kernels.ops import gemm, gemm_with_tree, linear
from repro.kernels.gemm import gemm_pallas, gemm_pallas_lean
from repro.kernels.flash_attention import flash_attention

__all__ = [
    "gemm",
    "gemm_with_tree",
    "linear",
    "gemm_pallas",
    "gemm_pallas_lean",
    "flash_attention",
]

"""Pure-jnp oracles for the GEMM kernels.

Two references:

  * :func:`gemm_ref` — the ground truth (``jnp.dot`` with fp32
    accumulation), used by every kernel allclose test.
  * :func:`blocked_gemm_ref` — a faithful transcription of the paper's
    Figure 1 five-loop BLIS algorithm (Loop 1 over ``n_c``, Loop 2 over
    ``k_c`` packing ``B_c``, Loop 3 over ``m_c`` packing ``A_c``, Loops 4/5
    over ``n_r``/``m_r`` around the micro-kernel).  It exists to validate
    the *loop structure and packing* semantics that the Pallas kernel
    mirrors at TPU block granularity.  Python loops → small shapes only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocking import BlockConfig, GotoBlocking


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """C = A @ B with fp32 accumulation (the oracle)."""

    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def blocked_gemm_ref(
    a: np.ndarray,
    b: np.ndarray,
    cfg: GotoBlocking,
) -> np.ndarray:
    """Paper Figure 1, verbatim loop structure (numpy, fp32 accumulate)."""

    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    c = np.zeros((m, n), np.float32)
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)

    for jc in range(0, n, cfg.nc):                      # Loop 1
        nc = min(cfg.nc, n - jc)
        for pc in range(0, k, cfg.kc):                  # Loop 2
            kc = min(cfg.kc, k - pc)
            b_c = b[pc : pc + kc, jc : jc + nc].copy()  # pack B_c
            for ic in range(0, m, cfg.mc):              # Loop 3
                mc = min(cfg.mc, m - ic)
                a_c = a[ic : ic + mc, pc : pc + kc].copy()  # pack A_c
                # Macro-kernel: Loops 4 and 5 around the micro-kernel.
                for jr in range(0, nc, cfg.nr):         # Loop 4
                    nr = min(cfg.nr, nc - jr)
                    for ir in range(0, mc, cfg.mr):     # Loop 5
                        mr = min(cfg.mr, mc - ir)
                        # Micro-kernel: rank-k_c update of an m_r x n_r tile.
                        c[ic + ir : ic + ir + mr, jc + jr : jc + jr + nr] += (
                            a_c[ir : ir + mr, :] @ b_c[:, jr : jr + nr]
                        )
    return c


def blocked_gemm_tpu_ref(a: jnp.ndarray, b: jnp.ndarray, cfg: BlockConfig) -> jnp.ndarray:
    """Block-accumulation oracle matching the Pallas kernel's tiling.

    Computes C block-by-block with per-(bm,bn) fp32 accumulators over bk
    slices — the same arithmetic order as the Pallas grid, so comparisons
    are bit-friendlier than against one big dot.
    """

    m, k = a.shape
    _, n = b.shape
    out = jnp.zeros((m, n), jnp.float32)
    for i0 in range(0, m, cfg.bm):
        for j0 in range(0, n, cfg.bn):
            acc = jnp.zeros((min(cfg.bm, m - i0), min(cfg.bn, n - j0)), jnp.float32)
            for k0 in range(0, k, cfg.bk):
                ab = a[i0 : i0 + cfg.bm, k0 : k0 + cfg.bk]
                bb = b[k0 : k0 + cfg.bk, j0 : j0 + cfg.bn]
                acc = acc + jnp.dot(ab, bb, preferred_element_type=jnp.float32)
            out = out.at[i0 : i0 + cfg.bm, j0 : j0 + cfg.bn].set(acc)
    return out.astype(a.dtype)


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Dense (B, S, H, D) attention oracle with optional causal/SWA mask."""

    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + (sk - sq)
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_attention_ref(
    q: jnp.ndarray,
    pages_k: jnp.ndarray,
    pages_v: jnp.ndarray,
    page_table: jnp.ndarray,
    pos: jnp.ndarray,
) -> jnp.ndarray:
    """Paged single-token decode-attention oracle (fp32 end to end).

    Deliberately *not* the production op order: ungrouped fp32 einsums
    over an eagerly gathered dense view, so both the XLA gather route and
    the online-softmax Pallas kernel are checked against independent
    arithmetic.  Shapes as in ``kernels.paged_attention``: ``q`` is
    ``(B, Hq, Dh)``, the arenas ``(P, ps, Hkv, Dh)``, the table
    ``(B, W)`` with ``W·ps`` the logical cache length, ``pos`` ``(B,)``.
    """

    b, hq, d = q.shape
    p, ps, hkv, _ = pages_k.shape
    w = page_table.shape[1]
    s_cache = w * ps
    g = hq // hkv
    idx = jnp.clip(page_table, 0, p - 1)
    view_k = pages_k[idx].reshape(b, s_cache, hkv, d).astype(jnp.float32)
    view_v = pages_v[idx].reshape(b, s_cache, hkv, d).astype(jnp.float32)
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, view_k) / np.sqrt(d)
    limit = jnp.minimum(jnp.asarray(pos, jnp.int32)[:, None] + 1, s_cache)
    valid = jnp.arange(s_cache)[None, :] < limit
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", pr, view_v)
    return o.reshape(b, hq, d).astype(q.dtype)


__all__ = [
    "gemm_ref",
    "blocked_gemm_ref",
    "blocked_gemm_tpu_ref",
    "attention_ref",
    "paged_attention_ref",
]

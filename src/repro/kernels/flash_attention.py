"""Blocked (flash) attention — the paper's tiling insight applied beyond GEMM.

Not a paper contribution, but the same architecture-aware principle: tile
the (S_q, S_k) iteration space into VMEM-resident blocks so each staged
block amortizes maximal compute, with an online-softmax accumulator taking
the role of the fp32 GEMM accumulator.  Used as the TPU hot path for the
transformer architectures; the pure-jnp chunked implementation in
``models/layers.py`` is the portable/SPMD path.

Grid: (batch*heads, S_q/bq, S_k/bk) with the K dimension sequential
("arbitrary") carrying (m, l, acc) scratch state; causal and sliding-window
masks are applied per block, and fully-masked blocks produce zero updates
(the index map still visits them — block skipping is a TODO noted in
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, window, bq, bk, sk, q_offset):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]  # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    q_idx = q_offset + qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_idx < sk  # padded K positions are invalid
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Attention over (B, S, H, D) tensors; GQA handled by the caller.

    ``q``/``k``/``v`` must share H here — the model layer repeats KV heads
    before calling (or maps over groups).  S_q and S_k are padded to block
    multiples; padded K positions are masked off via the window/causal
    logic plus an explicit validity mask on the final slice.
    """

    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    psq, psk = sq + pad_q, sk + pad_k

    # (B, S, H, D) -> (B*H, S, D)
    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], d)

    qb_, kb_, vb_ = bh(qp), bh(kp), bh(vp)

    grid = (b * h, psq // block_q, psk // block_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        bq=block_q,
        bk=block_k,
        sk=sk,
        q_offset=sk - sq,  # causal alignment when the query is a suffix
    )
    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")
            )
        except Exception:  # pragma: no cover
            pass

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh_, i, j: (bh_, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, i, j: (bh_, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, i, j: (bh_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh_, i, j: (bh_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, psq, d), q.dtype),
        scratch_shapes=[
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qb_, kb_, vb_)

    out = out.reshape(b, h, psq, d).transpose(0, 2, 1, 3)
    return out[:, :sq]


__all__ = ["flash_attention"]

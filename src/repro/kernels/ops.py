"""Jit'd public entry points for the kernels, control-tree aware.

``gemm`` is the operation the whole framework routes its projection /
FFN matmuls through.  Backend dispatch mirrors the paper's control-tree
mechanism: the executing device class's :class:`ControlTree` selects both
the blocking parameters *and* the micro-kernel implementation
(paper Section 5.3: "opens the door to the use of specific highly-tuned
micro-kernels adapted to each micro-architecture").

Backends:

  * ``"xla"``              — jnp.dot (the portable reference path; also what
                             the SPMD dry-run lowers, since Mosaic cannot
                             target the CPU backend),
  * ``"pallas"``           — the blocked TPU kernel (hot path on TPU),
  * ``"pallas_interpret"`` — kernel body interpreted on CPU (validation).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blocking import BlockConfig, derive_block_config
from repro.core.control_tree import ControlTree
from repro.kernels.gemm import gemm_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    config: Optional[BlockConfig] = None,
    backend: str = "auto",
    out_dtype=None,
) -> jnp.ndarray:
    """``a @ b`` over the last/first axes with leading dims collapsed.

    ``a`` may carry arbitrary leading (batch/sequence) dims; ``b`` is 2-D
    ``(k, n)`` — the linear-layer contraction every model in the zoo uses.
    """

    out_dtype = out_dtype or a.dtype
    if b.ndim != 2:
        raise ValueError(f"gemm expects 2-D rhs, got {b.shape}")
    lead = a.shape[:-1]
    k = a.shape[-1]
    a2 = a.reshape(-1, k)

    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"

    if backend == "xla":
        # Declare the dot output in the compute dtype: the MXU still
        # accumulates fp32 per shard, but GSPMD then places the
        # tensor-parallel all-reduce on the bf16 tensor instead of an fp32
        # intermediate — half the wire bytes on every row-parallel
        # projection (EXPERIMENTS.md §Perf A).
        pet = jnp.float32 if out_dtype == jnp.float32 else out_dtype
        out = jnp.dot(a2, b, preferred_element_type=pet).astype(out_dtype)
    elif backend == "pallas":
        out = gemm_pallas(a2, b, config, out_dtype=out_dtype)
    elif backend == "pallas_interpret":
        out = gemm_pallas(a2, b, config, out_dtype=out_dtype, interpret=True)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out.reshape(*lead, b.shape[1])


def gemm_with_tree(a: jnp.ndarray, b: jnp.ndarray, tree: ControlTree, out_dtype=None):
    """GEMM configured by a device class's control tree."""

    return gemm(a, b, config=tree.block, backend=tree.backend, out_dtype=out_dtype)


def linear(x, w, b=None, *, config=None, backend: str = "auto"):
    """Affine layer on top of :func:`gemm` (bias in fp32, cast back)."""

    y = gemm(x, w, config=config, backend=backend)
    if b is not None:
        y = (y.astype(jnp.float32) + b.astype(jnp.float32)).astype(y.dtype)
    return y


__all__ = ["gemm", "gemm_with_tree", "linear"]

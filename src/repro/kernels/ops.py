"""Jit'd public entry points for the kernels, execution-context aware.

``gemm`` is the operation the whole framework routes its projection /
FFN matmuls through.  Backend dispatch mirrors the paper's control-tree
mechanism: the executing device class's :class:`ControlTree` selects both
the blocking parameters *and* the micro-kernel implementation
(paper Section 5.3: "opens the door to the use of specific highly-tuned
micro-kernels adapted to each micro-architecture").

Routing happens through :mod:`repro.core.execution`: an ambient
:class:`~repro.core.execution.ExecutionContext` (activated by the trainer,
server, benchmarks, or ``AsymmetricMesh.execution_context``) supplies the
backend and per-class block shapes, so model code calls ``gemm(a, b)``
bare.  Explicit ``config=``/``backend=`` arguments always win over the
context; with no context active the pre-context defaults apply unchanged
(``"auto"`` probes TPU, ``config=None`` resolves via the env-var cache).

Backends (the dispatch table lives in ``execution.BACKENDS``):

  * ``"xla"``              — jnp.dot (the portable reference path; also what
                             the SPMD dry-run lowers, since Mosaic cannot
                             target the CPU backend),
  * ``"pallas"``           — the blocked pipelined TPU kernel (hot path on
                             TPU for full-VMEM classes),
  * ``"pallas_lean"``      — the VMEM-lean k-streaming variant (single-
                             buffered staging, resident accumulator) for
                             little-VMEM classes — the paper's per-class
                             micro-kernel, selected by that class's tree,
  * ``"pallas_interpret"`` / ``"pallas_lean_interpret"`` — the same kernel
                             bodies interpreted on CPU (validation; the
                             parity harness runs every variant this way).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import execution as X
from repro.core.blocking import BlockConfig
from repro.core.control_tree import ControlTree


def gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    config: Optional[BlockConfig] = None,
    backend: str = "auto",
    out_dtype=None,
) -> jnp.ndarray:
    """``a @ b`` over the last/first axes with leading dims collapsed.

    ``a`` may carry arbitrary leading (batch/sequence) dims; ``b`` is 2-D
    ``(k, n)`` — the linear-layer contraction every model in the zoo uses.
    """

    out_dtype = out_dtype or a.dtype
    if b.ndim != 2:
        raise ValueError(f"gemm expects 2-D rhs, got {b.shape}")
    lead = a.shape[:-1]
    k = a.shape[-1]
    a2 = a.reshape(-1, k)

    ctx = X.current_context()
    if ctx is not None:
        if backend == "auto":
            backend = ctx.tree.backend
        if config is None and X.resolve_backend(backend) != "xla":
            config = ctx.block_config(
                a2.shape[0], k, b.shape[1], a2.dtype.name, a2.dtype.itemsize
            )

    out = X.dispatch_gemm(a2, b, config=config, backend=backend, out_dtype=out_dtype)
    return out.reshape(*lead, b.shape[1])


def gemm_with_tree(a: jnp.ndarray, b: jnp.ndarray, tree: ControlTree, out_dtype=None):
    """GEMM configured by a device class's control tree."""

    with X.context_for_tree(tree):
        return gemm(a, b, out_dtype=out_dtype)


def linear(x, w, b=None, *, config=None, backend: str = "auto"):
    """Affine layer on top of :func:`gemm` (bias in fp32, cast back)."""

    y = gemm(x, w, config=config, backend=backend)
    if b is not None:
        y = (y.astype(jnp.float32) + b.astype(jnp.float32)).astype(y.dtype)
    return y


__all__ = ["gemm", "gemm_with_tree", "linear"]

"""GotoBLAS-style blocked GEMM as a Pallas TPU kernel.

TPU adaptation of the paper's Figure 1.  The mapping of the five BLIS loops
onto the Pallas grid (HBM → VMEM → MXU instead of RAM → L2 → L1 → regs):

  ==========  =============================  =================================
  BLIS loop   paper role                     Pallas realization
  ==========  =============================  =================================
  Loop 1/3    coarse partition across        grid dims 0/1 over (M/bm, N/bn)
              clusters / L2-resident A_c     — "parallel" semantics; blocks
                                             staged into VMEM by BlockSpec
  Loop 2      k_c panels / pack B_c          grid dim 2 over K/bk —
                                             "arbitrary" (sequential) with a
                                             VMEM fp32 accumulator
  Loop 4/5    micro-kernel sweep from L1     the jnp.dot inside the kernel
                                             body, lowered onto the MXU
  micro-k     m_r x n_r register tile        128x128 systolic MXU tile
  packing     explicit A_c/B_c copies        implicit: BlockSpec index_map +
                                             double-buffered HBM→VMEM DMA
  ==========  =============================  =================================

The per-class ``BlockConfig`` (control tree) chooses (bm, bk, bn) exactly
like the paper chooses (m_c, k_c) per core type.  On this CPU-only
container the kernel is validated with ``interpret=True``; on TPU the same
code JITs through Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific helpers are importable on CPU; guard for API drift.
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

from repro.core.blocking import BlockConfig, pad_to_blocks


def resolve_block_config(m: int, k: int, n: int, dtype) -> BlockConfig:
    """Config used when the caller passes ``cfg=None``.

    Delegates to the single resolution path in
    :func:`repro.core.execution.resolve_block_config`: with
    ``$REPRO_TUNING_CACHE`` set, the tuned entry for this
    (spec, dtype, shape bucket) wins; otherwise — and always when the env
    var is unset — the analytical derivation is used, so defaults are
    unchanged.  The kernel itself is identical either way; only the block
    shapes differ.
    """

    from repro.core.execution import resolve_block_config as _resolve

    cfg, _ = _resolve(m, k, n, dtype_name=dtype.name, dtype_bytes=dtype.itemsize)
    return cfg


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref):
    """Grid point (i, j, k): C[i,j] += A[i,k] @ B[k,j] with fp32 VMEM acc."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    cfg: Optional[BlockConfig] = None,
    *,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """``C = A @ B`` via the blocked Pallas kernel.

    Pads (M, K, N) up to block multiples (the paper's edge-case handling of
    partial panels), launches the (M/bm, N/bn, K/bk) grid, and slices the
    result back.  ``interpret=True`` executes the kernel body in Python on
    CPU — the validation mode used by the test suite.
    """

    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    if cfg is None:
        cfg = resolve_block_config(m, k, n, a.dtype)

    pm, pk, pn = pad_to_blocks(m, k, n, cfg)
    if (pm, pk) != (m, k):
        a = jnp.pad(a, ((0, pm - m), (0, pk - k)))
    if (pk, pn) != (k, n):
        b = jnp.pad(b, ((0, pk - k), (0, pn - n)))

    grid = (pm // cfg.bm, pn // cfg.bn, pk // cfg.bk)

    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            )
        except Exception:  # pragma: no cover - older API name
            pass

    scratch = (
        [_VMEM((cfg.bm, cfg.bn), jnp.float32)]
        if _VMEM is not None
        else [pl.MemorySpace.ANY((cfg.bm, cfg.bn), jnp.float32)]  # pragma: no cover
    )

    out = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.bm, cfg.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cfg.bk, cfg.bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((cfg.bm, cfg.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(a, b)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("cfg", "out_dtype", "interpret"))
def gemm_pallas_jit(a, b, cfg=None, out_dtype=None, interpret=False):
    return gemm_pallas(a, b, cfg, out_dtype=out_dtype, interpret=interpret)


__all__ = ["gemm_pallas", "gemm_pallas_jit", "resolve_block_config"]

"""GotoBLAS-style blocked GEMM as Pallas TPU kernels — per-class variants.

TPU adaptation of the paper's Figure 1.  The mapping of the five BLIS loops
onto the Pallas grid (HBM → VMEM → MXU instead of RAM → L2 → L1 → regs):

  ==========  =============================  =================================
  BLIS loop   paper role                     Pallas realization
  ==========  =============================  =================================
  Loop 1/3    coarse partition across        grid dims 0/1 over (M/bm, N/bn)
              clusters / L2-resident A_c     — "parallel" semantics; blocks
                                             staged into VMEM by BlockSpec
  Loop 2      k_c panels / pack B_c          grid dim 2 over K/bk —
                                             "arbitrary" (sequential) with a
                                             VMEM fp32 accumulator
  Loop 4/5    micro-kernel sweep from L1     the jnp.dot inside the kernel
                                             body, lowered onto the MXU
  micro-k     m_r x n_r register tile        128x128 systolic MXU tile
  packing     explicit A_c/B_c copies        implicit: BlockSpec index_map +
                                             double-buffered HBM→VMEM DMA
  ==========  =============================  =================================

Two micro-kernel variants share this scaffolding (the paper's §5.3 point
that each core class may want its *own* micro-kernel, not just its own
blocking):

  * :func:`gemm_pallas` — the default pipelined kernel: a 3-D grid whose
    K dimension is sequential, with the Pallas pipeline double-buffering
    the A/B block staging (working set ``2·(A+B) + acc``).
  * :func:`gemm_pallas_lean` — the VMEM-lean k-streaming variant for
    little-VMEM classes: a 2-D grid over output tiles; K is streamed
    *inside* the kernel body with single-buffered manual DMA
    (``make_async_copy``) while one fp32 accumulator tile stays resident
    (working set ``(A+B) + acc``).  Trading the double-buffering depth for
    footprint lets a class like ``TPU_LITTLE`` run the full shared (bm, bn)
    panel instead of shrinking ``bm`` — at the cost of not overlapping the
    HBM streams with the MXU (the tuning cost model charges exactly that).

The per-class ``BlockConfig`` (control tree) chooses (bm, bk, bn) exactly
like the paper chooses (m_c, k_c) per core type.  On this CPU-only
container the kernels are validated with ``interpret=True``; on TPU the
same code JITs through Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific helpers are importable on CPU; guard for API drift.
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

from repro.core.blocking import BlockConfig, _round_up, pad_to_blocks

# Block dims may not exceed the problem rounded up to this lane tile: a
# bigger block silently multiplies padded FLOPs (a cache entry from the
# wrong bucket, a hand-typed config) instead of helping.  ``LANE`` is the
# public name — ``repro.analysis.configcheck`` enforces the same
# padded-problem bound on committed tuning-cache entries with it.
LANE = _LANE = 128


def resolve_block_config(
    m: int, k: int, n: int, dtype, *, double_buffer: bool = True
) -> BlockConfig:
    """Config used when the caller passes ``cfg=None``.

    Delegates to the single resolution path in
    :func:`repro.core.execution.resolve_block_config`: with
    ``$REPRO_TUNING_CACHE`` set, the tuned entry for this
    (spec, dtype, shape bucket) wins; otherwise — and always when the env
    var is unset — the analytical derivation is used, so defaults are
    unchanged.  The kernel itself is identical either way; only the block
    shapes differ.  ``double_buffer=False`` is the lean kernel's VMEM
    model (single-buffered staging admits larger panels).
    """

    from repro.core.execution import resolve_block_config as _resolve

    cfg, _ = _resolve(
        m, k, n,
        dtype_name=dtype.name,
        dtype_bytes=dtype.itemsize,
        double_buffer=double_buffer,
    )
    return cfg


# ---------------------------------------------------------------------------
# Shared pallas_call scaffolding (validation, padding, compiler params)
# ---------------------------------------------------------------------------


def validate_block_config(m: int, k: int, n: int, cfg: BlockConfig) -> None:
    """Reject blocks that exceed the lane-padded problem, loudly.

    ``pad_to_blocks`` rounds every dim up to its block, so an oversized
    block used to be *silently accepted* — e.g. ``bk=256`` against
    ``K=100`` padded K all the way to 256 and more than doubled the padded
    FLOPs of every grid step.  Any dim only ever needs padding up to the
    128-lane MXU tile; a block beyond that is a misconfiguration (a cache
    entry from another shape bucket, a hand-typed config) and now raises a
    :class:`ValueError` naming the offending dimension.
    """

    for name, dim, blk in (("bm", m, cfg.bm), ("bk", k, cfg.bk), ("bn", n, cfg.bn)):
        padded = _round_up(dim, _LANE)
        if blk > padded:
            axis = {"bm": "M", "bk": "K", "bn": "N"}[name]
            raise ValueError(
                f"block config {name}={blk} exceeds padded {axis}={padded} "
                f"(problem {m}x{k}x{n}, lane tile {_LANE}); blocks larger "
                f"than the padded problem only multiply padding waste"
            )


def _check_operands(a: jnp.ndarray, b: jnp.ndarray) -> None:
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"gemm kernels are 2-D: got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")


def _pad_operands(
    a: jnp.ndarray, b: jnp.ndarray, cfg: BlockConfig
) -> tuple[jnp.ndarray, jnp.ndarray, int, int, int]:
    """Pad (M, K, N) up to block multiples (the paper's partial-panel edge
    handling); returns the padded operands and dims."""

    m, k = a.shape
    _, n = b.shape
    pm, pk, pn = pad_to_blocks(m, k, n, cfg)
    if (pm, pk) != (m, k):
        a = jnp.pad(a, ((0, pm - m), (0, pk - k)))
    if (pk, pn) != (k, n):
        b = jnp.pad(b, ((0, pk - k), (0, pn - n)))
    return a, b, pm, pk, pn


def _compiler_params(semantics: tuple[str, ...], interpret: bool) -> dict:
    """``dimension_semantics`` for Mosaic; nothing in interpret mode."""

    if pltpu is None or interpret:
        return {}
    try:
        return {
            "compiler_params": pltpu.CompilerParams(dimension_semantics=semantics)
        }
    except Exception:  # pragma: no cover - older API name
        return {}


# ---------------------------------------------------------------------------
# Default pipelined kernel (double-buffered BlockSpec staging)
# ---------------------------------------------------------------------------


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref):
    """Grid point (i, j, k): C[i,j] += A[i,k] @ B[k,j] with fp32 VMEM acc."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    cfg: Optional[BlockConfig] = None,
    *,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """``C = A @ B`` via the blocked (pipelined) Pallas kernel.

    Launches the (M/bm, N/bn, K/bk) grid; the Pallas pipeline stages A/B
    blocks HBM→VMEM double-buffered.  ``interpret=True`` executes the
    kernel body in Python on CPU — the validation mode the test suite and
    the parity harness use.
    """

    _check_operands(a, b)
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    if cfg is None:
        cfg = resolve_block_config(m, k, n, a.dtype)
    validate_block_config(m, k, n, cfg)

    a, b, pm, pk, pn = _pad_operands(a, b, cfg)
    grid = (pm // cfg.bm, pn // cfg.bn, pk // cfg.bk)

    scratch = (
        [_VMEM((cfg.bm, cfg.bn), jnp.float32)]
        if _VMEM is not None
        else [pl.MemorySpace.ANY((cfg.bm, cfg.bn), jnp.float32)]  # pragma: no cover
    )

    out = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.bm, cfg.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cfg.bk, cfg.bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((cfg.bm, cfg.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **_compiler_params(("parallel", "parallel", "arbitrary"), interpret),
    )(a, b)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# VMEM-lean k-streaming kernel (single-buffered manual DMA)
# ---------------------------------------------------------------------------


def _gemm_lean_kernel(bm: int, bk: int, bn: int, n_k: int):
    """Kernel factory: output tile (i, j) streams K in bk slices.

    The operands stay in HBM (``memory_space=ANY``); each K step DMAs one
    (bm, bk) A slice and one (bk, bn) B slice into a *single* VMEM buffer
    pair and accumulates into the resident fp32 tile.  No second buffer →
    no DMA/compute overlap, but half the input staging footprint — the
    deliberate trade of :class:`BlockConfig` ``vmem_bytes(False)``.
    """

    def kernel(a_hbm, b_hbm, o_ref, a_vmem, b_vmem, acc_ref, sem_a, sem_b):
        i = pl.program_id(0)
        j = pl.program_id(1)
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def body(kk, carry):
            cp_a = pltpu.make_async_copy(
                a_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)], a_vmem, sem_a
            )
            cp_b = pltpu.make_async_copy(
                b_hbm.at[pl.ds(kk * bk, bk), pl.ds(j * bn, bn)], b_vmem, sem_b
            )
            cp_a.start()
            cp_b.start()
            cp_a.wait()
            cp_b.wait()
            acc_ref[...] += jnp.dot(
                a_vmem[...], b_vmem[...], preferred_element_type=jnp.float32
            )
            return carry

        jax.lax.fori_loop(0, n_k, body, 0)
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    return kernel


def gemm_pallas_lean(
    a: jnp.ndarray,
    b: jnp.ndarray,
    cfg: Optional[BlockConfig] = None,
    *,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """``C = A @ B`` via the VMEM-lean k-streaming Pallas kernel.

    The ``TPU_LITTLE``-class variant: a (M/bm, N/bn) grid whose kernel
    body streams K with single-buffered manual DMA while the fp32
    accumulator tile stays resident (see :func:`_gemm_lean_kernel`).  With
    ``cfg=None`` the block shapes resolve under the *single-buffer* VMEM
    model, so the same budget admits larger (bm, bn) panels than the
    pipelined default.
    """

    if pltpu is None:  # pragma: no cover - non-TPU pallas builds
        raise RuntimeError("gemm_pallas_lean needs jax.experimental.pallas.tpu")
    _check_operands(a, b)
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    if cfg is None:
        cfg = resolve_block_config(m, k, n, a.dtype, double_buffer=False)
    validate_block_config(m, k, n, cfg)

    a, b, pm, pk, pn = _pad_operands(a, b, cfg)
    grid = (pm // cfg.bm, pn // cfg.bn)

    out = pl.pallas_call(
        _gemm_lean_kernel(cfg.bm, cfg.bk, cfg.bn, pk // cfg.bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((cfg.bm, cfg.bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((cfg.bm, cfg.bk), a.dtype),
            pltpu.VMEM((cfg.bk, cfg.bn), b.dtype),
            pltpu.VMEM((cfg.bm, cfg.bn), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
        **_compiler_params(("parallel", "parallel"), interpret),
    )(a, b)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("cfg", "out_dtype", "interpret"))
def gemm_pallas_jit(a, b, cfg=None, out_dtype=None, interpret=False):
    return gemm_pallas(a, b, cfg, out_dtype=out_dtype, interpret=interpret)


# The micro-kernel variant registry: variant name -> kernel entry point.
# This is the single source the tuner's search dimension
# (candidates.KERNEL_BACKENDS), the wallclock timer, and the benchmarks
# all derive from — registering a new hardware variant here propagates to
# all three (its execution.BACKENDS/INTERPRET_TWIN dispatch entries are
# guarded separately by the parity harness).
GEMM_KERNELS = {
    "pallas": gemm_pallas,
    "pallas_lean": gemm_pallas_lean,
}


__all__ = [
    "GEMM_KERNELS",
    "LANE",
    "gemm_pallas",
    "gemm_pallas_lean",
    "gemm_pallas_jit",
    "resolve_block_config",
    "validate_block_config",
]

"""Donation dataflow lint: use-after-donate and host-copy donation pins.

Two hazards this repo has actually shipped (CHANGES.md, PR 5):

* **RPR001 — use-after-donate.**  A buffer passed in a donated argnum
  position of a ``jax.jit``-wrapped callable is invalidated by the call;
  reading the same Python name afterwards (before rebinding it) touches a
  deleted buffer at runtime.  The safe idiom rebinds in the same
  statement: ``state = step(x, state)``.

* **RPR002 — donation pin.**  ``np.asarray``/``np.array`` of a device
  value pins a cached *host* copy; passing the result (directly or via a
  local name) into a donated position silently disables donation — the
  step still runs, just with a full extra copy of the state every call.
  This is the PR-5 twin-trainer bug, now machine-checked.

The analysis is intraprocedural but *module-aware* for bindings: a
``self._step = jax.jit(fn, donate_argnums=(2,))`` in ``__init__`` is
recognized at call sites in other methods (dotted names are matched
textually — ``self._step`` is the same binding wherever it appears).
``donate_argnums`` is resolved from integer literals, literal tuples, and
simple conditional assignments (``donate = (2,) if flag else ()`` donates
position 2 on the hazardous branch); positions that cannot be resolved
statically are skipped rather than guessed.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, Optional

from repro.analysis.diagnostics import Diagnostic

_NP_FUNCS = frozenset({"asarray", "array"})
_JIT_ATTRS = frozenset({"jit", "pjit"})


def dotted_name(node: ast.AST) -> Optional[str]:
    """``self._step`` / ``step`` as a dotted string; None for non-chains."""

    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _int_constants(node: ast.AST) -> set[int]:
    return {
        n.value
        for n in ast.walk(node)
        if isinstance(n, ast.Constant) and isinstance(n.value, int)
        and not isinstance(n.value, bool)
    }


class _ModuleIndex(ast.NodeVisitor):
    """Module-wide facts: import aliases and donated jit bindings."""

    def __init__(self) -> None:
        self.numpy_aliases: set[str] = set()
        self.jax_aliases: set[str] = set()
        self.np_func_names: set[str] = set()   # `from numpy import asarray`
        self.jit_names: set[str] = set()       # `from jax import jit`
        # dotted binding name -> donated positional indices
        self.donated: dict[str, frozenset[int]] = {}
        # name -> last simple assignment value (for donate_argnums=NAME)
        self._assigns: dict[str, ast.AST] = {}

    # -- imports ------------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            top = a.name.split(".")[0]
            alias = a.asname or top
            if top == "numpy":
                self.numpy_aliases.add(alias)
            if top == "jax":
                self.jax_aliases.add(alias)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        for a in node.names:
            name = a.asname or a.name
            if mod.split(".")[0] == "numpy" and a.name in _NP_FUNCS:
                self.np_func_names.add(name)
            if mod.split(".")[0] == "jax" and a.name in _JIT_ATTRS:
                self.jit_names.add(name)

    # -- donated bindings ---------------------------------------------------

    def is_jit_call(self, call: ast.Call) -> bool:
        f = call.func
        if isinstance(f, ast.Name):
            return f.id in self.jit_names
        if isinstance(f, ast.Attribute) and f.attr in _JIT_ATTRS:
            base = dotted_name(f.value)
            return base is not None and base.split(".")[0] in self.jax_aliases
        return False

    def is_np_copy_call(self, call: ast.Call) -> bool:
        f = call.func
        if isinstance(f, ast.Name):
            return f.id in self.np_func_names
        if isinstance(f, ast.Attribute) and f.attr in _NP_FUNCS:
            base = dotted_name(f.value)
            return base is not None and base in self.numpy_aliases
        return False

    def donate_positions(self, call: ast.Call) -> frozenset[int]:
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                value = kw.value
                if isinstance(value, ast.Name) and value.id in self._assigns:
                    value = self._assigns[value.id]
                return frozenset(_int_constants(value))
        return frozenset()

    def visit_Assign(self, node: ast.Assign) -> None:
        value = node.value
        for target in node.targets:
            name = dotted_name(target)
            if name is not None and isinstance(target, ast.Name):
                self._assigns[name] = value
            if (
                name is not None
                and isinstance(value, ast.Call)
                and self.is_jit_call(value)
            ):
                pos = self.donate_positions(value)
                if pos:
                    self.donated[name] = pos
        self.generic_visit(node)


def _statements(body: list[ast.stmt]) -> Iterator[ast.stmt]:
    """Simple statements of a scope in textual order (compound statements
    flattened; nested function/class scopes are opaque)."""

    for stmt in body:
        if isinstance(
            stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        ):
            continue
        if isinstance(stmt, (ast.If, ast.For, ast.AsyncFor, ast.While)):
            yield stmt  # the header (test/iter) is part of this unit
            yield from _statements(stmt.body)
            yield from _statements(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            yield stmt
            yield from _statements(stmt.body)
        elif isinstance(stmt, ast.Try):
            yield from _statements(stmt.body)
            for h in stmt.handlers:
                yield from _statements(h.body)
            yield from _statements(stmt.orelse)
            yield from _statements(stmt.finalbody)
        else:
            yield stmt


def _shallow_walk(stmt: ast.stmt) -> Iterator[ast.AST]:
    """Walk a statement without descending into nested scopes or into the
    bodies of compound statements (those are separate units)."""

    if isinstance(stmt, (ast.If, ast.While)):
        roots: list[ast.AST] = [stmt.test]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        roots = [stmt.target, stmt.iter]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        roots = list(stmt.items)
    else:
        roots = [stmt]
    for root in roots:
        for node in ast.walk(root):
            if isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef,
                       ast.Lambda)
            ):
                continue
            yield node


@dataclasses.dataclass
class _Donation:
    name: str          # dotted name of the donated buffer
    unit: int          # statement-unit index of the donating call
    line: int


def _stores_and_loads(stmt: ast.stmt) -> tuple[set[str], list[tuple[str, int]]]:
    stores: set[str] = set()
    loads: list[tuple[str, int]] = []
    for node in _shallow_walk(stmt):
        if isinstance(node, (ast.Name, ast.Attribute)):
            name = dotted_name(node)
            if name is None:
                continue
            ctx = getattr(node, "ctx", None)
            if isinstance(ctx, (ast.Store, ast.Del)):
                stores.add(name)
            elif isinstance(ctx, ast.Load) and isinstance(
                node, ast.Name
            ):
                loads.append((name, node.lineno))
            elif isinstance(ctx, ast.Load) and isinstance(node, ast.Attribute):
                loads.append((name, node.lineno))
    return stores, loads


def check_scope(
    path: str,
    scope_body: list[ast.stmt],
    index: _ModuleIndex,
) -> list[Diagnostic]:
    """Run the donation checks over one function (or module) body."""

    diags: list[Diagnostic] = []
    units = list(_statements(scope_body))
    # name -> line of the np.asarray/np.array assignment it came from
    host_copies: dict[str, int] = {}
    donations: list[_Donation] = []

    for i, stmt in enumerate(units):
        for node in _shallow_walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            positions = _donated_positions_of_call(node, index)
            if not positions:
                continue
            for p in sorted(positions):
                if p >= len(node.args):
                    continue
                arg = node.args[p]
                if isinstance(arg, ast.Call) and index.is_np_copy_call(arg):
                    diags.append(
                        Diagnostic(
                            code="RPR002",
                            path=path,
                            line=arg.lineno,
                            col=arg.col_offset,
                            message=(
                                "np host copy passed in donated argnum "
                                f"{p}: the cached host buffer pins the "
                                "value and silently disables donation"
                            ),
                        )
                    )
                    continue
                name = dotted_name(arg)
                if name is None:
                    continue
                if name in host_copies:
                    diags.append(
                        Diagnostic(
                            code="RPR002",
                            path=path,
                            line=host_copies[name],
                            message=(
                                f"`{name}` is an np.asarray/np.array host "
                                f"copy (line {host_copies[name]}) passed in "
                                f"donated argnum {p} at line {node.lineno}: "
                                "donation is silently disabled"
                            ),
                        )
                    )
                donations.append(_Donation(name=name, unit=i, line=node.lineno))

        # Stores apply after the unit's RHS evaluated (so `x = step(x)`
        # with a host-copy `x` is still caught above), then new host-copy
        # origins are recorded.
        stores, _ = _stores_and_loads(stmt)
        for s in stores:
            host_copies.pop(s, None)
        if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call):
            if index.is_np_copy_call(stmt.value):
                for target in stmt.targets:
                    name = dotted_name(target)
                    if name is not None:
                        host_copies[name] = stmt.lineno

    # use-after-donate: a Load of the donated name in a later unit, before
    # the first unit that rebinds it.  A store in the donating unit itself
    # (`state = step(x, state)` — the canonical safe idiom) rebinds
    # immediately: the RHS is fully evaluated before the assignment.
    for don in donations:
        same_unit_stores, _ = _stores_and_loads(units[don.unit])
        if don.name in same_unit_stores:
            continue
        for j in range(don.unit + 1, len(units)):
            stores, loads = _stores_and_loads(units[j])
            read = next((ln for (n, ln) in loads if n == don.name), None)
            if read is not None:
                diags.append(
                    Diagnostic(
                        code="RPR001",
                        path=path,
                        line=read,
                        message=(
                            f"`{don.name}` was donated at line {don.line} "
                            "and is read here before being rebound: the "
                            "buffer is invalidated by the donating call"
                        ),
                    )
                )
                break
            if don.name in stores:
                break
    return diags


def _donated_positions_of_call(
    call: ast.Call, index: _ModuleIndex
) -> frozenset[int]:
    """Donated positions if this call invokes a donated binding (or an
    inline ``jax.jit(..., donate_argnums=...)(args)``)."""

    func = call.func
    name = dotted_name(func)
    if name is not None and name in index.donated:
        return index.donated[name]
    if isinstance(func, ast.Call) and index.is_jit_call(func):
        return index.donate_positions(func)
    return frozenset()


def check_module(path: str, tree: ast.Module) -> list[Diagnostic]:
    """Donation checks over every scope of a parsed module."""

    index = _ModuleIndex()
    index.visit(tree)
    diags = check_scope(path, tree.body, index)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            diags.extend(check_scope(path, node.body, index))
    return diags


__all__ = ["check_module", "dotted_name"]

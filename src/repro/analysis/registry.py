"""Registry contract checks: import the package, verify the tables.

These checks import :mod:`repro.core.execution` and
:mod:`repro.kernels.gemm` and inspect the dispatch tables *without
executing any kernel* — pure dictionary closure properties:

* **RPR101** — the violations :func:`repro.core.execution.validate_registry`
  reports: ``BACKENDS``/``BACKEND_OPS`` agreement, a registered
  ``INTERPRET_TWIN`` (the parity-harness route) for every entry,
  ``LEAN_VARIANTS`` buffering-model sanity, and ``GEMM_KERNELS`` naming
  only compiled GEMM dispatch entries.

* **RPR102** — op families closed under
  :func:`~repro.core.execution.align_backend_family`: remapping any
  family member onto any other member's execution family (compiled or
  interpret) must land inside the same family and inside the table.
  This is the invariant that lets a tuning cache recorded on hardware be
  replayed under interpret mode (and vice versa) without a name ever
  escaping the registry.
"""

from __future__ import annotations

from repro.analysis.diagnostics import Diagnostic

# Where registry findings anchor: the tables live here.
_EXECUTION = "src/repro/core/execution.py"


def check_registry() -> list[Diagnostic]:
    from repro.core import execution as X

    diags = [
        Diagnostic(code="RPR101", path=_EXECUTION, line=1, message=p)
        for p in X.validate_registry()
    ]

    # Family closure under align_backend_family.  Skip if the base tables
    # are already broken (RPR101 reported above) — closure errors would
    # only repeat the same root cause.
    if diags:
        return diags
    families: dict[str, list[str]] = {}
    for name, op in X.BACKEND_OPS.items():
        families.setdefault(op, []).append(name)
    for op, members in families.items():
        for variant in members:
            for requested in members:
                try:
                    mapped = X.align_backend_family(variant, requested)
                except Exception as e:  # a raise is itself a closure break
                    diags.append(
                        Diagnostic(
                            code="RPR102",
                            path=_EXECUTION,
                            line=1,
                            message=(
                                f"align_backend_family({variant!r}, "
                                f"{requested!r}) raised {type(e).__name__}: {e}"
                            ),
                        )
                    )
                    continue
                if mapped not in X.BACKENDS or X.BACKEND_OPS[mapped] != op:
                    diags.append(
                        Diagnostic(
                            code="RPR102",
                            path=_EXECUTION,
                            line=1,
                            message=(
                                f"{op} family not closed: "
                                f"align_backend_family({variant!r}, "
                                f"{requested!r}) = {mapped!r} escapes the "
                                "family"
                            ),
                        )
                    )
    return diags


__all__ = ["check_registry"]

"""Project-specific static verifier (``python -m repro.analysis``).

Machine-checks the invariants this repo used to re-litigate in PR review
(see CHANGES.md: the PR-5 ``np.asarray`` donation pin, the PR-4
oversized-block config, the PR-2 backend-string drift):

* donation discipline (RPR001/RPR002),
* retrace/recompile hazards (RPR003),
* ContextVar token discipline (RPR004),
* backend-vocabulary drift against the live registry (RPR005),
* dispatch-table closure (RPR101/RPR102),
* VMEM-budget / lane / shared-bk config contracts (RPR201),
* bench-artifact schema (RPR202).

The AST layer (``ast_checks``/``donation``) never imports jax; the
contract layer (``registry``/``configcheck``) imports the package but
executes no kernels.  See DESIGN.md §8 for the invariant catalogue and
the suppression policy (``# repro: noqa=RPR0xx -- reason``).
"""

from repro.analysis.cli import analyze_file, analyze_paths, main
from repro.analysis.diagnostics import CODES, Diagnostic

__all__ = ["CODES", "Diagnostic", "analyze_file", "analyze_paths", "main"]

"""``python -m repro.analysis`` — the project's static verifier CLI.

Usage::

    python -m repro.analysis src tests benchmarks
    python -m repro.analysis --format github src tests benchmarks
    python -m repro.analysis --format json --no-contracts tests/fixtures/analysis

Two layers run by default:

1. **AST passes** over every ``.py`` file under the given paths
   (donation hazards, loop-jit, ContextVar discipline, backend drift)
   plus the tuning-cache contract on every ``.json`` under the paths
   that parses as a cache file.
2. **Contract checks** (``--no-contracts`` skips them): the backend
   registry closure, the shipped control-tree family, and the
   ``BENCH_*.json`` schema under ``--artifacts`` (default
   ``artifacts/bench`` when it exists).

Exit status is the number of findings clamped to 1 — a clean tree exits
0, anything else fails CI.  Directories named ``fixtures`` are skipped
during recursive discovery (the test corpus is *supposed* to be dirty)
but analyzed when named explicitly on the command line.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

from repro.analysis import ast_checks, configcheck, registry
from repro.analysis.diagnostics import (
    CODES,
    Diagnostic,
    apply_suppressions,
    render,
)

_SKIP_DIRS = frozenset(
    {"fixtures", "__pycache__", ".git", ".venv", "node_modules"}
)


def build_vocabulary() -> frozenset[str]:
    """The backend-token vocabulary, keyed off the live registries."""

    from repro.core.execution import backend_vocabulary
    from repro.tuning.measure import MEASURE_BACKEND_NAMES

    return frozenset(backend_vocabulary()) | frozenset(MEASURE_BACKEND_NAMES)


def build_objectives() -> frozenset[str]:
    """The scheduling-objective vocabulary, keyed off the live tuple.

    Sourced from ``repro.core.schedule.OBJECTIVES`` so the drift check
    can never disagree with what ``validate_objective`` accepts.
    """

    from repro.core.schedule import OBJECTIVES

    return frozenset(OBJECTIVES)


def build_fault_points() -> frozenset[str]:
    """The fault-injection point vocabulary, keyed off the live registry.

    Sourced from ``repro.runtime.faults.FAULT_POINTS`` so the drift check
    can never disagree with what ``validate_point`` accepts.
    """

    from repro.runtime.faults import FAULT_POINTS

    return frozenset(FAULT_POINTS)


def discover(paths: list[str]) -> tuple[list[str], list[str]]:
    """(.py files, .json files) under the given paths, fixtures pruned."""

    py: list[str] = []
    js: list[str] = []
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                py.append(path)
            elif path.endswith(".json"):
                js.append(path)
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for fname in sorted(files):
                full = os.path.join(root, fname)
                if fname.endswith(".py"):
                    py.append(full)
                elif fname.endswith(".json"):
                    js.append(full)
    return py, js


def analyze_file(
    path: str,
    vocabulary: Optional[frozenset[str]] = None,
    objectives: Optional[frozenset[str]] = None,
    fault_points: Optional[frozenset[str]] = None,
) -> list[Diagnostic]:
    """All applicable AST passes + suppressions for one Python file."""

    if vocabulary is None:
        vocabulary = build_vocabulary()
    if objectives is None:
        objectives = build_objectives()
    if fault_points is None:
        fault_points = build_fault_points()
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        diags = ast_checks.run_ast_checks(
            path, source, vocabulary, objectives, fault_points
        )
    except SyntaxError as e:
        # Not our diagnostic to own: surface as a hard error.
        raise SystemExit(f"{path}: cannot parse: {e}") from e
    return apply_suppressions(path, source, diags)


def analyze_paths(
    paths: list[str],
    *,
    contracts: bool = True,
    artifacts: Optional[str] = None,
    vocabulary: Optional[frozenset[str]] = None,
    objectives: Optional[frozenset[str]] = None,
    fault_points: Optional[frozenset[str]] = None,
) -> list[Diagnostic]:
    """The full analyzer: AST passes over ``paths`` + contract checks."""

    if vocabulary is None:
        vocabulary = build_vocabulary()
    if objectives is None:
        objectives = build_objectives()
    if fault_points is None:
        fault_points = build_fault_points()
    diags: list[Diagnostic] = []
    py_files, json_files = discover(paths)
    for path in py_files:
        diags.extend(analyze_file(path, vocabulary, objectives, fault_points))
    for path in json_files:
        diags.extend(configcheck.check_tuning_cache_file(path))
    if contracts:
        diags.extend(registry.check_registry())
        diags.extend(configcheck.check_shipped_trees())
        if artifacts is None and os.path.isdir(
            os.path.join("artifacts", "bench")
        ):
            artifacts = os.path.join("artifacts", "bench")
        if artifacts:
            diags.extend(configcheck.check_artifacts_dir(artifacts))
    return diags


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verifier for the repo's donation, "
                    "backend-registry, VMEM-budget, and context-discipline "
                    "invariants.",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src", "tests", "benchmarks"],
        help="files/directories to lint (default: src tests benchmarks)",
    )
    ap.add_argument(
        "--format", choices=("text", "github", "json"), default="text",
        help="diagnostic output format (github = PR annotations)",
    )
    ap.add_argument(
        "--no-contracts", action="store_true",
        help="skip the registry/tree/artifact contract checks (AST only)",
    )
    ap.add_argument(
        "--artifacts", default=None, metavar="DIR",
        help="bench-artifact dir for the BENCH_*.json schema check "
             "(default: artifacts/bench when present)",
    )
    ap.add_argument(
        "--list-codes", action="store_true",
        help="print the diagnostic catalogue and exit",
    )
    args = ap.parse_args(argv)

    if args.list_codes:
        print(json.dumps(CODES, indent=1, sort_keys=True))
        return 0

    missing = [p for p in args.paths if not os.path.exists(p)]
    if missing:
        print(f"no such path(s): {missing}", file=sys.stderr)
        return 2

    diags = analyze_paths(
        args.paths,
        contracts=not args.no_contracts,
        artifacts=args.artifacts,
    )
    out = render(diags, args.format)
    if out:
        print(out)
    if args.format != "json":
        print(
            f"repro.analysis: {len(diags)} finding(s)"
            if diags else "repro.analysis: clean",
            file=sys.stderr,
        )
    return 1 if diags else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""AST lint passes: recompile hazards, context discipline, backend drift.

* **RPR003 — jit/pallas_call in a loop body.**  ``jax.jit(...)`` and
  ``pl.pallas_call(...)`` construct a *new* callable whose traces are
  keyed on the wrapper object: building one per loop iteration defeats
  the trace cache and recompiles every pass.  Flagged when the call sits
  syntactically inside a ``for``/``while`` of the same function scope
  (a nested ``def`` resets the scope — defining a helper that jits is
  fine; the helper is not run per iteration by the loop itself).

* **RPR004 — raw ``ContextVar.set``.**  The repo's context discipline
  (``core/execution.py`` / ``observability/trace.py``) keeps every
  ``ContextVar.set`` paired with a token reset on exit — either in a
  ``finally`` or in the ``__exit__`` of the same context-manager class.
  A bare ``set`` anywhere else leaks ambient state across the caller's
  control flow.  The two blessed modules are exempt wholesale (they *are*
  the helpers); elsewhere the pairing is checked structurally.

* **RPR005 — backend-name drift.**  Before PR 2 this repo had three
  backend-string vocabularies that drifted apart.  Now there is one
  registry (``execution.BACKENDS``); this pass flags any backend-shaped
  string literal (a ``backend=``/``kernel_backend=`` keyword, a
  comparison or ``in`` test against a ``*backend``-named expression, a
  subscript of a registry table) whose value is outside the vocabulary
  the caller passes in — which the CLI builds from the *live* registries,
  so the lint can never itself drift from the code.  The same pass guards
  the *objective* vocabulary (``schedule.OBJECTIVES`` — perf/energy/edp):
  an ``objective=`` keyword or a comparison against an
  ``objective``-named expression with a literal outside the live tuple is
  the identical bug class (a misspelled ``"engery"`` silently selecting
  the default objective).

* **RPR006 — fault-point drift.**  The fleet's fault-injection registry
  (``runtime.faults.FAULT_POINTS``) is the vocabulary every injection
  site and every :class:`FaultPlan` speaks; a misspelled point name
  (``"pod_deth"``) would silently never fire — the worst failure mode a
  *fault-injection* test can have, since the run then passes by testing
  nothing.  Flagged: positional string arguments of the funnels
  (``fault_active`` / ``validate_point``), a ``point=`` keyword
  (``FaultEvent`` construction), and string subscripts of
  ``FAULT_POINTS`` — whenever the literal is outside the vocabulary the
  CLI builds from the *live* registry.
"""

from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.donation import dotted_name

# Modules allowed to touch ContextVars rawly: they implement the token
# discipline everything else must inherit via their context managers.
BLESSED_CONTEXTVAR_MODULES = (
    "core/execution.py",
    "observability/trace.py",
)

# Dotted suffixes that mark an expression as backend-valued.
_BACKEND_NAME_HINTS = ("backend", "kernel_backend", "exec_backend")

# Registry-table names whose string subscripts must be vocabulary members.
_REGISTRY_TABLES = frozenset(
    {"BACKENDS", "BACKEND_OPS", "INTERPRET_TWIN", "LEAN_VARIANTS",
     "GEMM_KERNELS"}
)

# Registry funnels whose positional string arguments are backend names.
_BACKEND_FUNCS = frozenset(
    {"resolve_backend", "resolve_paged_attn_backend", "interpret_twin",
     "backend_op", "backend_double_buffers", "align_backend_family"}
)


def _is_backend_named(node: ast.AST) -> bool:
    name = dotted_name(node)
    if name is None:
        return False
    last = name.split(".")[-1]
    return last == "backend" or any(
        last == h or last.endswith("_" + h) for h in _BACKEND_NAME_HINTS
    )


def _is_objective_named(node: ast.AST) -> bool:
    name = dotted_name(node)
    if name is None:
        return False
    last = name.split(".")[-1]
    return last == "objective" or last.endswith("_objective")


# ---------------------------------------------------------------------------
# RPR003: jit / pallas_call constructed inside loop bodies
# ---------------------------------------------------------------------------


class _LoopJitVisitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.depth = 0
        self.diags: list[Diagnostic] = []

    def _visit_scope(self, node) -> None:
        saved, self.depth = self.depth, 0
        self.generic_visit(node)
        self.depth = saved

    visit_FunctionDef = _visit_scope
    visit_AsyncFunctionDef = _visit_scope
    visit_Lambda = _visit_scope

    def _visit_loop(self, node) -> None:
        self.depth += 1
        self.generic_visit(node)
        self.depth -= 1

    visit_For = _visit_loop
    visit_AsyncFor = _visit_loop
    visit_While = _visit_loop

    def visit_Call(self, node: ast.Call) -> None:
        if self.depth > 0:
            name = dotted_name(node.func)
            last = name.split(".")[-1] if name else ""
            if last in ("jit", "pjit") and name.split(".")[0] == "jax":
                self._flag(node, "jax.jit")
            elif last == "pallas_call":
                self._flag(node, "pallas_call")
        self.generic_visit(node)

    def _flag(self, node: ast.Call, what: str) -> None:
        self.diags.append(
            Diagnostic(
                code="RPR003",
                path=self.path,
                line=node.lineno,
                col=node.col_offset,
                message=(
                    f"{what} constructed inside a loop body: each "
                    "iteration builds a fresh callable and retraces/"
                    "recompiles — hoist the construction out of the loop"
                ),
            )
        )


# ---------------------------------------------------------------------------
# RPR004: raw ContextVar.set outside the blessed helpers
# ---------------------------------------------------------------------------


def _contextvar_names(tree: ast.Module) -> set[str]:
    """Module-level names bound to ``contextvars.ContextVar(...)``."""

    out: set[str] = set()
    for stmt in tree.body:
        targets: list[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        else:
            continue
        if not isinstance(value, ast.Call):
            continue
        fname = dotted_name(value.func)
        if fname and fname.split(".")[-1] == "ContextVar":
            for t in targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _has_reset_in_finally(fn: ast.AST, var: str) -> bool:
    """Does this function reset ``var`` in a ``finally`` block?"""

    for node in ast.walk(fn):
        if isinstance(node, ast.Try):
            for stmt in node.finalbody:
                for call in ast.walk(stmt):
                    if isinstance(call, ast.Call):
                        fname = dotted_name(call.func)
                        if fname == f"{var}.reset":
                            return True
    return False


def _class_resets_in_exit(cls: ast.ClassDef, var: str) -> bool:
    """Does the enclosing class pair the set with a reset in __exit__?"""

    for item in cls.body:
        if (
            isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
            and item.name == "__exit__"
        ):
            for call in ast.walk(item):
                if isinstance(call, ast.Call):
                    fname = dotted_name(call.func)
                    if fname is not None and fname.startswith(var + "."):
                        if fname.split(".")[-1] in ("reset", "set"):
                            return True
    return False


def check_contextvar_sets(path: str, tree: ast.Module) -> list[Diagnostic]:
    norm = path.replace("\\", "/")
    if any(norm.endswith(b) for b in BLESSED_CONTEXTVAR_MODULES):
        return []
    cvars = _contextvar_names(tree)
    if not cvars:
        return []
    diags: list[Diagnostic] = []

    def scan(body: Iterable[ast.stmt], enclosing_class: Optional[ast.ClassDef],
             enclosing_fn: Optional[ast.AST]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.ClassDef):
                scan(stmt.body, stmt, None)
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scan(stmt.body, enclosing_class, stmt)
                continue
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                fname = dotted_name(node.func)
                if fname is None or not fname.endswith(".set"):
                    continue
                var = fname[: -len(".set")]
                if var not in cvars:
                    continue
                ok = False
                if enclosing_fn is not None and _has_reset_in_finally(
                    enclosing_fn, var
                ):
                    ok = True
                if (
                    not ok
                    and enclosing_class is not None
                    and enclosing_fn is not None
                    and getattr(enclosing_fn, "name", "") in (
                        "__enter__", "__exit__"
                    )
                    and _class_resets_in_exit(enclosing_class, var)
                ):
                    ok = True
                if not ok:
                    diags.append(
                        Diagnostic(
                            code="RPR004",
                            path=path,
                            line=node.lineno,
                            col=node.col_offset,
                            message=(
                                f"raw ContextVar set on `{var}` without a "
                                "token reset in a finally/__exit__: use the "
                                "blessed context managers (ExecutionContext"
                                "/trace.span) or pair set with reset"
                            ),
                        )
                    )

    scan(tree.body, None, None)
    return diags


# ---------------------------------------------------------------------------
# RPR005: backend-string drift against the live registry vocabulary
# ---------------------------------------------------------------------------


def _str_literals(node: ast.AST) -> list[ast.Constant]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [
            e for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)
        ]
    return []


class _BackendDriftVisitor(ast.NodeVisitor):
    def __init__(
        self,
        path: str,
        vocabulary: frozenset[str],
        objectives: Optional[frozenset[str]] = None,
    ):
        self.path = path
        self.vocab = vocabulary
        self.objectives = objectives
        self.diags: list[Diagnostic] = []

    def _check(self, lit: ast.Constant, where: str) -> None:
        if lit.value not in self.vocab:
            self.diags.append(
                Diagnostic(
                    code="RPR005",
                    path=self.path,
                    line=lit.lineno,
                    col=lit.col_offset,
                    message=(
                        f"backend name {lit.value!r} ({where}) is not in "
                        "the registry vocabulary — add it to "
                        "execution.BACKENDS or fix the drift"
                    ),
                )
            )

    def _check_objective(self, lit: ast.Constant, where: str) -> None:
        if self.objectives is not None and lit.value not in self.objectives:
            self.diags.append(
                Diagnostic(
                    code="RPR005",
                    path=self.path,
                    line=lit.lineno,
                    col=lit.col_offset,
                    message=(
                        f"objective name {lit.value!r} ({where}) is not in "
                        "the scheduling-objective vocabulary "
                        "(schedule.OBJECTIVES) — fix the drift"
                    ),
                )
            )

    def visit_Call(self, node: ast.Call) -> None:
        callee = dotted_name(node.func)
        last = callee.split(".")[-1] if callee else ""
        if last != "add_argument":  # argparse flags define their own enums
            for kw in node.keywords:
                if kw.arg in ("backend", "kernel_backend"):
                    for lit in _str_literals(kw.value):
                        self._check(lit, f"keyword {kw.arg}=")
                elif kw.arg == "objective":
                    for lit in _str_literals(kw.value):
                        self._check_objective(lit, "keyword objective=")
        if last in _BACKEND_FUNCS:
            for arg in node.args:
                for lit in _str_literals(arg):
                    self._check(lit, f"argument of {last}")
        if last == "validate_objective":
            for arg in node.args:
                for lit in _str_literals(arg):
                    self._check_objective(lit, f"argument of {last}")
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        sides = [node.left, *node.comparators]
        backendish = any(_is_backend_named(s) for s in sides)
        if backendish:
            for s in sides:
                for lit in _str_literals(s):
                    self._check(lit, "comparison with a backend value")
        elif any(_is_objective_named(s) for s in sides):
            for s in sides:
                for lit in _str_literals(s):
                    self._check_objective(lit, "comparison with an objective value")
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        base = dotted_name(node.value)
        if base and base.split(".")[-1] in _REGISTRY_TABLES:
            for lit in _str_literals(node.slice):
                self._check(lit, f"subscript of {base.split('.')[-1]}")
        self.generic_visit(node)


def check_backend_drift(
    path: str,
    tree: ast.Module,
    vocabulary: frozenset[str],
    objectives: Optional[frozenset[str]] = None,
) -> list[Diagnostic]:
    v = _BackendDriftVisitor(path, vocabulary, objectives)
    v.visit(tree)
    return v.diags


# ---------------------------------------------------------------------------
# RPR006: fault-point drift against the live FAULT_POINTS registry
# ---------------------------------------------------------------------------

# Funnels whose positional string arguments name an injection point.
_FAULT_FUNCS = frozenset({"fault_active", "validate_point"})


class _FaultPointDriftVisitor(ast.NodeVisitor):
    def __init__(self, path: str, fault_points: frozenset[str]):
        self.path = path
        self.points = fault_points
        self.diags: list[Diagnostic] = []

    def _check(self, lit: ast.Constant, where: str) -> None:
        if lit.value not in self.points:
            self.diags.append(
                Diagnostic(
                    code="RPR006",
                    path=self.path,
                    line=lit.lineno,
                    col=lit.col_offset,
                    message=(
                        f"fault point {lit.value!r} ({where}) is not in the "
                        "injection registry — a plan naming it never fires; "
                        "add it to runtime.faults.FAULT_POINTS or fix the "
                        "drift"
                    ),
                )
            )

    def visit_Call(self, node: ast.Call) -> None:
        callee = dotted_name(node.func)
        last = callee.split(".")[-1] if callee else ""
        if last in _FAULT_FUNCS:
            for arg in node.args:
                for lit in _str_literals(arg):
                    self._check(lit, f"argument of {last}")
        for kw in node.keywords:
            if kw.arg == "point":  # FaultEvent(point=...) and friends
                for lit in _str_literals(kw.value):
                    self._check(lit, "keyword point=")
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        base = dotted_name(node.value)
        if base and base.split(".")[-1] == "FAULT_POINTS":
            for lit in _str_literals(node.slice):
                self._check(lit, "subscript of FAULT_POINTS")
        self.generic_visit(node)


def check_fault_point_drift(
    path: str, tree: ast.Module, fault_points: frozenset[str]
) -> list[Diagnostic]:
    v = _FaultPointDriftVisitor(path, fault_points)
    v.visit(tree)
    return v.diags


def check_loop_jit(path: str, tree: ast.Module) -> list[Diagnostic]:
    v = _LoopJitVisitor(path)
    v.visit(tree)
    return v.diags


def run_ast_checks(
    path: str,
    source: str,
    vocabulary: frozenset[str],
    objectives: Optional[frozenset[str]] = None,
    fault_points: Optional[frozenset[str]] = None,
) -> list[Diagnostic]:
    """All AST passes (donation included) over one file's source."""

    from repro.analysis import donation

    tree = ast.parse(source, filename=path)
    diags = []
    diags.extend(donation.check_module(path, tree))
    diags.extend(check_loop_jit(path, tree))
    diags.extend(check_contextvar_sets(path, tree))
    diags.extend(check_backend_drift(path, tree, vocabulary, objectives))
    if fault_points is not None:
        diags.extend(check_fault_point_drift(path, tree, fault_points))
    return diags


__all__ = [
    "BLESSED_CONTEXTVAR_MODULES",
    "run_ast_checks",
    "check_loop_jit",
    "check_contextvar_sets",
    "check_backend_drift",
    "check_fault_point_drift",
]

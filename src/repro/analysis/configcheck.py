"""Config/artifact contracts: tuning caches, shipped trees, bench JSONs.

* **RPR201 — block-config contracts.**  Every committed tuning-cache
  entry and every control tree buildable from the shipped core specs must
  satisfy, *under the buffering model of the kernel that will consume it*
  (single-buffer for ``pallas_lean``-family variants, double otherwise):

    - the VMEM working set fits the named spec's budget,
    - all block dims are 128-lane aligned,
    - no block dim exceeds the lane-padded problem it was recorded for
      (the PR-4 bug class: an oversized ``bk`` silently multiplies padded
      FLOPs — ``kernels.gemm.validate_block_config`` now raises at call
      time; this check catches the bad entry at commit time),
    - cache keys bucket consistently with the recorded shape,
    - under the Loop-3 (rows) coarse loop, all classes of a tree family
      share one ``bk`` (the shared-B-panel constraint of §5.3).

* **RPR202 — bench artifact schema.**  ``artifacts/bench/BENCH_*.json``
  must be ``{"meta": {...}, "records": [...]}`` as written by
  ``benchmarks.harness.write_json`` — the CI baseline comparison and the
  perf-trajectory tooling both parse exactly that shape.

Nothing here executes a kernel: caches are parsed, trees are *built*
(pure Python derivation), artifacts are schema-checked.
"""

from __future__ import annotations

import json
import os
import re
from typing import Optional

from repro.analysis.diagnostics import Diagnostic

_KEY_RE = re.compile(r"^(?P<spec>[^/]+)/(?P<dtype>[^/]+)/(?P<m>\d+)x(?P<k>\d+)x(?P<n>\d+)$")

# Required provenance keys of a harness ``meta`` block.
_META_KEYS = ("git_sha", "jax_version", "timestamp")


def looks_like_tuning_cache(payload: object) -> bool:
    return (
        isinstance(payload, dict)
        and "entries" in payload
        and "version" in payload
        and isinstance(payload.get("entries"), dict)
    )


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def check_tuning_cache_file(path: str) -> list[Diagnostic]:
    """Validate one tuning-cache JSON against the block-config contracts."""

    from repro.core.blocking import BlockConfig
    from repro.core.execution import BACKENDS, backend_double_buffers
    from repro.kernels.gemm import LANE
    from repro.tuning.cache import CACHE_VERSION, shape_bucket_key
    from repro.tuning.candidates import SPECS

    diags: list[Diagnostic] = []

    def bad(msg: str) -> None:
        diags.append(Diagnostic(code="RPR201", path=path, line=1, message=msg))

    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        bad(f"unreadable tuning cache: {e}")
        return diags
    if not looks_like_tuning_cache(payload):
        return diags  # not a cache; nothing to assert
    if payload.get("version") != CACHE_VERSION:
        # Version-mismatched caches are invalidated wholesale at load time
        # (by design), so their entries carry no contract to verify.
        return diags

    for key, entry in payload["entries"].items():
        m = _KEY_RE.match(key)
        if m is None:
            bad(f"entry key {key!r} is not spec/dtype/MxKxN")
            continue
        spec_name = m.group("spec")
        spec = SPECS.get(spec_name)
        if spec is None:
            bad(
                f"entry {key!r} names unknown core spec {spec_name!r} "
                f"(known: {sorted(SPECS)})"
            )
            continue
        try:
            cfg = BlockConfig(
                bm=int(entry["bm"]),
                bk=int(entry["bk"]),
                bn=int(entry["bn"]),
                dtype_bytes=int(entry.get("dtype_bytes", 2)),
                acc_bytes=int(entry.get("acc_bytes", 4)),
            )
        except (KeyError, TypeError, ValueError) as e:
            bad(f"entry {key!r} malformed: {e}")
            continue

        for dim_name, blk in (("bm", cfg.bm), ("bk", cfg.bk), ("bn", cfg.bn)):
            if blk % LANE != 0 or blk < LANE:
                bad(
                    f"entry {key!r}: {dim_name}={blk} is not "
                    f"{LANE}-lane aligned"
                )

        backend = entry.get("backend")
        db = (
            backend_double_buffers(backend)
            if isinstance(backend, str) and backend in BACKENDS
            else True
        )
        if not cfg.fits(spec, double_buffer=db):
            model = "double" if db else "single"
            bad(
                f"entry {key!r}: working set "
                f"{cfg.vmem_bytes(double_buffer=db)} B ({model}-buffered, "
                f"backend={backend!r}) exceeds {spec_name}'s VMEM budget "
                f"{int(spec.vmem_bytes * spec.vmem_fill)} B"
            )

        shape = entry.get("shape")
        if (
            isinstance(shape, (list, tuple))
            and len(shape) == 3
            and all(isinstance(d, int) and d > 0 for d in shape)
        ):
            sm, sk, sn = shape
            for dim_name, dim, blk in (
                ("bm", sm, cfg.bm), ("bk", sk, cfg.bk), ("bn", sn, cfg.bn)
            ):
                padded = max(LANE, _round_up(dim, LANE))
                if blk > padded:
                    axis = {"bm": "M", "bk": "K", "bn": "N"}[dim_name]
                    bad(
                        f"entry {key!r}: {dim_name}={blk} exceeds the "
                        f"lane-padded {axis}={padded} of its recorded shape "
                        f"{sm}x{sk}x{sn} — padded-FLOPs multiplier "
                        "(the PR-4 bug class)"
                    )
            expect = shape_bucket_key(
                spec_name, m.group("dtype"), sm, sk, sn
            )
            if expect != key:
                bad(
                    f"entry {key!r}: recorded shape {sm}x{sk}x{sn} buckets "
                    f"to {expect!r} — key and shape drifted apart"
                )
    return diags


def check_shipped_trees(
    shapes: Optional[list[tuple[int, int, int]]] = None,
) -> list[Diagnostic]:
    """Build control trees from the shipped specs; verify their contracts.

    Every ``BlockConfig`` reachable from the registered spec family
    (``tuning.candidates.SPECS``) through :func:`build_control_trees`
    must fit its class's VMEM under the tree backend's buffering model,
    stay lane-aligned, and honor the shared-``bk`` constraint when the
    coarse loop shares the B panel.
    """

    from repro.core.control_tree import build_control_trees
    from repro.core.execution import backend_double_buffers
    from repro.kernels.gemm import LANE
    from repro.tuning.candidates import SPECS

    anchor = "src/repro/core/control_tree.py"
    diags: list[Diagnostic] = []
    shapes = shapes or [(1024, 1024, 1024), (2048, 2048, 2048), (512, 4096, 512)]
    for m, k, n in shapes:
        for backend in ("xla", "pallas"):
            for coarse_loop in ("rows", "cols"):
                trees = build_control_trees(
                    dict(SPECS), m, k, n,
                    backend=backend, coarse_loop=coarse_loop,
                    use_cache=False,
                )
                bks = set()
                for name, tree in trees.items():
                    where = (
                        f"tree[{name}] ({m}x{k}x{n}, backend={backend}, "
                        f"coarse={coarse_loop})"
                    )
                    db = backend_double_buffers(tree.backend)
                    if not tree.block.fits(tree.spec, double_buffer=db):
                        diags.append(
                            Diagnostic(
                                code="RPR201", path=anchor, line=1,
                                message=(
                                    f"{where}: block {tree.block.bm}x"
                                    f"{tree.block.bk}x{tree.block.bn} "
                                    f"overflows {tree.spec.name} VMEM under "
                                    f"its {'double' if db else 'single'}-"
                                    "buffered model"
                                ),
                            )
                        )
                    for blk in (tree.block.bm, tree.block.bk, tree.block.bn):
                        if blk % LANE != 0:
                            diags.append(
                                Diagnostic(
                                    code="RPR201", path=anchor, line=1,
                                    message=(
                                        f"{where}: block dim {blk} is not "
                                        f"{LANE}-lane aligned"
                                    ),
                                )
                            )
                    bks.add(tree.block.bk)
                if coarse_loop == "rows" and len(bks) > 1:
                    diags.append(
                        Diagnostic(
                            code="RPR201", path=anchor, line=1,
                            message=(
                                f"shared-B-panel violation at {m}x{k}x{n} "
                                f"(backend={backend}): classes disagree on "
                                f"the shared bk: {sorted(bks)}"
                            ),
                        )
                    )
    return diags


def check_bench_artifact(path: str) -> list[Diagnostic]:
    """Schema-check one ``BENCH_*.json`` against the harness contract."""

    diags: list[Diagnostic] = []

    def bad(msg: str) -> None:
        diags.append(Diagnostic(code="RPR202", path=path, line=1, message=msg))

    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        bad(f"unreadable bench artifact: {e}")
        return diags
    if not isinstance(payload, dict):
        bad(f"top level must be an object, got {type(payload).__name__}")
        return diags
    meta = payload.get("meta")
    records = payload.get("records")
    if not isinstance(meta, dict):
        bad("missing/non-object `meta` block (harness.write_json stamps it)")
    else:
        missing = [k for k in _META_KEYS if k not in meta]
        if missing:
            bad(f"meta block missing provenance keys: {missing}")
    if not isinstance(records, list):
        bad("missing/non-list `records`")
    elif not all(isinstance(r, dict) for r in records):
        bad("every record must be an object")
    else:
        for i, rec in enumerate(records):
            if "objective_ab" in rec:
                _check_objective_ab(rec["objective_ab"], i, bad)
    return diags


def _check_objective_ab(block, idx: int, bad) -> None:
    """Schema for a record's ``objective_ab`` A/B comparison block.

    Emitted by ``benchmarks.bench_serving.objective_ab``: a perf side and
    one non-perf side, each carrying the modeled energy columns the CI
    energy gate reads (``energy_j``, ``tokens_per_j``), plus the derived
    ratios the ``--check`` gate thresholds.
    """

    where = f"records[{idx}].objective_ab"
    if not isinstance(block, dict):
        bad(f"{where} must be an object, got {type(block).__name__}")
        return
    obj = block.get("objective")
    if not isinstance(obj, str) or obj == "perf":
        bad(f"{where}.objective must name a non-perf objective, got {obj!r}")
        return
    for side in ("perf", obj):
        cols = block.get(side)
        if not isinstance(cols, dict):
            bad(f"{where}.{side} side missing/non-object")
            continue
        for col in ("energy_j", "tokens_per_j"):
            v = cols.get(col)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                bad(f"{where}.{side}.{col} must be a number, got {v!r}")
    for ratio in ("energy_ratio", "throughput_ratio"):
        v = block.get(ratio)
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            bad(f"{where}.{ratio} must be a number, got {v!r}")
    if block.get("tokens_identical") is not True:
        bad(f"{where}.tokens_identical must be true — the objective knob "
            "must not change decoded tokens")


def check_artifacts_dir(art_dir: str) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    if not os.path.isdir(art_dir):
        return diags
    for fname in sorted(os.listdir(art_dir)):
        if fname.startswith("BENCH_") and fname.endswith(".json"):
            diags.extend(check_bench_artifact(os.path.join(art_dir, fname)))
    return diags


__all__ = [
    "check_tuning_cache_file",
    "check_shipped_trees",
    "check_bench_artifact",
    "check_artifacts_dir",
    "looks_like_tuning_cache",
]

"""Diagnostic model for the repro static verifier.

One :class:`Diagnostic` per finding, carrying a stable ``RPR0xx`` code so
call sites can suppress (and CI can grep) without matching message prose.
The code space is partitioned by layer:

  * ``RPR0xx`` — AST lint passes over source trees (no imports executed),
  * ``RPR1xx`` — backend-registry contract checks (the dispatch tables),
  * ``RPR2xx`` — config/artifact contract checks (tuning caches, shipped
    control trees, ``BENCH_*.json`` schemas).

Suppression is inline and reasoned::

    risky_line()  # repro: noqa=RPR001 -- twin trainer is undonated by design

A suppression names its code(s) and must carry a ``-- reason``; one with
no reason is itself reported (``RPR000``) so unexplained escapes cannot
accumulate.  A suppression comment applies to
the physical lines its statement spans (multi-line calls may carry it on
any of their lines).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable, Optional

# code -> one-line invariant description (the catalogue DESIGN.md §8 mirrors).
CODES: dict[str, str] = {
    "RPR000": "suppression without a reason (`# repro: noqa=CODE -- why`)",
    "RPR001": "use-after-donate: value read after being passed in a donated "
              "argnum position of a jitted callable",
    "RPR002": "donation pin: np.asarray/np.array result flows into a donated "
              "argnum position (host copy silently disables donation)",
    "RPR003": "jax.jit / pl.pallas_call constructed inside a loop body "
              "(per-iteration retrace/recompile hazard)",
    "RPR004": "raw ContextVar.set without token-reset-in-finally outside the "
              "blessed helpers (execution.py / trace.py discipline)",
    "RPR005": "backend-name or scheduling-objective string literal outside "
              "the live vocabulary (execution.BACKENDS / schedule.OBJECTIVES "
              "drift)",
    "RPR006": "fault-point name string literal outside the live injection "
              "registry (runtime.faults.FAULT_POINTS drift)",
    "RPR101": "backend-registry closure violation (BACKENDS / BACKEND_OPS / "
              "INTERPRET_TWIN / LEAN_VARIANTS)",
    "RPR102": "kernel-family closure violation (GEMM_KERNELS / paged-attn "
              "family not closed under align_backend_family)",
    "RPR201": "block-config contract violation (VMEM budget under the "
              "kernel's buffering model, lane alignment, padded-problem "
              "bound, shared-bk constraint)",
    "RPR202": "bench artifact schema violation (BENCH_*.json meta/records)",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: stable code + location + human message."""

    code: str
    path: str
    line: int
    message: str
    col: int = 0

    def __post_init__(self) -> None:
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")

    def key(self) -> tuple:
        return (self.path, self.line, self.col, self.code, self.message)


# ``# repro: noqa=RPR001 -- why`` / ``# repro: noqa=RPR001,RPR002 -- why``
_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\s*=\s*(?P<codes>RPR\d{3}(?:\s*,\s*RPR\d{3})*)"
    r"(?:\s*--\s*(?P<reason>.*\S))?"
)


@dataclasses.dataclass
class Suppressions:
    """Per-file map of line -> suppressed codes, parsed from comments."""

    by_line: dict[int, frozenset[str]]
    missing_reason: list[int]  # lines with a noqa but no `-- reason`

    @classmethod
    def scan(cls, source: str) -> "Suppressions":
        by_line: dict[int, frozenset[str]] = {}
        missing: list[int] = []
        for i, text in enumerate(source.splitlines(), start=1):
            m = _NOQA_RE.search(text)
            if not m:
                continue
            codes = frozenset(c.strip() for c in m.group("codes").split(","))
            by_line[i] = by_line.get(i, frozenset()) | codes
            if not m.group("reason"):
                missing.append(i)
        return cls(by_line=by_line, missing_reason=missing)

    def covers(self, code: str, lines: Iterable[int]) -> bool:
        return any(code in self.by_line.get(ln, ()) for ln in lines)


def apply_suppressions(
    path: str, source: str, diags: list[Diagnostic]
) -> list[Diagnostic]:
    """Drop suppressed findings; report reason-less noqa comments."""

    supp = Suppressions.scan(source)
    lines = source.splitlines()
    out = []
    for d in diags:
        span = _statement_span(lines, d.line)
        if not supp.covers(d.code, span):
            out.append(d)
    for ln in supp.missing_reason:
        out.append(
            Diagnostic(
                code="RPR000",
                path=path,
                line=ln,
                message="suppression must explain itself: "
                        "`# repro: noqa=CODE -- reason`",
            )
        )
    return out


def _statement_span(lines: list[str], lineno: int, reach: int = 8) -> range:
    """Physical lines a finding's suppression may sit on.

    A multi-line statement (call spanning several lines) may carry the
    noqa on any of its continuation lines; without a full parse we accept
    a bounded look-ahead from the flagged line through lines that are
    clearly continuations (deeper indent / closing brackets), capped at
    ``reach`` lines.
    """

    if lineno < 1 or lineno > len(lines):
        return range(lineno, lineno + 1)
    end = lineno
    base_indent = len(lines[lineno - 1]) - len(lines[lineno - 1].lstrip())
    for ln in range(lineno + 1, min(lineno + reach, len(lines)) + 1):
        text = lines[ln - 1]
        stripped = text.strip()
        if not stripped:
            break
        indent = len(text) - len(text.lstrip())
        if indent > base_indent or stripped[0] in ")]}":
            end = ln
        else:
            break
    return range(lineno, end + 1)


# ---------------------------------------------------------------------------
# Output formats
# ---------------------------------------------------------------------------


def format_text(diags: list[Diagnostic]) -> str:
    return "\n".join(
        f"{d.path}:{d.line}:{d.col}: {d.code} {d.message}" for d in diags
    )


def format_github(diags: list[Diagnostic]) -> str:
    """GitHub Actions workflow-command annotations (render on the PR diff)."""

    out = []
    for d in diags:
        msg = f"{d.code} {d.message}".replace("%", "%25").replace(
            "\n", "%0A"
        )
        out.append(
            f"::error file={d.path},line={d.line},col={max(d.col, 1)},"
            f"title={d.code}::{msg}"
        )
    return "\n".join(out)


def format_json(diags: list[Diagnostic]) -> str:
    return json.dumps(
        {
            "version": 1,
            "codes": CODES,
            "diagnostics": [dataclasses.asdict(d) for d in diags],
        },
        indent=1,
        sort_keys=True,
    )


FORMATTERS = {"text": format_text, "github": format_github, "json": format_json}


def render(diags: list[Diagnostic], fmt: str) -> str:
    try:
        formatter = FORMATTERS[fmt]
    except KeyError:
        raise ValueError(
            f"unknown format {fmt!r}; known: {sorted(FORMATTERS)}"
        ) from None
    return formatter(sorted(diags, key=Diagnostic.key))


__all__ = [
    "CODES",
    "Diagnostic",
    "Suppressions",
    "apply_suppressions",
    "render",
    "FORMATTERS",
]

"""data substrate."""

"""Deterministic token data pipeline with asymmetric batch layout.

Sources:
  * :class:`SyntheticLM` — seeded counter-based token stream (fully
    deterministic and resumable from any step — the property the
    fault-tolerance tests rely on),
  * :class:`MemmapLM` — flat uint16/int32 token files (production path).

:class:`AsymmetricBatcher` lays each global batch out as the padded
``(n_pods * c_max, S)`` block prescribed by the scheduler's chunk table,
with a validity mask, so pod *i*'s data shard contains exactly the rows
the (CA-)SAS/DAS schedule assigned to it (the paper's coarse-grain Loop-1/3
partition, at batch granularity).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.asymmetric import AsymmetricMesh, BatchLayout


class SyntheticLM:
    """Deterministic pseudo-text: tokens from a counter-keyed Philox."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, batch: int, seq: int) -> dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=step))
        tokens = rng.integers(0, self.vocab, size=(batch, seq + 1), dtype=np.int32)
        # Inject learnable structure: every even position repeats the
        # previous token mod vocab, so tiny models can visibly learn.
        tokens[:, 1::2] = (tokens[:, 0:-1:2] + 1) % self.vocab
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class MemmapLM:
    """Flat binary token file -> (tokens, labels) windows."""

    def __init__(self, path: str, vocab: int, dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab

    def batch(self, step: int, batch: int, seq: int) -> dict[str, np.ndarray]:
        n = len(self.data)
        span = seq + 1
        starts = (step * batch + np.arange(batch)) * span % max(n - span, 1)
        rows = np.stack([self.data[s : s + span].astype(np.int32) for s in starts])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


@dataclasses.dataclass
class BatchWithLayout:
    arrays: dict[str, np.ndarray]  # tokens/labels: (n_pods*c_max, S); mask: (n_pods*c_max, S)
    layout: BatchLayout


class AsymmetricBatcher:
    """Reshapes a logical global batch onto the scheduler's chunk table."""

    def __init__(self, source, asym: AsymmetricMesh):
        self.source = source
        self.asym = asym

    def batch(self, step: int, global_batch: int, seq: int) -> BatchWithLayout:
        layout = self.asym.batch_layout(global_batch)
        logical = self.source.batch(step, global_batch, seq)
        n_pods, c_max = len(layout.sizes), layout.c_max
        out = {}
        for k, v in logical.items():
            padded = np.zeros((n_pods * c_max,) + v.shape[1:], v.dtype)
            pos = 0
            for i, size in enumerate(layout.sizes):
                padded[i * c_max : i * c_max + size] = v[pos : pos + size]
                pos += size
            out[k] = padded
        mask = np.repeat(
            layout.mask.reshape(n_pods * c_max, 1), logical["tokens"].shape[1], axis=1
        ).astype(np.float32)
        out["mask"] = mask
        return BatchWithLayout(arrays=out, layout=layout)


def batches(source, global_batch: int, seq: int, steps: int, start_step: int = 0
            ) -> Iterator[dict[str, np.ndarray]]:
    for step in range(start_step, start_step + steps):
        yield source.batch(step, global_batch, seq)


__all__ = ["SyntheticLM", "MemmapLM", "AsymmetricBatcher", "BatchWithLayout", "batches"]

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:

  * the sharding config is coherent (GSPMD partitions every op),
  * the program fits (``memory_analysis`` bytes per device),
  * and it yields the roofline inputs: parsed per-device FLOPs / HBM bytes /
    collective bytes (``hlo_analysis``, trip-count-corrected) plus XLA's own
    ``cost_analysis`` for cross-checking.

Usage::

    python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--force]

One JSON artifact per cell lands in ``artifacts/dryrun/``.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, list_configs  # noqa: E402
from repro.core import execution as X  # noqa: E402
from repro.distributed import sharding as SH  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model_zoo as Z  # noqa: E402
from repro.optim import adamw as O  # noqa: E402


def _bf16_specs(tree):
    """Serving runs bf16 weights (training keeps fp32 masters)."""

    def f(x):
        dt = jnp.bfloat16 if x.dtype == jnp.float32 and x.ndim >= 2 else x.dtype
        return jax.ShapeDtypeStruct(x.shape, dt)

    return jax.tree.map(f, tree)


def _mixed_active(asym, mesh) -> bool:
    return (
        asym is not None
        and len(asym.classes) > 1
        and "pod" in mesh.axis_names
        and mesh.shape["pod"] == asym.n_pods
    )


def build_cell(arch_name: str, shape_name: str, mesh, *, remat=True, fsdp=True,
               seq_shard=True, asym=None):
    """Returns (fn, example_args_specs, in_shardings, out_shardings).

    With a multi-class ``asym`` (``--little-spec``) and a pod-axis mesh,
    the cell fn is wrapped through ``class_sharded``: each pod's shard of
    the step lowers under its own class's control tree — the mixed-step
    program the fleet would actually run.
    """

    cfg = get_config(arch_name)
    SH.use_mesh_for_activations(mesh, seq_shard=seq_shard)
    shape = next(s for s in cfg.shapes(include_skipped=True) if s.name == shape_name)
    params_spec = jax.eval_shape(lambda: Z.init_params(jax.random.PRNGKey(0), cfg))
    batch = Z.batch_spec(cfg, shape)
    batch_sh = SH.batch_sharding(mesh, batch)
    mixed = _mixed_active(asym, mesh)

    if shape.kind == "train":
        p_sh = SH.shard_params(params_spec, mesh, fsdp=fsdp)
        opt_spec = jax.eval_shape(O.init_opt_state, params_spec)
        o_sh = SH.shard_opt_state(None, p_sh, mesh)
        opt_cfg = O.AdamWConfig()
        loss = Z.make_loss_fn(cfg, remat=remat)

        if mixed:
            from repro.runtime.trainer import build_class_sharded_grad_step

            grad_fn = build_class_sharded_grad_step(loss, asym, mesh)

            def train_step(params, opt_state, b):
                l, metrics, grads = grad_fn(params, b)
                params, opt_state, om = O.adamw_update(params, grads, opt_state, opt_cfg)
                return params, opt_state, l

            train_step.provenance = grad_fn.provenance
        else:
            def train_step(params, opt_state, b):
                (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, b)
                params, opt_state, om = O.adamw_update(params, grads, opt_state, opt_cfg)
                return params, opt_state, l

        return (
            train_step,
            (params_spec, opt_spec, batch),
            (p_sh, o_sh, batch_sh),
            (p_sh, o_sh, NamedSharding(mesh, P())),
        )

    # Inference cells: bf16 weights, no optimizer.
    params_bf16 = _bf16_specs(params_spec)
    p_sh = SH.shard_params(params_bf16, mesh, fsdp=False)

    if shape.kind == "prefill":
        fn = Z.make_prefill_fn(cfg)
        if mixed:
            fn = asym.class_sharded(
                fn, mesh=mesh,
                in_specs=(P(), SH.pod_batch_specs(batch)),
                out_specs=P("pod"),
            )
        logits_sh = SH.array_sharding(
            mesh,
            (shape.global_batch, shape.seq_len, cfg.vocab),
            P(SH.batch_pspec(mesh, shape.global_batch)[0], None, "model"),
        )
        return fn, (params_bf16, batch), (p_sh, batch_sh), logits_sh

    # decode
    state_spec = Z.decode_state_spec(cfg, shape.global_batch, shape.seq_len)
    state_sh = SH.cache_sharding(mesh, state_spec)
    fn = Z.make_decode_fn(cfg)
    if mixed:
        sspecs = SH.pod_state_specs(state_spec)
        fn = asym.class_sharded(
            fn, mesh=mesh,
            in_specs=(P(), P("pod"), sspecs, P()),
            out_specs=(P("pod"), sspecs),
        )
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    logits_sh = SH.array_sharding(
        mesh,
        (shape.global_batch, 1, cfg.vocab),
        P(SH.batch_pspec(mesh, shape.global_batch)[0], None, "model"),
    )
    return (
        fn,
        (params_bf16, batch, state_spec, pos_spec),
        (p_sh, batch_sh, state_sh, NamedSharding(mesh, P())),
        (logits_sh, state_sh),
    )


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             force: bool = False, remat: bool = True, fsdp: bool = True,
             seq_shard: bool = True, tag: str = "", spec_name: str = "tpu-v5e",
             little_spec: str = "", backend: str = "auto") -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    # Non-default specs/backends get their own cell files — otherwise a
    # --spec/--backend run would silently return records lowered under a
    # different context.
    cell_id = (
        f"{arch_name}__{shape_name}__{mesh_tag}"
        + (f"__{spec_name}" if spec_name != "tpu-v5e" else "")
        + (f"__mixed-{little_spec}" if little_spec else "")
        + (f"__{backend}" if backend != "auto" else "")
        + (f"__{tag}" if tag else "")
    )
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch_name)
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_tag,
        "tag": tag,
        "ok": False,
        "skipped": False,
    }
    if shape_name == "long_500k" and not cfg.subquadratic:
        rec.update(skipped=True, reason="full quadratic attention (see DESIGN.md)")
        _write(path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        from repro.tuning.candidates import get_spec

        asym = None
        if little_spec:
            if not multi_pod:
                raise ValueError("--little-spec needs --multi-pod (a pod axis)")
            from repro.core.asymmetric import AsymmetricMesh, DeviceClass

            asym = AsymmetricMesh(
                [
                    DeviceClass("big", spec=get_spec(spec_name)),
                    DeviceClass("little", spec=get_spec(little_spec),
                                rel_throughput=0.35),
                ],
                backend=backend,
            )

        t0 = time.time()
        # Lower under the target class's execution context: with a tuning
        # cache active the cell's matmuls pick up the per-spec tuned block
        # configs; without one this is behavior-neutral (analytical +
        # auto backend, exactly the bare defaults).  With --little-spec the
        # cell fn itself is class-sharded (each pod under its own tree) and
        # this outer context only covers math outside the shard_map.
        exec_ctx = X.default_context(spec=get_spec(spec_name), backend=backend)
        with exec_ctx:
            fn, args, in_sh, out_sh = build_cell(
                arch_name, shape_name, mesh, remat=remat, fsdp=fsdp,
                seq_shard=seq_shard, asym=asym,
            )
            # Donate the big mutable state: params+opt for train (step output
            # aliases input), the KV/SSM caches for decode.
            donate = (0, 1) if len(args) == 3 else ((2,) if len(args) == 4 else ())
            with mesh:
                lowered = jax.jit(
                    fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
                ).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0] if ca else {}
        text = compiled.as_text()
        cost = hlo_analysis.analyze(text)

        rec.update(
            ok=True,
            device_class=exec_ctx.device_class,
            exec_backend=exec_ctx.backend(),
            class_sharded=bool(asym is not None),
            shard_classes=(
                [(p.pod, p.device_class, p.block_source, p.backend)
                 for p in getattr(fn, "provenance", [])]
                if asym is not None else None
            ),
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "total_bytes": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes
                + ma.output_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            xla_cost={
                "flops": ca.get("flops"),
                "bytes_accessed": ca.get("bytes accessed"),
            },
            hlo_cost=cost.as_dict(),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        rec.update(error=f"{type(e).__name__}: {e}", trace=traceback.format_exc()[-2000:])
    _write(path, rec)
    return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    from repro.tuning.candidates import SPECS

    ap.add_argument("--spec", default="tpu-v5e", choices=sorted(SPECS),
                    help="core spec whose execution context lowers the cells")
    ap.add_argument("--little-spec", default="", choices=[""] + sorted(SPECS),
                    help="second device class: lower the cell class-sharded "
                         "(pod 0 under --spec, pod 1 under this spec); needs "
                         "--multi-pod.  The shard_map is fully manual, so "
                         "intra-pod devices replicate their pod's program — "
                         "the record shows the mixed program structure, not "
                         "per-device memory at production intra-pod sharding")
    from repro.core.execution import GEMM_BACKEND_NAMES

    ap.add_argument("--backend", default="auto",
                    choices=["auto"] + sorted(GEMM_BACKEND_NAMES),
                    help="micro-kernel dispatch entry the cells lower with "
                         "(e.g. pallas_lean for the VMEM-lean variant; auto "
                         "probes the platform — xla off-TPU).  Pallas "
                         "backends only compile on TPU hosts")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = (
            [s.name for s in cfg.shapes(include_skipped=True)]
            if (args.all or not args.shape)
            else [args.shape]
        )
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(
                    arch,
                    shape,
                    multi_pod=mp,
                    out_dir=args.out,
                    force=args.force,
                    remat=not args.no_remat,
                    fsdp=not args.no_fsdp,
                    seq_shard=not args.no_seq_shard,
                    tag=args.tag,
                    spec_name=args.spec,
                    little_spec=args.little_spec,
                    backend=args.backend,
                )
                if rec.get("skipped"):
                    n_skip += 1
                    status = "SKIP"
                elif rec.get("ok"):
                    n_ok += 1
                    status = "ok"
                else:
                    n_fail += 1
                    status = "FAIL"
                mem = rec.get("memory", {}).get("total_bytes")
                mem_s = f"{mem/2**30:6.2f} GiB/dev" if mem else "-"
                print(
                    f"[{status:4s}] {arch:18s} {shape:12s} "
                    f"{'2x16x16' if mp else '16x16':8s} {mem_s} "
                    f"compile={rec.get('compile_s','-')}s"
                    + (f"  err={rec.get('error','')[:120]}" if status == "FAIL" else ""),
                    flush=True,
                )
    print(f"\ndry-run summary: ok={n_ok} fail={n_fail} skip={n_skip}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods × 256
chips as (pod=2, data=16, model=16) — the ``pod`` axis is the coarse
(asymmetric-schedulable) axis, ``data``/``model`` the symmetric intra-pod
axes (see DESIGN.md §2).

Functions, not module constants: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (TypeError, AttributeError):  # older jax without axis_types/AxisType
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(*, model: int = 1, data: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — tests/examples."""

    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


__all__ = ["make_production_mesh", "make_host_mesh"]

"""Static cost analysis of compiled (post-SPMD-partitioning) HLO text.

Why not ``compiled.cost_analysis()``: XLA's flop counter visits each
computation once, so a ``jax.lax.scan`` over L layers reports the body's
FLOPs a single time (~1/L of the truth — verified empirically in
EXPERIMENTS.md §Dry-run).  This parser rebuilds the call graph
(ENTRY → while bodies → fusions), extracts each while loop's trip count
from its condition computation, and multiplies.

Outputs per compiled module (all **per device**, since SPMD-partitioned
HLO is the per-device program):

  * ``flops``            — 2·M·N·K over every dot/convolution, × trip counts,
  * ``bytes``            — operand+result bytes of every top-level kernel op
                           (fusion internals excluded: the fusion boundary
                           is the HBM traffic boundary), × trip counts,
  * ``collective_bytes`` — operand bytes of all-reduce / all-gather /
                           reduce-scatter / all-to-all / collective-permute,
                           × trip counts, split by type.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f4e2m1fn": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_PREFIX_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\("
)


def _parse_op_line(line: str):
    """Split an HLO op line into (name, type, opcode, args, attrs) with
    balanced-paren scanning — greedy regexes corrupt operand lists for ops
    carrying parenthesized attrs (``dimensions={...}``, ``sharding=...``)."""

    m = _OP_PREFIX_RE.match(line)
    if not m:
        return None
    depth = 1
    i = m.end()
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    args = line[m.end() : i - 1]
    attrs = line[i:]
    return m.group(1), m.group(2), m.group(3), args, attrs
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "while",
    "conditional", "call", "after-all", "add-dependency", "custom-call", "iota",
    "partition-id", "replica-id",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    is_entry: bool = False


def _parse_operand_names(args: str) -> list[str]:
    # operands look like "%a.1, f32[8]{0} %b, ..." or "bf16[2,3]{1,0} %x"
    names = []
    depth = 0
    cur = []
    for ch in args:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            names.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        names.append("".join(cur))
    out = []
    for tok in names:
        tok = tok.strip()
        m = re.search(r"%?([\w\.\-]+)\s*$", tok)
        out.append(m.group(1) if m else tok)
    return out


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    text = re.sub(r"/\*.*?\*/", "", text)  # strip /*index=N*/ tuple comments
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
            if m and "=" not in line.split("{")[0]:
                cur = Computation(
                    name=m.group(1), ops=[], is_entry=line.strip().startswith("ENTRY")
                )
            continue
        s = line.strip()
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, opcode, args, attrs = parsed
            cur.ops.append(
                Op(
                    name=name,
                    type_str=type_str,
                    opcode=opcode,
                    operands=_parse_operand_names(args),
                    attrs=attrs or "",
                    raw=line,
                )
            )
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant compared in the condition (scan loops)."""

    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    attn_score_bytes: float = 0.0  # HBM traffic of materialized attention
    # scores — VMEM-resident under the Pallas flash kernel on TPU
    collective_bytes: float = 0.0
    by_collective: dict = dataclasses.field(default_factory=dict)
    dot_count: int = 0
    while_trips: dict = dataclasses.field(default_factory=dict)
    top_bytes: list = dataclasses.field(default_factory=list)   # (bytes, op, comp)
    top_flops: list = dataclasses.field(default_factory=list)   # (flops, op, comp)

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "attn_score_bytes": self.attn_score_bytes,
            "collective_bytes": self.collective_bytes,
            "by_collective": dict(self.by_collective),
            "dot_count": self.dot_count,
            "while_trips": dict(self.while_trips),
        }


def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    out_dims = _shape_dims(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.raw)
    contract = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs_type = symbols.get(op.operands[0], "")
    lhs_dims = _shape_dims(lhs_type)
    k = 1
    for c in contract:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * max(k, 1)


def _conv_flops(op: Op, symbols: dict[str, str]) -> float:
    # approximate: 2 * prod(out) * prod(kernel dims) (feature dims included)
    out = 1
    for d in _shape_dims(op.type_str):
        out *= d
    rhs_dims = _shape_dims(symbols.get(op.operands[1], "")) if len(op.operands) > 1 else []
    k = 1
    for d in rhs_dims[:-1]:  # exclude output-feature dim (already in out)
        k *= d
    return 2.0 * out * max(k, 1)


def _fusion_bytes(op: Op, comps, symbols, parent_syms) -> float:
    """Effective HBM bytes of one fusion call.

    Parameters consumed (only) through a ``dynamic-slice`` inside the body
    are charged at the slice size, not the full operand (per-layer weight
    selection from scan-stacked tensors reads one layer, not all L).  A
    root ``dynamic-update-slice`` writes (and re-reads) only its update
    window — XLA aliases the big buffer in place.
    """

    mm = re.search(r"calls=%?([\w\.\-]+)", op.raw)
    out_b = _shape_bytes(op.type_str)
    in_full = [_shape_bytes(parent_syms.get(o, "")) for o in op.operands]
    if not mm or mm.group(1) not in comps:
        return out_b + sum(in_full)
    body = comps[mm.group(1)]
    body_syms = symbols[mm.group(1)]

    # Pure dtype/layout-cast fusions (convert/bitcast/reshape only) never
    # reach HBM on TPU — Mosaic/XLA:TPU folds them into the consumer; they
    # exist as separate kernels only in this CPU lowering of bf16 dots.
    kinds = {bop.opcode for bop in body.ops if bop.opcode != "parameter"}
    if kinds <= {"convert", "bitcast", "reshape", "copy", "transpose"}:
        # Dtype/layout-only fusions: XLA:TPU folds converts into consumers
        # and transposes into dot dimension-numbers; they hit HBM only in
        # this CPU lowering.
        return 0.0

    # parameter name -> index; consumer counts per body value
    param_idx: dict[str, int] = {}
    consumers: dict[str, int] = {}
    defs: dict[str, Op] = {}
    for bop in body.ops:
        defs[bop.name] = bop
        if bop.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", bop.raw)
            if m:
                param_idx[bop.name] = int(m.group(1))
        for o in bop.operands:
            consumers[o] = consumers.get(o, 0) + 1

    def resolve(name: str) -> str:
        # Walk through dtype/layout casts to the producing value.
        seen = 0
        while (
            name in defs
            and defs[name].opcode in ("convert", "bitcast", "reshape", "copy")
            and defs[name].operands
            and seen < 8
        ):
            name = defs[name].operands[0]
            seen += 1
        return name

    eff = dict(enumerate(in_full))
    root_is_dus = False
    dus_update_b = None
    for bop in body.ops:
        if bop.opcode == "dynamic-slice" and bop.operands:
            src = resolve(bop.operands[0])
            if src in param_idx and consumers.get(src, 0) == 1:
                eff[param_idx[src]] = _shape_bytes(bop.type_str)
        elif bop.opcode == "dynamic-update-slice" and bop.operands:
            src = resolve(bop.operands[0])
            upd = bop.operands[1] if len(bop.operands) > 1 else None
            upd_b = _shape_bytes(body_syms.get(upd, "")) if upd else 0
            if src in param_idx:
                # In-place on TPU: the DUS path touches only the window;
                # any sibling read of the same buffer is charged by its
                # own consumer (e.g. the attention dot).
                eff[param_idx[src]] = min(eff[param_idx[src]], upd_b)
            # The fusion output is the (possibly converted) updated buffer:
            # in-place on TPU, so the write is the update window only.
            full_src_b = _shape_bytes(body_syms.get(src, ""))
            if out_b >= 0.9 * full_src_b > 0:
                root_is_dus = True
                dus_update_b = upd_b
    out_eff = dus_update_b if (root_is_dus and dus_update_b) else out_b
    return out_eff + sum(eff.values())


def analyze(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # Per-computation symbol tables (op name -> result type).
    symbols = {c.name: {op.name: op.type_str for op in c.ops} for c in comps.values()}

    # Multipliers via BFS over the call graph; fusion bodies tracked apart.
    mult: dict[str, float] = defaultdict(float)
    fusion_body: set[str] = set()
    cost = HloCost(by_collective=defaultdict(float))

    stack = [(entry.name, 1.0)]
    seen_pairs = set()
    while stack:
        cname, m = stack.pop()
        mult[cname] += m
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            if op.opcode == "while":
                body = cond = None
                mm = re.search(r"body=%?([\w\.\-]+)", op.raw)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.raw)
                if mm:
                    body = mm.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                cost.while_trips[body or op.name] = trips
                if body:
                    stack.append((body, m * trips))
            elif op.opcode == "fusion":
                mm = re.search(r"calls=%?([\w\.\-]+)", op.raw)
                if mm:
                    fusion_body.add(mm.group(1))
                    stack.append((mm.group(1), m))
            elif op.opcode in ("call", "conditional", "map", "reduce", "sort",
                               "reduce-window", "scatter", "select-and-scatter",
                               "all-reduce", "reduce-scatter"):
                for target in _CALL_ATTR_RE.findall(op.raw):
                    key = (target, m, op.name)
                    if key not in seen_pairs:
                        seen_pairs.add(key)
                        if op.opcode in ("call", "conditional"):
                            stack.append((target, m))
                        # to_apply adders contribute negligible flops; skip.

    def _score_like(type_str: str) -> bool:
        # (B, H, [G,] q_chunk, S_k) attention-score blocks from the
        # chunked-attention path: 4+D, q_chunk in {256, 512}, long K.
        dims = _shape_dims(type_str)
        return len(dims) >= 4 and dims[-2] in (256, 512) and dims[-1] >= 2048

    # Now accumulate costs.
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        syms = symbols[cname]
        in_fusion = cname in fusion_body
        for op in comp.ops:
            if op.opcode == "dot":
                fl = m * _dot_flops(op, syms)
                cost.flops += fl
                cost.dot_count += 1
                cost.top_flops.append((fl, op.name, cname))
            elif op.opcode == "convolution":
                cost.flops += m * _conv_flops(op, syms)
            if in_fusion:
                continue  # bytes & collectives counted at the call site
            if op.opcode in _COLLECTIVES:
                b = sum(_shape_bytes(syms.get(o, "")) for o in op.operands)
                # XLA:CPU legalizes bf16 reductions by promoting to f32
                # (marker: "...promoted" apply computation); on TPU the
                # wire dtype stays bf16 — count the true width.
                if "promoted" in op.raw:
                    b //= 2
                cost.collective_bytes += m * b
                cost.by_collective[op.opcode] += m * b
            if op.opcode in _SKIP_BYTES or op.opcode in _COLLECTIVES:
                continue
            out_b = _shape_bytes(op.type_str)
            if op.opcode == "fusion":
                b = _fusion_bytes(op, comps, symbols, syms)
                cost.bytes += m * b
                if _score_like(op.type_str):
                    cost.attn_score_bytes += m * b
                cost.top_bytes.append((m * b, f"fusion:{op.name}", cname))
                continue
            if op.opcode in ("dynamic-update-slice", "dynamic-slice", "gather", "scatter"):
                # These touch only the slice/update window, not the whole
                # operand (XLA aliases the big buffer in place): count the
                # moved window twice (read + write).  For DUS the window is
                # the update operand; for DS/gather it is the output.
                if op.opcode == "dynamic-update-slice":
                    win = _shape_bytes(syms.get(op.operands[1], "")) if len(op.operands) > 1 else out_b
                elif op.opcode == "scatter":
                    win = _shape_bytes(syms.get(op.operands[-1], "")) if op.operands else out_b
                else:
                    win = out_b
                cost.bytes += m * 2 * win
                cost.top_bytes.append((m * 2 * win, f"{op.opcode}:{op.name}", cname))
                continue
            in_b = sum(_shape_bytes(syms.get(o, "")) for o in op.operands)
            cost.bytes += m * (out_b + in_b)
            if _score_like(op.type_str) or (
                op.opcode == "dot" and any(_score_like(syms.get(o, "")) for o in op.operands)
            ):
                cost.attn_score_bytes += m * (out_b + in_b)
            cost.top_bytes.append((m * (out_b + in_b), f"{op.opcode}:{op.name}", cname))
    cost.by_collective = dict(cost.by_collective)
    cost.top_bytes = sorted(cost.top_bytes, reverse=True)[:20]
    cost.top_flops = sorted(cost.top_flops, reverse=True)[:20]
    return cost


__all__ = ["analyze", "parse_hlo", "HloCost"]

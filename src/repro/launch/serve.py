"""Serving driver: batched prefill + decode with asymmetric request routing.

Demonstrates the inference side of the paper's scheduling: a heterogeneous
two-class serving fleet where the (CA-)SAS/DAS schedulers split each
request batch across device classes proportionally to their measured
decode throughput, exactly as the paper splits GEMM row-panels.

Example (CPU, reduced config)::

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 8 --prompt-len 16 --gen-len 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as Z


def generate(cfg, params, prompts, gen_len: int, seq_cap: int, decode=None):
    """Greedy decode: prefill via full forward, then token-by-token."""

    b, plen = prompts.shape
    decode = decode if decode is not None else jax.jit(Z.make_decode_fn(cfg))
    state = Z.init_decode_state(cfg, b, seq_cap)

    # Prefill by replaying the prompt through the decode step (simple and
    # exact; a fused prefill that bulk-writes the cache is the fast path —
    # both produce identical caches, asserted in tests).
    tok = prompts[:, :1]
    logits = None
    for t in range(plen):
        logits, state = decode(params, {"tokens": prompts[:, t : t + 1]}, state, jnp.int32(t))
    out = [prompts]
    for t in range(plen, plen + gen_len):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt))
        logits, state = decode(params, {"tokens": nxt}, state, jnp.int32(t))
    return np.concatenate(out, axis=1)


def mixed_decode_step(cfg, asym, mesh, batch_padded: int, seq_cap: int):
    """The decode fn wrapped so each pod decodes its request shard under
    its own class's control tree (true CA-SAS serving: one SPMD step, two
    per-class programs).  Decode is pure data parallelism over requests —
    no cross-pod collectives, so no epilogue."""

    state_spec = jax.eval_shape(
        lambda: Z.init_decode_state(cfg, batch_padded, seq_cap)
    )
    sspecs = SH.pod_state_specs(state_spec)
    bspecs = SH.pod_batch_specs({"tokens": 0})  # the decode batch tree
    return asym.class_sharded(
        Z.make_decode_fn(cfg),
        mesh=mesh,
        in_specs=(P(), bspecs, sspecs, P()),
        out_specs=(P("pod"), sspecs),
    )


def pad_requests(prompts: np.ndarray, layout):
    """Lay requests out pod-major per the chunk table; returns (padded,
    order) with ``padded[order] == prompts`` row-for-row."""

    c_max = layout.c_max
    padded = np.zeros((len(layout.sizes) * c_max,) + prompts.shape[1:], prompts.dtype)
    order, pos = [], 0
    for i, size in enumerate(layout.sizes):
        padded[i * c_max : i * c_max + size] = prompts[pos : pos + size]
        order.extend(range(i * c_max, i * c_max + size))
        pos += size
    return padded, np.asarray(order, np.int64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--strategy", default="ca-das")
    ap.add_argument("--device-class", default=None,
                    help="serve under this class's control tree (default: fastest)")
    ap.add_argument("--class-sharded", default="auto", choices=["auto", "on", "off"],
                    help="decode each pod's request shard under its own class's "
                         "tree in one SPMD step; auto = on when the host has a "
                         "device per pod")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    SH.use_mesh_for_activations(None)

    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.embed_inputs or cfg.family == "encdec":
        raise SystemExit(f"{cfg.name}: serving demo targets token-in archs")

    # Asymmetric request routing: split the request batch across classes.
    asym = AsymmetricMesh(biglittle_classes(chips_per_pod=1), strategy=args.strategy,
                          batch_tile=1)
    if args.class_sharded == "on" and args.device_class is not None:
        raise SystemExit(
            "--class-sharded on serves every class simultaneously; "
            "it cannot be combined with --device-class"
        )
    mixed = (
        args.class_sharded != "off"
        and args.device_class is None  # explicit class selection wins
        and len(asym.classes) > 1
        and jax.device_count() >= asym.n_pods
    )
    if args.class_sharded == "on" and not mixed:
        raise SystemExit(
            f"--class-sharded on needs {asym.n_pods} devices, "
            f"have {jax.device_count()}"
        )
    layout = asym.batch_layout(args.batch)
    print("request split across classes:", layout.sizes)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32)
    seq_cap = args.prompt_len + args.gen_len

    t0 = time.time()
    if mixed:
        # One SPMD decode step, one program per class: pod i's shard runs
        # under class(i)'s control tree (paper §5.3, serving side).
        mesh = make_host_mesh(pod=asym.n_pods)
        padded, order = pad_requests(prompts, layout)
        step = mixed_decode_step(cfg, asym, mesh, padded.shape[0], seq_cap)
        out_padded = generate(cfg, params, jnp.asarray(padded), args.gen_len,
                              seq_cap, decode=jax.jit(step))
        out = out_padded[order]
        shard_classes = [(p.pod, p.device_class, p.block_source, p.backend)
                         for p in step.provenance]
        # A mixed step may run a different micro-kernel variant per class
        # (big -> pallas, little -> pallas_lean): report every variant.
        device_class = "mixed"
        exec_backend = "+".join(
            sorted({p.backend for p in step.provenance})
        )
    else:
        # Every decode matmul runs under the serving class's control tree —
        # the context is active while the decode fn traces (first call).
        exec_ctx = asym.execution_context(args.device_class)
        with exec_ctx:
            out = generate(cfg, params, jnp.asarray(prompts), args.gen_len, seq_cap)
        shard_classes = None
        device_class, exec_backend = exec_ctx.device_class, exec_ctx.backend()
    dt = time.time() - t0
    tput = args.batch * args.gen_len / dt
    print(json.dumps({
        "arch": cfg.name,
        "device_class": device_class,
        "exec_backend": exec_backend,
        "class_sharded": mixed,
        "shard_classes": shard_classes,
        "batch": args.batch,
        "generated": out.shape[1] - args.prompt_len,
        "wall_s": round(dt, 2),
        "tokens_per_s": round(tput, 1),
        "sample": out[0, -8:].tolist(),
    }))


if __name__ == "__main__":
    main()

"""Serving driver: a thin CLI over the persistent slot-table engine.

The default path is :class:`repro.runtime.serving.ServingEngine` — the
fixed pod-major slot table with per-class request queues, fused bulk
prefill, donated decode state, and zero per-step host relayout (the
paper's keep-your-assignment scheduling, §5.4, applied to serving).  The
legacy **one-shot** path (``--one-shot``) keeps the pre-engine behavior —
re-pad per the chunk table once per generate call, per-token jit
dispatches — as the comparison baseline; its tokens are bit-identical to
the engine's (tested), so the JSON speed numbers are apples-to-apples.

Example (CPU, reduced config)::

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 8 --prompt-len 16 --gen-len 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as Z


def generate(cfg, params, prompts, gen_len: int, seq_cap: int, decode=None,
             prefill=None, donate: bool = True):
    """Greedy decode: fused bulk prefill, then token-by-token.

    Prefill is the fused bulk path (`model_zoo.make_prefill_fn(cfg,
    with_cache=True)`): one jitted forward over the whole prompt writes
    the cache in one shot, bit-identical to the token-by-token replay it
    replaced (tested).  The decode state is donated through both jits so
    the cache updates in place instead of being copied every token.

    Returns ``(tokens, timings)`` where ``timings`` splits jit compile
    time from steady-state decode: ``compile_s`` (first prefill + first
    decode call), ``decode_s``/``decode_steps`` (remaining steps), so
    callers can report steady-state tokens/s instead of folding XLA
    compilation into the throughput number.
    """

    b, plen = prompts.shape
    donate_state = (2,) if donate else ()
    if decode is None:
        decode = jax.jit(Z.make_decode_fn(cfg), donate_argnums=donate_state)
    if prefill is None:
        prefill = jax.jit(
            Z.make_prefill_fn(cfg, with_cache=True), donate_argnums=donate_state
        )
    state = Z.init_decode_state(cfg, b, seq_cap)

    t0 = time.perf_counter()
    logits, state = prefill(params, {"tokens": prompts}, state, jnp.int32(0))
    jax.block_until_ready(logits)
    timings = {"compile_s": time.perf_counter() - t0,
               "decode_s": 0.0, "decode_steps": 0}
    out = [np.asarray(prompts)]
    for t in range(plen, plen + gen_len):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt))
        t1 = time.perf_counter()
        logits, state = decode(params, {"tokens": nxt}, state, jnp.int32(t))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t1
        if t == plen:  # first decode call compiles
            timings["compile_s"] += dt
        else:
            timings["decode_s"] += dt
            timings["decode_steps"] += 1
    return np.concatenate(out, axis=1), timings


def mixed_decode_step(cfg, asym, mesh, batch_padded: int, seq_cap: int):
    """The decode fn wrapped so each pod decodes its request shard under
    its own class's control tree (true CA-SAS serving: one SPMD step, two
    per-class programs).  Decode is pure data parallelism over requests —
    no cross-pod collectives, so no epilogue."""

    state_spec = jax.eval_shape(
        lambda: Z.init_decode_state(cfg, batch_padded, seq_cap)
    )
    sspecs = SH.pod_state_specs(state_spec)
    bspecs = SH.pod_batch_specs({"tokens": 0})  # the decode batch tree
    return asym.class_sharded(
        Z.make_decode_fn(cfg),
        mesh=mesh,
        in_specs=(P(), bspecs, sspecs, P()),
        out_specs=(P("pod"), sspecs),
    )


def pad_requests(prompts: np.ndarray, layout):
    """Lay requests out pod-major per the chunk table; returns (padded,
    order) with ``padded[order] == prompts`` row-for-row.

    This is the **one-shot** path's host relayout.  The persistent engine
    never calls it after admission: requests keep their slot until they
    complete (asserted in tests/test_serving.py)."""

    c_max = layout.c_max
    padded = np.zeros((len(layout.sizes) * c_max,) + prompts.shape[1:], prompts.dtype)
    order, pos = [], 0
    for i, size in enumerate(layout.sizes):
        padded[i * c_max : i * c_max + size] = prompts[pos : pos + size]
        order.extend(range(i * c_max, i * c_max + size))
        pos += size
    return padded, np.asarray(order, np.int64)


def _one_shot(cfg, params, asym, prompts, args, seq_cap):
    """The legacy path: chunk-table relayout once per call, per-token jits."""

    mixed = (
        args.class_sharded != "off"
        and args.device_class is None  # explicit class selection wins
        and len(asym.classes) > 1
        and jax.device_count() >= asym.n_pods
    )
    if args.class_sharded == "on" and not mixed:
        raise SystemExit(
            f"--class-sharded on needs {asym.n_pods} devices, "
            f"have {jax.device_count()}"
        )
    layout = asym.batch_layout(args.batch)
    print("request split across classes:", layout.sizes)
    if mixed:
        # One SPMD decode step, one program per class: pod i's shard runs
        # under class(i)'s control tree (paper §5.3, serving side).
        mesh = make_host_mesh(pod=asym.n_pods)
        padded, order = pad_requests(prompts, layout)
        step = mixed_decode_step(cfg, asym, mesh, padded.shape[0], seq_cap)
        out_padded, timings = generate(
            cfg, params, jnp.asarray(padded), args.gen_len, seq_cap,
            decode=jax.jit(step, donate_argnums=(2,)),
            prefill=jax.jit(
                Z.bulk_prefill_from_decode(step), donate_argnums=(2,)
            ),
        )
        out = out_padded[order]
        shard_classes = [(p.pod, p.device_class, p.block_source, p.backend)
                         for p in step.provenance]
        # A mixed step may run a different micro-kernel variant per class
        # (big -> pallas, little -> pallas_lean): report every variant.
        device_class = "mixed"
        exec_backend = "+".join(sorted({p.backend for p in step.provenance}))
    else:
        # Every decode matmul runs under the serving class's control tree —
        # the context is active while the decode fn traces (first call).
        exec_ctx = asym.execution_context(args.device_class)
        with exec_ctx:
            out, timings = generate(
                cfg, params, jnp.asarray(prompts), args.gen_len, seq_cap
            )
        shard_classes = None
        device_class, exec_backend = exec_ctx.device_class, exec_ctx.backend()
    return out, timings, device_class, exec_backend, shard_classes, None


def truncate_at_eos(out: np.ndarray, prompt_len: int, eos_id: int):
    """EOS-aware stop for the one-shot path's dense output.

    The one-shot loop always decodes ``gen_len`` steps; with an EOS id the
    generated region of each row is cut after its first EOS (the EOS token
    itself is kept, the tail zeroed — matching the engine's per-row
    completions).  Returns ``(out, n_eos, n_budget)``.
    """

    out = out.copy()
    gen = out[:, prompt_len:]
    hit = gen == eos_id
    n_eos = 0
    for r in range(out.shape[0]):
        idx = np.nonzero(hit[r])[0]
        if len(idx):
            gen[r, idx[0] + 1:] = 0
            n_eos += 1
    return out, n_eos, out.shape[0] - n_eos


def _engine(cfg, params, asym, prompts, args, seq_cap):
    """The persistent slot-table engine path (the default)."""

    from repro.runtime.serving import ServingEngine

    layout = asym.batch_layout(args.batch)
    print("request split across classes:", layout.sizes)
    eng = ServingEngine(
        cfg, params, asym,
        seq_cap=seq_cap,
        slots_per_pod=args.slots_per_pod or layout.c_max,
        class_sharded=args.class_sharded,
        paged=args.paged,
        page_size=args.page_size,
        pool_pages=args.pool_pages,
        eos_id=args.eos_id,
    )
    out = eng.generate(prompts, args.gen_len)
    st = eng.stats
    # st.tokens counts active-slot tokens only — with fewer active slots
    # than requests (small slot table, multiple waves) batch×steps would
    # overstate the throughput.
    timings = {"compile_s": st.compile_s, "decode_s": st.decode_s,
               "decode_steps": st.decode_steps, "tokens": st.tokens}
    if eng.mixed:
        shard_classes = [(p.pod, p.device_class, p.block_source, p.backend)
                         for p in eng.provenance]
        device_class = "mixed"
        exec_backend = "+".join(sorted({p.backend for p in eng.provenance}))
    else:
        ctx = asym.execution_context()
        shard_classes = None
        device_class, exec_backend = ctx.device_class, ctx.backend()
    engine_stats = {"slots": [eng.n_pods, eng.c_max], **st.snapshot(),
                    "kv_pool": eng.kv_stats()}
    return out, timings, device_class, exec_backend, shard_classes, engine_stats


def _fleet(cfg, params, asym, prompts, args, seq_cap):
    """The multi-engine fleet path (``--fleet N``): N engines, one
    submit/stream front, DAS request scheduling over calibrated
    per-engine throughput (see runtime/fleet.py)."""

    from repro.runtime.fleet import Fleet
    from repro.runtime.serving import ServingEngine

    engines = []
    for _ in range(args.fleet):
        a = AsymmetricMesh(
            biglittle_classes(chips_per_pod=1), strategy=args.strategy,
            batch_tile=1, objective=args.objective,
        )
        layout = a.batch_layout(max(1, args.batch // args.fleet))
        engines.append(ServingEngine(
            cfg, params, a,
            seq_cap=seq_cap,
            slots_per_pod=args.slots_per_pod or layout.c_max,
            class_sharded=args.class_sharded,
            paged=args.paged,
            page_size=args.page_size,
            pool_pages=args.pool_pages,
            eos_id=args.eos_id,
        ))
    fleet = Fleet(engines, objective=args.objective)
    print("fleet rel_throughput:", [round(r, 3) for r in fleet.rel_throughput])
    out = fleet.generate(prompts, args.gen_len)
    # Engines tick in lockstep and would run concurrently in production,
    # so the fleet's modeled span is the max over engines, and compile is
    # paid once per engine in parallel.
    timings = {
        "compile_s": max(e.stats.compile_s for e in engines),
        "decode_s": max(e.stats.decode_s for e in engines),
        "decode_steps": max(e.stats.decode_steps for e in engines),
        "tokens": sum(e.stats.tokens for e in engines),
    }
    ctx = engines[0].asym.execution_context()
    device_class = "mixed" if engines[0].mixed else ctx.device_class
    exec_backend = (
        "+".join(sorted({p.backend for p in engines[0].provenance}))
        if engines[0].mixed
        else ctx.backend()
    )
    engine_stats = {
        "fleet": fleet.stats.snapshot(),
        "health": fleet.health(),
        "engines": [e.stats.snapshot() for e in engines],
        # the stop-count surface _engine provides, fleet-wide
        "completed_eos": sum(e.stats.completed_eos for e in engines),
        "completed_budget": sum(e.stats.completed_budget for e in engines),
    }
    return out, timings, device_class, exec_backend, None, engine_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--strategy", default="ca-das")
    ap.add_argument("--objective", default="perf", choices=["perf", "energy", "edp"],
                    help="scheduling objective: perf (default, bit-identical "
                         "to before), energy (park inefficient pods at low "
                         "load, weight shares by joules/unit), or edp")
    ap.add_argument("--device-class", default=None,
                    help="serve under this class's control tree (default: fastest)")
    ap.add_argument("--class-sharded", default="auto", choices=["auto", "on", "off"],
                    help="decode each pod's request shard under its own class's "
                         "tree in one SPMD step; auto = on when the host has a "
                         "device per pod")
    ap.add_argument("--one-shot", action="store_true",
                    help="legacy path: chunk-table relayout per call + "
                         "per-token jit dispatches (comparison baseline)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve through a fault-tolerant fleet of N engines "
                         "behind one scheduler (0 = single engine)")
    ap.add_argument("--slots-per-pod", type=int, default=None,
                    help="engine slot-region size (default: the layout's c_max)")
    ap.add_argument("--paged", default="off", choices=["auto", "on", "off"],
                    help="engine KV storage: paged page-pool instead of dense "
                         "per-slot lanes (memory proportional to live tokens; "
                         "bit-identical tokens)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page (default: derived from the "
                         "classes' tuned block configs)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="physical KV pages per pod partition (default: "
                         "full-occupancy capacity — never defers)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a request at this token id (engine: the slot "
                         "retires and its pages free mid-stream; one-shot: "
                         "rows are truncated after their first EOS)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable observability and write the trace here "
                         "(native format; summarize / export Chrome trace "
                         "with python -m repro.observability.report)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable observability and write a metrics JSON "
                         "snapshot here")
    args = ap.parse_args()

    if args.trace or args.metrics:
        from repro import observability as OBS

        OBS.enable()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    SH.use_mesh_for_activations(None)

    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.embed_inputs or cfg.family == "encdec":
        raise SystemExit(f"{cfg.name}: serving demo targets token-in archs")

    # Asymmetric request routing: split the request batch across classes.
    asym = AsymmetricMesh(biglittle_classes(chips_per_pod=1), strategy=args.strategy,
                          batch_tile=1, objective=args.objective)
    if args.one_shot and args.objective != "perf":
        raise SystemExit("--objective applies to the engine path only")
    if args.class_sharded == "on" and args.device_class is not None:
        raise SystemExit(
            "--class-sharded on serves every class simultaneously; "
            "it cannot be combined with --device-class"
        )
    if not args.one_shot and args.device_class is not None:
        raise SystemExit("--device-class applies to the --one-shot path only")
    if args.one_shot and args.paged != "off":
        raise SystemExit("--paged applies to the engine path only")
    if args.fleet and args.one_shot:
        raise SystemExit("--fleet fronts engine instances; it cannot be "
                         "combined with --one-shot")
    if args.fleet < 0:
        raise SystemExit(f"--fleet must be >= 0, got {args.fleet}")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32)
    seq_cap = args.prompt_len + args.gen_len

    t0 = time.time()
    run = _one_shot if args.one_shot else (_fleet if args.fleet else _engine)
    out, timings, device_class, exec_backend, shard_classes, engine_stats = run(
        cfg, params, asym, prompts, args, seq_cap
    )
    dt = time.time() - t0
    stop_counts = None
    if args.eos_id is not None:
        if engine_stats is not None:
            stop_counts = {"eos": engine_stats["completed_eos"],
                           "budget": engine_stats["completed_budget"]}
        else:
            out, n_eos, n_budget = truncate_at_eos(out, args.prompt_len, args.eos_id)
            stop_counts = {"eos": n_eos, "budget": n_budget}
    # Steady-state throughput: warmup/compile excluded.  The one-shot path
    # used to fold jit compile time into tokens_per_s, which made every
    # comparison against it meaningless on the first run.  The engine
    # reports its actual active-slot token count; the one-shot path
    # decodes the full batch every step.
    tokens = timings.get("tokens", args.batch * timings["decode_steps"])
    steady = tokens / timings["decode_s"] if timings["decode_s"] > 0 else 0.0
    summary = {
        "arch": cfg.name,
        "path": ("one-shot" if args.one_shot
                 else f"fleet:{args.fleet}" if args.fleet else "engine"),
        "objective": args.objective,
        "device_class": device_class,
        "exec_backend": exec_backend,
        "class_sharded": shard_classes is not None,
        "shard_classes": shard_classes,
        "batch": args.batch,
        "generated": out.shape[1] - args.prompt_len,
        "wall_s": round(dt, 2),
        "compile_s": round(timings["compile_s"], 3),
        "tokens_per_s": round(steady, 1),
        "sample": out[0, -8:].tolist(),
    }
    if stop_counts is not None:
        summary["stop_counts"] = stop_counts
    if engine_stats is not None:
        summary["engine"] = engine_stats
    if args.trace or args.metrics:
        from repro import observability as OBS
        from repro.observability import trace as TR

        buf = TR.get_buffer()
        if args.trace:
            summary["trace"] = buf.save(args.trace)
        if args.metrics:
            from repro.util.atomic import atomic_write_json

            summary["metrics"] = atomic_write_json(
                args.metrics, OBS.REGISTRY.snapshot(), indent=1, sort_keys=True
            )
    print(json.dumps(summary))


if __name__ == "__main__":
    main()

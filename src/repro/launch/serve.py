"""Serving driver: batched prefill + decode with asymmetric request routing.

Demonstrates the inference side of the paper's scheduling: a heterogeneous
two-class serving fleet where the (CA-)SAS/DAS schedulers split each
request batch across device classes proportionally to their measured
decode throughput, exactly as the paper splits GEMM row-panels.

Example (CPU, reduced config)::

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 8 --prompt-len 16 --gen-len 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as Z


def generate(cfg, params, prompts, gen_len: int, seq_cap: int):
    """Greedy decode: prefill via full forward, then token-by-token."""

    b, plen = prompts.shape
    decode = jax.jit(Z.make_decode_fn(cfg))
    state = Z.init_decode_state(cfg, b, seq_cap)

    # Prefill by replaying the prompt through the decode step (simple and
    # exact; a fused prefill that bulk-writes the cache is the fast path —
    # both produce identical caches, asserted in tests).
    tok = prompts[:, :1]
    logits = None
    for t in range(plen):
        logits, state = decode(params, {"tokens": prompts[:, t : t + 1]}, state, jnp.int32(t))
    out = [prompts]
    for t in range(plen, plen + gen_len):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt))
        logits, state = decode(params, {"tokens": nxt}, state, jnp.int32(t))
    return np.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--strategy", default="ca-das")
    ap.add_argument("--device-class", default=None,
                    help="serve under this class's control tree (default: fastest)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    SH.use_mesh_for_activations(None)

    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.embed_inputs or cfg.family == "encdec":
        raise SystemExit(f"{cfg.name}: serving demo targets token-in archs")

    # Asymmetric request routing: split the request batch across classes.
    asym = AsymmetricMesh(biglittle_classes(chips_per_pod=1), strategy=args.strategy,
                          batch_tile=1)
    table = asym.chunk_table(args.batch)
    print("request split across classes:", table.sizes())

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32)
    seq_cap = args.prompt_len + args.gen_len

    # Every decode matmul runs under the serving class's control tree —
    # the context is active while the decode fn traces (first call).
    exec_ctx = asym.execution_context(args.device_class)
    t0 = time.time()
    with exec_ctx:
        out = generate(cfg, params, jnp.asarray(prompts), args.gen_len, seq_cap)
    dt = time.time() - t0
    tput = args.batch * args.gen_len / dt
    print(json.dumps({
        "arch": cfg.name,
        "device_class": exec_ctx.device_class,
        "exec_backend": exec_ctx.backend(),
        "batch": args.batch,
        "generated": out.shape[1] - args.prompt_len,
        "wall_s": round(dt, 2),
        "tokens_per_s": round(tput, 1),
        "sample": out[0, -8:].tolist(),
    }))


if __name__ == "__main__":
    main()

"""Roofline analysis over the dry-run artifacts.

Per (arch × shape × mesh) cell, derives the three roofline terms from the
trip-count-corrected HLO cost (all per device, = per chip):

    compute term    = HLO_FLOPs / peak_FLOPs           [s]
    memory term     = HLO_bytes / HBM_bw               [s]
    collective term = collective_bytes / link_bw       [s]

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (1 effective link per chip assumed — topology factors ignored, noted).

Also reports MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
usefulness ratio MODEL_FLOPS / (HLO_FLOPs × chips) — remat and dispatch
overheads push it below 1.
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Optional

from repro.configs import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    tag: str
    chips: int
    compute_s: float
    memory_s: float          # as lowered (jnp chunked attention: scores hit HBM)
    memory_flash_s: float    # with the Pallas flash kernel (scores stay in VMEM)
    collective_s: float
    bottleneck: str          # classified on the flash path (the TPU hot path)
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    roofline_fraction: float  # compute_s / max(all terms) — 1.0 == compute-bound at peak
    memory_gib: Optional[float]

    def step_time_s(self) -> float:
        """Lower-bound step time: terms assumed perfectly overlapped."""

        return max(self.compute_s, self.memory_s, self.collective_s)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = next(s for s in cfg.shapes(include_skipped=True) if s.name == shape_name)
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_record(rec: dict) -> Optional[RooflineRow]:
    if not rec.get("ok"):
        return None
    chips = rec["n_chips"]
    hlo = rec["hlo_cost"]
    flops_dev = hlo["flops"]
    bytes_dev = hlo["bytes"]
    score_dev = hlo.get("attn_score_bytes", 0.0)
    coll_dev = hlo["collective_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    memory_flash_s = max(bytes_dev - score_dev, 0.0) / HBM_BW
    coll_s = coll_dev / LINK_BW
    terms = {"compute": compute_s, "memory": memory_flash_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops_dev * chips
    mem = rec.get("memory", {}).get("total_bytes")
    return RooflineRow(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        tag=rec.get("tag", ""),
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        memory_flash_s=memory_flash_s,
        collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
        roofline_fraction=compute_s / max(max(terms.values()), 1e-30),
        memory_gib=mem / 2**30 if mem else None,
    )


def load_rows(art_dir: str = "artifacts/dryrun", mesh: Optional[str] = "pod16x16",
              tag: str = "") -> list[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("tag", "") != tag:
            continue
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def format_table(rows: list[RooflineRow]) -> str:
    hdr = (
        f"{'arch':18s} {'shape':12s} {'chips':>5s} {'compute_s':>10s} {'mem_s':>10s} "
        f"{'mem_flash':>10s} {'collect_s':>10s} {'bound':>9s} {'MF/HLO':>7s} "
        f"{'roofl%':>7s} {'GiB/dev':>8s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:18s} {r.shape:12s} {r.chips:5d} {r.compute_s:10.3e} "
            f"{r.memory_s:10.3e} {r.memory_flash_s:10.3e} {r.collective_s:10.3e} "
            f"{r.bottleneck:>9s} {r.useful_ratio:7.2f} {100*r.roofline_fraction:6.1f}% "
            f"{r.memory_gib if r.memory_gib is not None else float('nan'):8.2f}"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = load_rows(args.dir, args.mesh, args.tag)
    print(format_table(rows))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(
                "arch,shape,mesh,chips,compute_s,memory_s,memory_flash_s,"
                "collective_s,bottleneck,model_flops,hlo_flops_global,"
                "useful_ratio,roofline_fraction,memory_gib\n"
            )
            for r in rows:
                f.write(
                    f"{r.arch},{r.shape},{r.mesh},{r.chips},{r.compute_s},"
                    f"{r.memory_s},{r.memory_flash_s},{r.collective_s},"
                    f"{r.bottleneck},{r.model_flops},{r.hlo_flops_global},"
                    f"{r.useful_ratio},{r.roofline_fraction},{r.memory_gib}\n"
                )


if __name__ == "__main__":
    main()

"""End-to-end training driver.

Example (CPU, reduced config)::

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --global-batch 8 --seq 64 --strategy ca-das

On a real fleet the same entry point runs the full config against the
production mesh (``--mesh 16x16`` / ``--mesh 2x16x16``).
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_config
from repro.core import execution
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--strategy", default="ca-das",
                    choices=["sss", "sas", "ca-sas", "das", "ca-das", "none"])
    ap.add_argument("--heterogeneous", action="store_true",
                    help="simulate a big+little two-pod fleet for the scheduler")
    ap.add_argument("--mesh", default="host", choices=["host", "16x16", "2x16x16"])
    ap.add_argument("--class-sharded", default="auto", choices=["auto", "on", "off"],
                    help="per-class programs in one SPMD step (shard_map over "
                         "the pod axis); auto = on when the mesh has >1 class "
                         "and enough devices for a pod axis")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    asym = None
    if args.strategy != "none":
        classes = (
            biglittle_classes(chips_per_pod=1)
            if args.heterogeneous
            else [DeviceClass("pod0", chips_per_pod=1), DeviceClass("pod1", chips_per_pod=1)]
        )
        asym = AsymmetricMesh(classes, strategy=args.strategy, batch_tile=2)

    if args.mesh == "host":
        # The class-sharded step needs a pod axis: carve one out of the
        # host devices when the run wants it and the host has enough.
        want_pods = (
            args.class_sharded != "off"
            and asym is not None
            and len(asym.classes) > 1
            and jax.device_count() >= asym.n_pods
        )
        mesh = make_host_mesh(pod=asym.n_pods if want_pods else 0)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "2x16x16")
    SH.use_mesh_for_activations(mesh, seq_shard=False)

    # Class-routed execution: the asymmetric mesh's primary control tree
    # governs every matmul in the step; homogeneous runs get the default
    # single-class context (behavior-neutral without a tuning cache).
    exec_ctx = (
        asym.execution_context() if asym is not None else execution.default_context()
    )

    tcfg = TrainerConfig(
        steps=args.steps,
        global_batch=args.global_batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        n_micro=args.n_micro,
        class_sharded={"auto": None, "on": True, "off": False}[args.class_sharded],
    )
    trainer = Trainer(
        cfg,
        mesh,
        tcfg=tcfg,
        opt_cfg=AdamWConfig(lr=args.lr, total_steps=args.steps),
        asym=asym,
        exec_ctx=exec_ctx,
    )
    t0 = time.time()
    history = trainer.run()
    dt = time.time() - t0
    shard_classes = (
        [(p.pod, p.device_class, p.block_source, p.backend)
         for p in trainer.class_sharded_step.provenance]
        if trainer.class_sharded_step is not None
        else None
    )
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "device_class": exec_ctx.device_class,
                "exec_backend": exec_ctx.backend(),
                "class_sharded": trainer.class_sharded_enabled(),
                "shard_classes": shard_classes,
                "steps": len(history),
                "first_loss": history[0]["loss"],
                "last_loss": history[-1]["loss"],
                "restarts": trainer.restarts,
                "wall_s": round(dt, 2),
                "chunk_sizes": trainer.asym.batch_layout(args.global_batch).sizes
                if trainer.asym
                else None,
            },
            indent=1,
        )
    )


if __name__ == "__main__":
    main()

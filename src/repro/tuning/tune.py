"""Autotune CLI: search block configs per shape and persist the cache.

Workflow (the paper's Section 3.3 search, driven to a cache file)::

    # search two shapes with the deterministic cost model and write the cache
    PYTHONPATH=src python -m repro.tuning.tune \
        --spec tpu-v5e --backend cost-model \
        --shapes 512x512x512,1024x1024x1024 --cache artifacts/tuning.json

    # second invocation: every shape is already cached -> logged hits, no search
    PYTHONPATH=src python -m repro.tuning.tune \
        --spec tpu-v5e --backend cost-model \
        --shapes 512x512x512,1024x1024x1024 --cache artifacts/tuning.json

    # consume from the kernel path
    REPRO_TUNING_CACHE=artifacts/tuning.json python train.py ...

``--backend wallclock`` times the real Pallas kernel instead (compiled on
TPU, interpret on CPU — slow, hardware-representative).  Wallclock search
runs the paper's two-stage protocol by default (``--two-stage auto``): the
roofline cost model prunes the grid to ``--coarse-keep`` promising
candidates, only those are wallclock-timed, and the timed winner's
neighborhood is refined (Figure 4's coarse sweep -> refine).  ``--dry-run``
searches a tiny default shape set and writes nothing (the CI smoke step).
``--calibrate-ratios`` additionally runs the Section 5.2.2 per-class
calibration over the big.LITTLE device classes and records the resulting
``init_ratios`` in the cache metadata block.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import sys
import time
from typing import Optional, Sequence

from repro.core.blocking import BlockConfig, TpuCoreSpec
from repro.observability import metrics as MET
from repro.observability import trace as T
from repro.tuning import cache as C
from repro.tuning import candidates as CAND
from repro.tuning import measure as M

log = logging.getLogger("repro.tuning.tune")

_M = None


def _obs_metrics():
    global _M
    if _M is None:
        _M = {
            "cache": MET.counter(
                "tuning_cache_lookups_total", "Tuning-cache lookups by outcome",
                labels=("result",)),
            "candidate_seconds": MET.histogram(
                "tuning_candidate_seconds",
                "Per-candidate score from the timing backend (seconds)"),
        }
    return _M

DTYPES = {"bf16": ("bfloat16", 2), "f32": ("float32", 4)}
DRY_RUN_SHAPES = [(256, 256, 256), (512, 512, 512)]


@dataclasses.dataclass
class SearchResult:
    """Outcome of tuning one shape (or of a cache hit skipping the search)."""

    shape: tuple[int, int, int]
    best: BlockConfig
    best_time_s: float
    analytical: BlockConfig
    analytical_time_s: float
    n_candidates: int          # candidates actually scored by `backend`
    cache_hit: bool = False
    n_pruned: int = 0          # candidates dropped by the cost-model prefilter
    # Micro-kernel variant the winner runs on (a BACKENDS key): the §5.3
    # search dimension — "pallas" (pipelined) or "pallas_lean".
    best_backend: str = "pallas"

    @property
    def speedup(self) -> float:
        return self.analytical_time_s / self.best_time_s if self.best_time_s else 1.0


def search_shape(
    m: int,
    k: int,
    n: int,
    *,
    spec: TpuCoreSpec,
    dtype_bytes: int,
    backend,
    max_candidates: Optional[int] = None,
    prefilter=None,
    coarse_keep: int = 8,
    kernel_backends: Sequence[str] = ("pallas",),
) -> SearchResult:
    """Score candidates; the analytical config is always candidate #0,
    so the winner's time is <= the analytical default's by construction.

    ``prefilter`` enables the paper's two-stage Figure-4 sweep: a cheap
    ``(m, k, n, cfg) -> seconds`` scorer (the roofline cost model) ranks
    the full grid first, only the ``coarse_keep`` most promising
    candidates (plus the analytical seed) are timed with ``backend``, and
    the timed winner's one-step neighborhood is then refined with
    ``backend`` as well.  This is what makes wallclock search affordable:
    the expensive timer runs on tens of candidates, not hundreds.

    ``kernel_backends`` enumerates micro-kernel variants as a search
    dimension (each config feasibility-checked under *its* variant's VMEM
    model).  With the default single ``("pallas",)`` the scorer is called
    ``backend(m, k, n, cfg)`` exactly as before; with variants enabled it
    must also accept ``kernel_backend=`` (``measure.make_backend`` scorers
    do).
    """

    kernel_backends = tuple(kernel_backends)
    multi = kernel_backends != ("pallas",)
    if multi:
        cands = CAND.enumerate_kernel_candidates(
            m, k, n, spec=spec, dtype_bytes=dtype_bytes, backends=kernel_backends
        )
    else:
        cands = [
            CAND.KernelCandidate(cfg)
            for cfg in CAND.enumerate_candidates(
                m, k, n, spec=spec, dtype_bytes=dtype_bytes
            )
        ]
    if max_candidates is not None and len(cands) > max_candidates:
        # Keep the analytical seed, truncate the tail of the coarse grid.
        cands = cands[:max_candidates]
    analytical = cands[0]

    def _score(fn, cand: CAND.KernelCandidate) -> float:
        t0 = time.perf_counter()
        if multi:
            t = fn(m, k, n, cand.cfg, kernel_backend=cand.backend)
        else:
            t = fn(m, k, n, cand.cfg)
        # Telemetry covers the real scorer only (not the cheap prefilter):
        # one span per timed candidate, wall = what the search paid,
        # score_s = what the backend measured/estimated.
        if fn is backend and T.enabled():
            T.complete("tuning.candidate", t0, time.perf_counter() - t0,
                       cat="tuning",
                       block=[cand.cfg.bm, cand.cfg.bk, cand.cfg.bn],
                       kernel_backend=cand.backend, score_s=t)
            _obs_metrics()["candidate_seconds"].observe(t)
        return t

    n_pruned = 0
    if prefilter is not None and len(cands) > coarse_keep + 1:
        # Coarse stage: rank by the cheap model, keep the best region.
        ranked = sorted(cands[1:], key=lambda c: _score(prefilter, c))
        kept = [analytical] + ranked[:coarse_keep]
        n_pruned = len(cands) - len(kept)
        cands = kept

    best, best_t, ana_t = None, float("inf"), None
    timed: set[tuple[int, int, int, str]] = set()
    for cand in cands:
        t = _score(backend, cand)
        timed.add(cand.key)
        if cand == analytical:
            ana_t = t
        if t < best_t:
            best, best_t = cand, t
    assert best is not None and ana_t is not None

    if prefilter is not None and n_pruned:
        # Fine stage: refine around the coarse winner (paper Figure 4),
        # staying on the winner's kernel variant.  Skipped when the coarse
        # stage pruned nothing — the grid was already timed exhaustively.
        from repro.core.execution import backend_double_buffers

        for cfg in CAND.neighborhood(
            best.cfg, spec=spec,
            double_buffer=backend_double_buffers(best.backend),
        ):
            cand = CAND.KernelCandidate(cfg=cfg, backend=best.backend)
            if cand.key in timed:
                continue
            t = _score(backend, cand)
            timed.add(cand.key)
            if t < best_t:
                best, best_t = cand, t

    return SearchResult(
        shape=(m, k, n),
        best=best.cfg,
        best_time_s=best_t,
        analytical=analytical.cfg,
        analytical_time_s=ana_t,
        n_candidates=len(timed),
        n_pruned=n_pruned,
        best_backend=best.backend,
    )


def parse_shapes(text: str) -> list[tuple[int, int, int]]:
    """``"512x512x512,1024x1024x1024"`` → [(512,512,512), (1024,1024,1024)]."""

    out = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        dims = part.lower().split("x")
        if len(dims) != 3:
            raise ValueError(f"shape {part!r} is not MxKxN")
        out.append(tuple(int(d) for d in dims))
    if not out:
        raise ValueError("no shapes given")
    return out


def tune_shapes(
    shapes: Sequence[tuple[int, int, int]],
    *,
    spec: TpuCoreSpec,
    dtype: str = "bf16",
    backend_name: str = "cost-model",
    cache: Optional[C.TuningCache] = None,
    force: bool = False,
    max_candidates: Optional[int] = None,
    two_stage: Optional[bool] = None,
    coarse_keep: int = 8,
    kernel_backends: Sequence[str] = CAND.KERNEL_BACKENDS,
    objective: str = "perf",
) -> list[SearchResult]:
    """Library entry point: search ``shapes``, updating ``cache`` in place.

    ``two_stage=None`` (auto) enables the cost-model prefilter exactly when
    the scoring backend is wallclock — the cost model pruning itself would
    be circular.  Pass True/False to force either way.

    The micro-kernel variant is a search dimension by default
    (``kernel_backends``); the cache entry records the winner under
    ``"backend"`` and the scorer under ``"measured_with"``.

    ``objective`` selects what the search minimizes (seconds, joules, or
    energy-delay product — cost-model backend only); the cache entry
    records it, and a cached entry tuned under a *different* objective is
    re-scored rather than trusted (its winner optimized the wrong metric).
    """

    from repro.core.schedule import validate_objective

    validate_objective(objective)
    dtype_name, dtype_bytes = DTYPES[dtype]
    backend = M.make_backend(backend_name, spec=spec, objective=objective)
    if two_stage is None:
        two_stage = backend_name == "wallclock"
    prefilter = (
        (
            lambda m, k, n, cfg, kernel_backend="pallas": M.cost_model_time(
                m, k, n, cfg, spec=spec, kernel_backend=kernel_backend
            )
        )
        if two_stage
        else None
    )
    results = []
    for m, k, n in shapes:
        cached = cache.get(spec.name, dtype_name, m, k, n) if cache else None
        if cached is not None and not force:
            key = C.shape_bucket_key(spec.name, dtype_name, m, k, n)
            # Entries tuned under a different objective optimized the wrong
            # metric — their winner is not this search's winner.  Treat as a
            # miss (entries predating the objective field scored seconds).
            entry_obj = cache.entries.get(key, {}).get("objective", "perf")
            if entry_obj != objective:
                log.info(
                    "cache entry for %s tuned for objective %r, want %r — re-searching",
                    key, entry_obj, objective,
                )
                cached = None
        if cached is not None and not force:
            log.info("cache hit for %s — skipping search (use --force to redo)", key)
            if T.enabled():
                _obs_metrics()["cache"].labels(result="hit").inc()
            ana = CAND.analytical_config(m, k, n, spec=spec, dtype_bytes=dtype_bytes)
            # Report the times recorded at tuning, not fresh measurements —
            # re-timing a hit would defeat the point of the cache under the
            # wallclock backend (2 real kernel runs per already-tuned shape).
            entry = cache.entries.get(key, {})
            best_t = entry.get("time_s")
            ana_t = entry.get("analytical_time_s")
            recorded = entry.get("backend")
            from repro.kernels.gemm import GEMM_KERNELS

            # Guard against pre-variant caches (scorer names) AND against
            # dispatch entries the timers cannot model ("xla", interpret
            # twins): only a registered kernel variant is reported.
            best_backend = recorded if recorded in GEMM_KERNELS else "pallas"
            if best_t is None or ana_t is None:
                best_t = backend(m, k, n, cached, kernel_backend=best_backend)
                ana_t = backend(m, k, n, ana)
            results.append(
                SearchResult(
                    shape=(m, k, n),
                    best=cached,
                    best_time_s=float(best_t),
                    analytical=ana,
                    analytical_time_s=float(ana_t),
                    n_candidates=0,
                    cache_hit=True,
                    best_backend=best_backend,
                )
            )
            continue
        if T.enabled():
            _obs_metrics()["cache"].labels(result="miss").inc()
        t0 = time.perf_counter()
        with T.span("tuning.search_shape", cat="tuning",
                    shape=f"{m}x{k}x{n}", spec=spec.name,
                    backend=backend_name) as sp:
            res = search_shape(
                m, k, n,
                spec=spec,
                dtype_bytes=dtype_bytes,
                backend=backend,
                max_candidates=max_candidates,
                prefilter=prefilter,
                coarse_keep=coarse_keep,
                kernel_backends=kernel_backends,
            )
            sp.tag(n_candidates=res.n_candidates, n_pruned=res.n_pruned,
                   best=[res.best.bm, res.best.bk, res.best.bn],
                   best_backend=res.best_backend)
        log.info(
            "tuned %dx%dx%d: best=(%d,%d,%d)@%s %.3es vs analytical=(%d,%d,%d) "
            "%.3es (%.2fx, %d timed, %d pruned, %.1fs search)",
            m, k, n,
            res.best.bm, res.best.bk, res.best.bn, res.best_backend,
            res.best_time_s,
            res.analytical.bm, res.analytical.bk, res.analytical.bn,
            res.analytical_time_s, res.speedup, res.n_candidates, res.n_pruned,
            time.perf_counter() - t0,
        )
        if cache is not None:
            cache.put(
                spec.name, dtype_name, m, k, n, res.best,
                backend=res.best_backend,
                measured_with=backend_name,
                time_s=res.best_time_s,
                analytical_time_s=res.analytical_time_s,
                objective=objective,
            )
        results.append(res)
    return results


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tuning.tune",
        description="Architecture-aware GEMM block-config autotuner.",
    )
    ap.add_argument("--spec", default="tpu-v5e", choices=sorted(CAND.SPECS))
    ap.add_argument("--shapes", default=None, help="comma-separated MxKxN list")
    ap.add_argument("--dtype", default="bf16", choices=sorted(DTYPES))
    ap.add_argument("--backend", default="cost-model", choices=["cost-model", "wallclock"])
    ap.add_argument("--objective", default="perf", choices=["perf", "energy", "edp"],
                    help="what the search minimizes: seconds, modeled joules, "
                         "or energy-delay product (cost-model backend only)")
    ap.add_argument(
        "--kernel-backends", default=",".join(CAND.KERNEL_BACKENDS),
        help="comma-separated micro-kernel variants to search (e.g. "
             "'pallas,pallas_lean', or a single 'pallas_lean' to force the "
             "VMEM-lean kernel); the cache entry records the winner",
    )
    ap.add_argument("--cache", default=None, help="cache file (default: $REPRO_TUNING_CACHE or artifacts/tuning/cache.json)")
    ap.add_argument("--force", action="store_true", help="re-search cached shapes")
    ap.add_argument("--max-candidates", type=int, default=None)
    ap.add_argument("--two-stage", default="auto", choices=["auto", "on", "off"],
                    help="cost-model prefilter before timing (auto: on for wallclock)")
    ap.add_argument("--coarse-keep", type=int, default=8,
                    help="candidates surviving the coarse prefilter stage")
    ap.add_argument("--calibrate-ratios", action="store_true",
                    help="also calibrate big.LITTLE class ratios (Section 5.2.2)")
    ap.add_argument("--dry-run", action="store_true",
                    help="search a tiny default shape set, write nothing")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")

    import os

    spec = CAND.get_spec(args.spec)
    try:
        shapes = parse_shapes(args.shapes) if args.shapes else list(DRY_RUN_SHAPES)
    except ValueError as e:
        ap.error(str(e))
    cache_path = args.cache or os.environ.get(C.ENV_VAR) or os.path.join(
        "artifacts", "tuning", "cache.json"
    )
    cache = C.TuningCache.load(cache_path)

    kernel_backends = [b.strip() for b in args.kernel_backends.split(",") if b.strip()]
    if not kernel_backends:
        ap.error("--kernel-backends needs at least one variant")

    results = tune_shapes(
        shapes,
        spec=spec,
        dtype=args.dtype,
        backend_name=args.backend,
        cache=cache,
        force=args.force,
        max_candidates=args.max_candidates,
        two_stage={"auto": None, "on": True, "off": False}[args.two_stage],
        coarse_keep=args.coarse_keep,
        kernel_backends=kernel_backends,
        objective=args.objective,
    )

    summary: dict = {
        "spec": spec.name,
        "backend": args.backend,
        "objective": args.objective,
        "dtype": args.dtype,
        "cache_path": None if args.dry_run else cache_path,
        "shapes": [
            {
                "shape": list(r.shape),
                "best": [r.best.bm, r.best.bk, r.best.bn],
                "best_backend": r.best_backend,
                "best_time_s": r.best_time_s,
                "analytical": [r.analytical.bm, r.analytical.bk, r.analytical.bn],
                "analytical_time_s": r.analytical_time_s,
                "speedup_vs_analytical": r.speedup,
                "cache_hit": r.cache_hit,
            }
            for r in results
        ],
    }

    if args.calibrate_ratios:
        from repro.core.asymmetric import biglittle_classes
        from repro.tuning.ratio import calibrate_class_ratios

        # Always the cost model here: wallclock cannot compare the two
        # heterogeneous core specs on one host (ratio.py raises) — per-pod
        # wallclock ratios come from measured step times via
        # repro.core.asymmetric.calibrate_ratios instead.
        cal = calibrate_class_ratios(biglittle_classes(), backend="cost-model")
        log.info("calibrated class ratios %s -> %s (knob=%.2f)",
                 cal.class_names, [round(x, 4) for x in cal.ratios], cal.knob())
        cache.entries.setdefault("__meta__", {})["init_ratios"] = {
            "classes": list(cal.class_names),
            "ratios": list(cal.ratios),
            "probe_shape": list(cal.probe_shape),
            "backend": cal.backend,
        }
        summary["init_ratios"] = list(cal.ratios)

    if args.dry_run:
        log.info("dry run: searched %d shapes, cache not written", len(results))
    else:
        cache.save(cache_path)
        log.info("wrote %d entries to %s", len(cache.entries), cache_path)

    return summary


if __name__ == "__main__":
    main(sys.argv[1:])

"""Candidate scoring: roofline cost model and wall-clock backends.

Two interchangeable backends score a ``BlockConfig`` for one GEMM shape:

  * ``cost-model`` — deterministic seconds estimate from the same roofline
    terms as :mod:`repro.launch.roofline` (compute vs HBM traffic, per the
    core spec's ``peak_flops`` / ``hbm_bw``), plus a per-grid-step launch
    overhead.  Pure Python, no JAX tracing — this is what tests and CI run,
    and what the ``--backend cost-model`` search uses.
  * ``wallclock`` — median wall time of the real Pallas kernel
    (:func:`repro.kernels.gemm.gemm_pallas`): ``interpret=True`` on CPU,
    compiled through Mosaic on TPU.  The paper's actual Section 3.3
    protocol; only meaningful on hardware.

The cost model deliberately charges what the analytical derivation cannot
see: padding waste on ragged shapes (a block bigger than the problem pays
for zeros) and grid-step overhead (too-small blocks launch thousands of
steps) — the two effects the paper's empirical search exists to capture.

Both backends take the micro-kernel variant (``kernel_backend``) as a
scoring dimension: the pipelined default overlaps the HBM streams with
the MXU (``max(compute, memory)``), while the VMEM-lean single-buffered
kernel serializes them (``compute + memory``) in exchange for fitting
larger panels — the §5.3 per-class trade the search now weighs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.blocking import TPU_V5E, BlockConfig, PowerModel, TpuCoreSpec, pad_to_blocks
from repro.core.execution import backend_double_buffers
from repro.core.schedule import validate_objective

# Fixed cost per grid step (DMA issue + pipeline bubble).  Order of
# magnitude from TPU kernel practice; the precise value only needs to rank
# "thousands of tiny blocks" below "tens of large blocks".
GRID_STEP_OVERHEAD_S = 1e-6

# The measurement-backend vocabulary (a *scorer* name, not a kernel
# backend — ``execution.BACKENDS`` is that other, disjoint vocabulary).
# ``repro.analysis``'s drift detector admits these tokens alongside the
# kernel registry so ``--backend cost-model`` CLI plumbing stays legal.
MEASURE_BACKEND_NAMES: tuple[str, ...] = ("cost-model", "wallclock")


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Roofline terms for one (shape, config, variant) cell."""

    cfg: BlockConfig
    compute_s: float
    memory_s: float
    overhead_s: float
    grid: tuple[int, int, int]
    # Micro-kernel variant the estimate models; decides stream overlap.
    kernel_backend: str = "pallas"
    # Work totals and the power model that prices them (energy objectives).
    flops: float = 0.0
    hbm_bytes: float = 0.0
    power: Optional[PowerModel] = None

    @property
    def time_s(self) -> float:
        """Step-time lower bound.

        The pipelined kernel double-buffers, so HBM traffic hides under
        the MXU (``max``); the lean kernel single-buffers, so each K step
        waits for its DMA before computing (``sum``).
        """

        if backend_double_buffers(self.kernel_backend):
            return max(self.compute_s, self.memory_s) + self.overhead_s
        return self.compute_s + self.memory_s + self.overhead_s

    @property
    def bottleneck(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"

    @property
    def energy_j(self) -> float:
        """Modeled joules: idle draw over the step plus activity terms."""

        if self.power is None:
            raise ValueError("CostBreakdown has no power model attached")
        return self.power.energy_j(self.time_s, self.flops, self.hbm_bytes)

    @property
    def edp(self) -> float:
        """Energy-delay product (J·s), the balanced objective."""

        return self.energy_j * self.time_s

    def score(self, objective: str = "perf") -> float:
        """The scalar the tuner minimizes under ``objective``."""

        validate_objective(objective)
        if objective == "perf":
            return self.time_s
        if objective == "energy":
            return self.energy_j
        return self.edp


def cost_breakdown(
    m: int,
    k: int,
    n: int,
    cfg: BlockConfig,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    kernel_backend: str = "pallas",
) -> CostBreakdown:
    """Deterministic roofline estimate of one blocked-GEMM invocation.

    Traffic model matches the Pallas grids of ``kernels/gemm.py``: per
    (i, j, kk) step an ``(bm, bk)`` A-block and ``(bk, bn)`` B-block are
    staged HBM->VMEM, so A is re-read once per j column and B once per i
    row; the fp32 accumulator lives in VMEM and C is written once.  (The
    lean kernel walks the same (i, j, kk) space — its inner fori_loop
    issues the same per-step DMAs, so the traffic and overhead terms are
    shared; only the overlap differs, see :class:`CostBreakdown`.)
    Compute covers the *padded* problem — padding waste is charged.
    """

    pm, pk, pn = pad_to_blocks(m, k, n, cfg)
    gm, gn, gk = pm // cfg.bm, pn // cfg.bn, pk // cfg.bk

    flops = 2.0 * pm * pk * pn
    a_bytes = gm * gn * gk * cfg.bm * cfg.bk * cfg.dtype_bytes
    b_bytes = gm * gn * gk * cfg.bk * cfg.bn * cfg.dtype_bytes
    c_bytes = pm * pn * cfg.dtype_bytes
    return CostBreakdown(
        cfg=cfg,
        compute_s=flops / spec.peak_flops,
        memory_s=(a_bytes + b_bytes + c_bytes) / spec.hbm_bw,
        overhead_s=gm * gn * gk * GRID_STEP_OVERHEAD_S,
        grid=(gm, gn, gk),
        kernel_backend=kernel_backend,
        flops=flops,
        hbm_bytes=float(a_bytes + b_bytes + c_bytes),
        power=spec.power,
    )


def cost_model_time(
    m: int,
    k: int,
    n: int,
    cfg: BlockConfig,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    kernel_backend: str = "pallas",
) -> float:
    """Scalar objective (seconds) of the cost-model backend."""

    return cost_breakdown(
        m, k, n, cfg, spec=spec, kernel_backend=kernel_backend
    ).time_s


def cost_model_score(
    m: int,
    k: int,
    n: int,
    cfg: BlockConfig,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    kernel_backend: str = "pallas",
    objective: str = "perf",
) -> float:
    """Scalar objective of the cost-model backend: seconds (``perf``),
    joules (``energy``), or J·s (``edp``) — see :meth:`CostBreakdown.score`."""

    return cost_breakdown(
        m, k, n, cfg, spec=spec, kernel_backend=kernel_backend
    ).score(objective)


def wallclock_time(
    m: int,
    k: int,
    n: int,
    cfg: BlockConfig,
    *,
    dtype=None,
    interpret: Optional[bool] = None,
    reps: int = 3,
    warmup: int = 1,
    kernel_backend: str = "pallas",
) -> float:
    """Median wall seconds of the real Pallas kernel on this host.

    ``interpret`` defaults to True off-TPU (the validation path) and False
    on TPU (the Mosaic-compiled perf path).  ``kernel_backend`` selects
    the micro-kernel variant being timed (``"pallas"``/``"pallas_lean"``).
    """

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.gemm import GEMM_KERNELS

    try:
        kernel = GEMM_KERNELS[kernel_backend]
    except KeyError:
        raise ValueError(
            f"wallclock cannot time kernel backend {kernel_backend!r}; "
            f"known: {sorted(GEMM_KERNELS)}"
        ) from None
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dtype = dtype or (jnp.bfloat16 if cfg.dtype_bytes == 2 else jnp.float32)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)

    def call():
        return jax.block_until_ready(kernel(a, b, cfg, interpret=interpret))

    for _ in range(warmup):
        call()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        call()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def make_backend(
    name: str,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    dtype=None,
    objective: str = "perf",
) -> Callable[..., float]:
    """Resolve a backend name to a ``(m, k, n, cfg) -> score`` scorer.

    Scorers also accept ``kernel_backend=`` (the micro-kernel variant
    being scored; default ``"pallas"``) — the search passes it when the
    variant dimension is enabled.  ``objective`` selects what the score
    measures (seconds / joules / J·s); only the cost model can price
    energy — a wall clock measures seconds, not watts — so ``wallclock``
    with a non-``perf`` objective raises.
    """

    validate_objective(objective)
    if name == "cost-model":
        return lambda m, k, n, cfg, kernel_backend="pallas": cost_model_score(
            m, k, n, cfg, spec=spec, kernel_backend=kernel_backend,
            objective=objective,
        )
    if name == "wallclock":
        if objective != "perf":
            raise ValueError(
                f"wallclock backend cannot score objective {objective!r}; "
                "the host clock measures seconds, not joules — use cost-model"
            )
        return lambda m, k, n, cfg, kernel_backend="pallas": wallclock_time(
            m, k, n, cfg, dtype=dtype, kernel_backend=kernel_backend
        )
    raise ValueError(f"unknown measure backend {name!r} (cost-model|wallclock)")


__all__ = [
    "GRID_STEP_OVERHEAD_S",
    "MEASURE_BACKEND_NAMES",
    "CostBreakdown",
    "cost_breakdown",
    "cost_model_time",
    "cost_model_score",
    "wallclock_time",
    "make_backend",
]

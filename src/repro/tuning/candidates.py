"""Candidate ``BlockConfig`` enumeration for the empirical search.

The paper searches the (m_c, k_c) plane in two stages — a coarse sweep and
a refinement around the winner (Section 3.3 / Figure 4).  The TPU analogue
enumerated here is the set of MXU/lane-aligned ``(bm, bk, bn)`` triples
whose double-buffered working set fits the per-core VMEM budget, clamped
to the (padded) problem so tiny problems do not claim blocks they cannot
fill.  The analytical optimum of :func:`derive_block_config` is always a
member — the search can therefore only match or beat it — and an explicit
neighborhood around it provides the paper's "refine near the model's
prediction" structure.

Candidates are objective-agnostic: the same feasible set is scored in
seconds, joules, or J·s depending on the tuner's ``--objective`` (see
``measure.cost_model_score``); each spec's :class:`~repro.core.blocking.
PowerModel` prices the energy objectives.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from repro.core.blocking import (
    TPU_LITTLE,
    TPU_V5E,
    BlockConfig,
    TpuCoreSpec,
    _round_up,
    derive_block_config,
)

# Named core specs addressable from the CLI / cache keys.  ``tpu-little``
# is the degraded class of ``repro.core.asymmetric.biglittle_classes`` —
# the same ``TPU_LITTLE`` object, so tuned entries and calibration agree
# on what the name means.
SPECS: dict[str, TpuCoreSpec] = {
    TPU_V5E.name: TPU_V5E,
    TPU_LITTLE.name: TPU_LITTLE,
}


def get_spec(name: str) -> TpuCoreSpec:
    try:
        return SPECS[name]
    except KeyError:
        raise KeyError(f"unknown core spec {name!r}; known: {sorted(SPECS)}") from None


def analytical_config(
    m: int,
    k: int,
    n: int,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    dtype_bytes: int = 2,
    double_buffer: bool = True,
) -> BlockConfig:
    """The model-derived default (the search's baseline and seed).

    ``double_buffer=False`` seeds the VMEM-lean kernel's search: the
    single-buffer working-set model admits larger panels.
    """

    return derive_block_config(
        m, k, n, spec=spec, dtype_bytes=dtype_bytes, double_buffer=double_buffer
    )


def _axis_values(problem_dim: int, cap: int, align: int) -> list[int]:
    """Aligned power-of-two ladder up to min(padded problem, cap)."""

    hi = min(_round_up(problem_dim, align), cap)
    vals = []
    v = align
    while v <= hi:
        vals.append(v)
        v *= 2
    if not vals or vals[-1] != hi:
        vals.append(hi)
    return vals


def neighborhood(
    cfg: BlockConfig, *, spec: TpuCoreSpec = TPU_V5E, double_buffer: bool = True
) -> list[BlockConfig]:
    """One-step refinements around ``cfg`` (the paper's fine sweep).

    Perturbs each dimension by ±1 alignment step and ±2x, keeping only
    feasible (aligned, VMEM-fitting under the given buffering model)
    results.
    """

    align = spec.mxu
    out = []
    for dim in ("bm", "bk", "bn"):
        base = getattr(cfg, dim)
        for nxt in (base - align, base + align, base // 2, base * 2):
            if nxt < align or nxt % align:
                continue
            cand = dataclasses.replace(cfg, **{dim: nxt})
            if cand.fits(spec, double_buffer=double_buffer):
                out.append(cand)
    return out


def enumerate_candidates(
    m: int,
    k: int,
    n: int,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    dtype_bytes: int = 2,
    max_bm: int = 1024,
    max_bk: int = 2048,
    max_bn: int = 1024,
    extra: Optional[Iterable[BlockConfig]] = None,
    double_buffer: bool = True,
) -> list[BlockConfig]:
    """The deduplicated feasible candidate set for one GEMM shape.

    Every returned config is MXU-aligned in all three dims and fits the
    VMEM budget under the requested buffering model (``cfg.fits(spec,
    double_buffer=...)``); the analytical optimum and its neighborhood are
    always included.  Deterministic order: analytical first, then
    ascending ``(bm, bk, bn)``.
    """

    align = spec.mxu
    seed = analytical_config(
        m, k, n, spec=spec, dtype_bytes=dtype_bytes, double_buffer=double_buffer
    )

    pool: list[BlockConfig] = [seed]
    pool += neighborhood(seed, spec=spec, double_buffer=double_buffer)
    for bm in _axis_values(m, max_bm, align):
        for bn in _axis_values(n, max_bn, align):
            for bk in _axis_values(k, max_bk, align):
                cand = BlockConfig(bm=bm, bk=bk, bn=bn, dtype_bytes=dtype_bytes)
                if cand.fits(spec, double_buffer=double_buffer):
                    pool.append(cand)
    if extra:
        pool += [c for c in extra if c.fits(spec, double_buffer=double_buffer)]

    seen: set[tuple[int, int, int]] = set()
    out: list[BlockConfig] = []
    for cand in [seed] + sorted(pool, key=lambda c: (c.bm, c.bk, c.bn)):
        key = (cand.bm, cand.bk, cand.bn)
        if key in seen:
            continue
        if cand.bm % align or cand.bk % align or cand.bn % align:
            continue
        seen.add(key)
        out.append(cand)
    return out


# ---------------------------------------------------------------------------
# Micro-kernel variants as a search dimension (paper §5.3)
# ---------------------------------------------------------------------------

# The kernel variants the search enumerates by default: every entry of
# the variant registry (the pipelined default plus the VMEM-lean
# k-streaming kernel).  Interpret twins and "xla" are execution modes /
# dispatch entries, not separate search points — neither the cost model
# nor the wallclock timer can model them as kernels.
def _kernel_backends() -> tuple[str, ...]:
    from repro.kernels.gemm import GEMM_KERNELS

    return tuple(GEMM_KERNELS)


KERNEL_BACKENDS: tuple[str, ...] = _kernel_backends()


@dataclasses.dataclass(frozen=True)
class KernelCandidate:
    """One search point: a block config *and* the kernel variant to run it.

    The lean variant's single-buffered working set admits (bm, bn) panels
    the pipelined kernel cannot hold — the variant dimension genuinely
    widens the feasible set, it is not a relabeling.
    """

    cfg: BlockConfig
    backend: str = "pallas"

    @property
    def key(self) -> tuple[int, int, int, str]:
        return (self.cfg.bm, self.cfg.bk, self.cfg.bn, self.backend)


def enumerate_kernel_candidates(
    m: int,
    k: int,
    n: int,
    *,
    spec: TpuCoreSpec = TPU_V5E,
    dtype_bytes: int = 2,
    backends: Iterable[str] = KERNEL_BACKENDS,
    **kwargs,
) -> list[KernelCandidate]:
    """The (config, variant) candidate set for one GEMM shape.

    Per variant, configs are enumerated under *that kernel's* VMEM model
    (double-buffered for ``"pallas"``, single-buffered for
    ``"pallas_lean"``); duplicates of (bm, bk, bn, backend) are dropped.
    Order: each variant's analytical seed first (default variant leading),
    then the merged grids.
    """

    from repro.core.execution import backend_double_buffers
    from repro.kernels.gemm import GEMM_KERNELS

    backends = list(backends)
    for b in backends:
        # Validate against the *kernel* registry, not the dispatch table:
        # "xla" and the interpret twins are not timeable search variants.
        if b not in GEMM_KERNELS:
            raise ValueError(
                f"unknown kernel backend {b!r}; searchable variants: "
                f"{sorted(GEMM_KERNELS)}"
            )
    out: list[KernelCandidate] = []
    seen: set[tuple[int, int, int, str]] = set()
    per_backend = [
        (
            b,
            enumerate_candidates(
                m, k, n,
                spec=spec,
                dtype_bytes=dtype_bytes,
                double_buffer=backend_double_buffers(b),
                **kwargs,
            ),
        )
        for b in backends
    ]
    # Seeds first (search_shape treats candidate #0 as the baseline).
    for b, cands in per_backend:
        cand = KernelCandidate(cfg=cands[0], backend=b)
        if cand.key not in seen:
            seen.add(cand.key)
            out.append(cand)
    for b, cands in per_backend:
        for cfg in cands[1:]:
            cand = KernelCandidate(cfg=cfg, backend=b)
            if cand.key not in seen:
                seen.add(cand.key)
                out.append(cand)
    return out


__all__ = [
    "KERNEL_BACKENDS",
    "SPECS",
    "KernelCandidate",
    "get_spec",
    "analytical_config",
    "neighborhood",
    "enumerate_candidates",
    "enumerate_kernel_candidates",
]

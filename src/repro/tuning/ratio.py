"""Per-class throughput-ratio calibration (paper Section 5.2.2).

The paper exposes the big:LITTLE work ratio as a knob, sweeps it (Figure
7), and picks the value where the clusters finish together.  Here the same
calibration is produced two ways:

  * :func:`calibrate_class_ratios` — *measure* each device class: score a
    probe GEMM on each class's core spec with a tuning backend (cost-model
    by default, wallclock on hardware) using that class's tuned or
    analytical block config, then normalize aggregate class throughput to
    the fastest.  This replaces the hand-typed ``rel_throughput`` numbers
    in :mod:`repro.core.asymmetric`.
  * :func:`sweep_ratio_knob` — reproduce the paper's explicit knob sweep
    on the calibrated big.LITTLE *simulator* (:mod:`repro.core.simulator`)
    and return the GFLOPS-optimal ratio, validating that the measured
    calibration lands where the sweep's optimum sits.

The result feeds ``AsymmetricMesh.from_calibration(...)`` and thereby the
``DynamicScheduler``'s ``init_ratios`` — a calibrated starting point that
the between-steps feedback then refines online.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core import simulator as SIM
from repro.core.blocking import BlockConfig
from repro.tuning.candidates import analytical_config
from repro.tuning.measure import cost_model_time, wallclock_time


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Calibrated per-class relative throughput (fastest class == 1.0)."""

    class_names: tuple[str, ...]
    ratios: tuple[float, ...]          # per-chip, normalized to max
    probe_shape: tuple[int, int, int]
    backend: str
    times_s: tuple[float, ...]         # per-class probe time (one chip)

    @property
    def init_ratios(self) -> list[float]:
        return list(self.ratios)

    def knob(self) -> float:
        """The paper's scalar big:LITTLE ratio (fast rate / slow rate)."""

        return max(self.ratios) / min(self.ratios)


@dataclasses.dataclass(frozen=True)
class ClassMeasurement:
    """One class's measured work: ``units`` completed in ``seconds``.

    The wallclock feed for :func:`calibrate_class_ratios`: on a real fleet
    these are per-pod step times (rows or tokens per step); on one host,
    ``benchmarks.bench_schedulers.measure_class_step_times`` produces them
    by timing the probe GEMM under each class's execution context.
    """

    name: str
    units: float
    seconds: float

    @property
    def rate(self) -> float:
        return self.units / self.seconds


def _ratios_from_measurements(
    classes: Sequence, measurements: Sequence[ClassMeasurement]
) -> tuple[list[float], list[float]]:
    """Per-chip ratios (and raw seconds) from measured per-pod step times."""

    by_name = {m.name: m for m in measurements}
    missing = [c.name for c in classes if c.name not in by_name]
    if missing:
        raise ValueError(f"measurements missing classes {missing}")
    # rel_throughput is per *chip*: divide the pod rate by its chip count
    # so a big pod does not look fast merely by being wide.
    rates = [
        by_name[c.name].rate / max(1, getattr(c, "chips_per_pod", 1)) for c in classes
    ]
    top = max(rates)
    return [r / top for r in rates], [by_name[c.name].seconds for c in classes]


def calibrate_class_ratios(
    classes: Sequence,
    *,
    probe_shape: tuple[int, int, int] = (1024, 1024, 1024),
    backend: str = "cost-model",
    dtype_bytes: int = 2,
    configs: Optional[Sequence[BlockConfig]] = None,
    measurements: Optional[Sequence[ClassMeasurement]] = None,
) -> Calibration:
    """Measure per-class throughput ratios on a probe GEMM.

    ``classes`` are :class:`repro.core.asymmetric.DeviceClass` instances
    (anything with ``.name`` and ``.spec``).  Each class is probed with its
    *own* block config — pass ``configs`` to use tuned entries, otherwise
    each class gets its analytical derivation (the "two control trees" of
    Section 5.3 applied to calibration itself).

    ``measurements`` short-circuits the probe entirely: pass per-class
    :class:`ClassMeasurement` records (real per-pod step times, or the
    host-local stand-ins from ``benchmarks.bench_schedulers``) and the
    ratios come straight from them — the only way ``backend="wallclock"``
    can calibrate *heterogeneous* core specs, since one host cannot time
    two different chips.
    """

    m, k, n = probe_shape
    if measurements is not None:
        ratios, secs = _ratios_from_measurements(classes, measurements)
        return Calibration(
            class_names=tuple(c.name for c in classes),
            ratios=tuple(ratios),
            probe_shape=probe_shape,
            backend=backend,
            times_s=tuple(secs),
        )
    if backend == "wallclock" and len({c.spec.name for c in classes}) > 1:
        # Wall-clock timing runs every probe on *this* host: it can only
        # distinguish block-config effects, not the classes' different
        # hardware, so heterogeneous specs would calibrate to ~1:1 and
        # overload the slow class.  Measure each class on its own pod and
        # feed the times back via ``measurements=`` (ClassMeasurement
        # records, e.g. from benchmarks.bench_schedulers), or use the
        # cost model.
        raise ValueError(
            "wallclock calibration cannot compare heterogeneous core specs "
            "on one host; use backend='cost-model' or pass per-pod measured "
            "step times via measurements=[ClassMeasurement(...), ...]"
        )
    times = []
    for i, cls in enumerate(classes):
        spec = cls.spec
        cfg = configs[i] if configs is not None else analytical_config(
            m, k, n, spec=spec, dtype_bytes=dtype_bytes
        )
        if backend == "cost-model":
            t = cost_model_time(m, k, n, cfg, spec=spec)
        elif backend == "wallclock":
            t = wallclock_time(m, k, n, cfg)
        else:
            raise ValueError(f"unknown calibration backend {backend!r}")
        times.append(t)
    rates = [1.0 / t for t in times]
    top = max(rates)
    return Calibration(
        class_names=tuple(c.name for c in classes),
        ratios=tuple(r / top for r in rates),
        probe_shape=probe_shape,
        backend=backend,
        times_s=tuple(times),
    )


def sweep_ratio_knob(
    r: int = 4096,
    ratios: Sequence[float] = (1, 2, 3, 4, 5, 6, 7),
    *,
    cache_aware: bool = True,
    clusters: Sequence[SIM.ClusterModel] = SIM.EXYNOS_5422,
) -> tuple[float, list[SIM.SimResult]]:
    """Paper Figure 7: sweep the static ratio knob, return the optimum.

    Runs the calibrated big.LITTLE simulator over candidate ratios and
    returns ``(best_ratio, all_results)`` where best maximizes GFLOPS.
    """

    results = [
        SIM.simulate_static(r, ratio=float(x), cache_aware=cache_aware, clusters=clusters)
        for x in ratios
    ]
    best = max(zip(ratios, results), key=lambda p: p[1].gflops)
    return float(best[0]), results


__all__ = [
    "Calibration",
    "ClassMeasurement",
    "calibrate_class_ratios",
    "sweep_ratio_knob",
]

"""Persistent on-disk tuning cache (the subsystem's memory).

A single JSON file maps ``(core-spec name, dtype, M/K/N shape bucket)`` to
the tuned ``BlockConfig`` plus provenance (backend, measured/estimated
seconds, the analytical baseline it beat).  Shape dims are bucketed by
rounding up to the 128-lane MXU tile, so problem sizes that pad
identically share an entry — the paper tunes per core class, not per
matrix.

Format (``CACHE_VERSION`` guards schema drift; a version mismatch
invalidates the whole file and the caller falls back to the analytical
derivation):

.. code-block:: json

    {
      "version": 1,
      "entries": {
        "tpu-v5e/bfloat16/512x512x512": {
          "bm": 512, "bk": 512, "bn": 512,
          "dtype_bytes": 2, "acc_bytes": 4,
          "backend": "pallas",
          "measured_with": "cost-model",
          "time_s": 1.4e-3, "analytical_time_s": 1.5e-3,
          "shape": [512, 512, 512]
        }
      }
    }

``"backend"`` records the winning micro-kernel *variant* (a key of
``repro.core.execution.BACKENDS`` — e.g. ``"pallas"`` or the VMEM-lean
``"pallas_lean"``); ``"measured_with"`` records the scorer that picked it
(``"cost-model"``/``"wallclock"``).  Caches written before the variant
search stored the scorer under ``"backend"`` — consumers treat any value
outside the dispatch table as "no variant recorded", so old caches keep
working with the default kernel.

``"objective"`` records what the search minimized (``"perf"`` seconds,
``"energy"`` modeled joules, ``"edp"`` joules·seconds); entries predating
the field are ``"perf"``.  ``time_s``/``analytical_time_s`` are in the
objective's units.  The tuner treats an entry tuned under a different
objective as a miss — its winner optimized the wrong metric.

Writes are atomic and durable (``repro.util.atomic``: tempfile + fsync +
``os.replace``) so a crashed tuner never leaves a torn cache for a
training job to read.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
from typing import Any, Optional

from repro.core.blocking import TPU_V5E, BlockConfig, TpuCoreSpec, derive_block_config
from repro.util.atomic import atomic_write_json

log = logging.getLogger(__name__)

CACHE_VERSION = 1
ENV_VAR = "REPRO_TUNING_CACHE"
ENV_SPEC_VAR = "REPRO_TUNING_SPEC"


def _bucket(dim: int) -> int:
    """Dim rounded up to the 128-lane MXU tile (min 128).

    Every feasible block is a multiple of 128, so all problem sizes in one
    bucket pad to the same dims — a tuned entry transfers exactly within
    its bucket.  (Coarser buckets, e.g. powers of two, would alias a small
    problem onto an entry whose blocks overshoot it and pay up to 8x
    padded FLOPs.)
    """

    return max(128, ((dim + 127) // 128) * 128)


def shape_bucket_key(spec_name: str, dtype_name: str, m: int, k: int, n: int) -> str:
    return f"{spec_name}/{dtype_name}/{_bucket(m)}x{_bucket(k)}x{_bucket(n)}"


@dataclasses.dataclass
class TuningCache:
    """In-memory view of one cache file; ``save()`` persists atomically."""

    path: Optional[str] = None
    entries: dict[str, dict[str, Any]] = dataclasses.field(default_factory=dict)

    # -- IO ----------------------------------------------------------------

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        """Read a cache file; missing/corrupt/version-mismatched → empty."""

        if not os.path.exists(path):
            return cls(path=path)
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            log.warning("tuning cache %s unreadable (%s); starting empty", path, e)
            return cls(path=path)
        if not isinstance(raw, dict):
            log.warning(
                "tuning cache %s is not a JSON object (got %s); starting empty",
                path, type(raw).__name__,
            )
            return cls(path=path)
        if raw.get("version") != CACHE_VERSION:
            log.warning(
                "tuning cache %s has version %r != %d; invalidating",
                path, raw.get("version"), CACHE_VERSION,
            )
            return cls(path=path)
        return cls(path=path, entries=dict(raw.get("entries", {})))

    def save(self, path: Optional[str] = None) -> str:
        """Atomic durable write (shared ``repro.util.atomic`` helper:
        tempfile in the target dir, fsync, then ``os.replace``)."""

        path = path or self.path
        if path is None:
            raise ValueError("TuningCache.save() needs a path")
        payload = {"version": CACHE_VERSION, "entries": self.entries}
        atomic_write_json(
            path, payload, indent=1, sort_keys=True, newline=False,
            prefix=".tuning-cache-",
        )
        self.path = path
        return path

    # -- entries -----------------------------------------------------------

    def put(
        self,
        spec_name: str,
        dtype_name: str,
        m: int,
        k: int,
        n: int,
        cfg: BlockConfig,
        **meta: Any,
    ) -> str:
        key = shape_bucket_key(spec_name, dtype_name, m, k, n)
        self.entries[key] = {
            "bm": cfg.bm,
            "bk": cfg.bk,
            "bn": cfg.bn,
            "dtype_bytes": cfg.dtype_bytes,
            "acc_bytes": cfg.acc_bytes,
            "shape": [m, k, n],
            **meta,
        }
        return key

    def get(
        self, spec_name: str, dtype_name: str, m: int, k: int, n: int
    ) -> Optional[BlockConfig]:
        key = shape_bucket_key(spec_name, dtype_name, m, k, n)
        e = self.entries.get(key)
        if e is None:
            return None
        try:
            return BlockConfig(
                bm=int(e["bm"]),
                bk=int(e["bk"]),
                bn=int(e["bn"]),
                dtype_bytes=int(e.get("dtype_bytes", 2)),
                acc_bytes=int(e.get("acc_bytes", 4)),
            )
        except (KeyError, TypeError, ValueError) as err:
            # A malformed entry (hand-edited, truncated) is a miss, not a
            # crash on the kernel hot path.
            log.warning("tuning cache entry %s malformed (%s); ignoring", key, err)
            return None

    def lookup_or_analytical(
        self,
        m: int,
        k: int,
        n: int,
        *,
        spec: TpuCoreSpec = TPU_V5E,
        dtype_name: str = "bfloat16",
        dtype_bytes: int = 2,
    ) -> tuple[BlockConfig, bool]:
        """Tuned config on hit, analytical derivation on miss."""

        cfg = self.get(spec.name, dtype_name, m, k, n)
        if cfg is not None:
            log.debug("tuning cache hit %s", shape_bucket_key(spec.name, dtype_name, m, k, n))
            return cfg, True
        return derive_block_config(m, k, n, spec=spec, dtype_bytes=dtype_bytes), False


# ---------------------------------------------------------------------------
# Hot-path lookup for kernels/gemm.py: env-var gated, mtime-memoized
# ---------------------------------------------------------------------------

_memo: dict[str, tuple[float, TuningCache]] = {}


def active_cache() -> Optional[TuningCache]:
    """The cache named by ``$REPRO_TUNING_CACHE``, or None when unset.

    Reloaded only when the file's mtime changes, so the per-call cost on
    the kernel path is one ``os.stat``.
    """

    path = os.environ.get(ENV_VAR)
    if not path:
        return None
    try:
        mtime = os.stat(path).st_mtime
    except OSError:
        return None
    hit = _memo.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    cache = TuningCache.load(path)
    _memo[path] = (mtime, cache)
    return cache


def cached_block_config(
    m: int,
    k: int,
    n: int,
    dtype_name: str,
    dtype_bytes: int,
    *,
    spec_name: Optional[str] = None,
) -> Optional[BlockConfig]:
    """Kernel-side lookup: tuned config or None (caller derives analytically).

    ``spec_name`` selects the per-class entry (control trees pass their
    class's core spec); when omitted, the spec the cache was tuned for is
    named by ``$REPRO_TUNING_SPEC`` (default ``tpu-v5e``).
    """

    cache = active_cache()
    if cache is None:
        return None
    if spec_name is None:
        spec_name = os.environ.get(ENV_SPEC_VAR, TPU_V5E.name)
    cfg = cache.get(spec_name, dtype_name, m, k, n)
    if cfg is not None and cfg.dtype_bytes != dtype_bytes:
        cfg = dataclasses.replace(cfg, dtype_bytes=dtype_bytes)
    return cfg


def cached_kernel_backend(
    m: int,
    k: int,
    n: int,
    dtype_name: str,
    *,
    spec_name: Optional[str] = None,
) -> Optional[str]:
    """The raw ``"backend"`` field of the active cache entry, or None.

    Returns the string as stored — callers validate it against
    ``execution.BACKENDS`` (pre-variant caches stored the measurement
    backend here; an unknown value means "no variant recorded").
    """

    cache = active_cache()
    if cache is None:
        return None
    if spec_name is None:
        spec_name = os.environ.get(ENV_SPEC_VAR, TPU_V5E.name)
    entry = cache.entries.get(shape_bucket_key(spec_name, dtype_name, m, k, n))
    if entry is None:
        return None
    backend = entry.get("backend")
    return backend if isinstance(backend, str) else None


__all__ = [
    "CACHE_VERSION",
    "ENV_VAR",
    "ENV_SPEC_VAR",
    "TuningCache",
    "shape_bucket_key",
    "active_cache",
    "cached_block_config",
    "cached_kernel_backend",
]

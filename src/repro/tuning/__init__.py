"""Architecture-aware autotuning (the paper's empirical configuration loop).

The paper's headline result (Sections 3.3, 5.1–5.2) is that the *measured*
per-core-class optima for the BLIS blocking parameters and the big:LITTLE
ratio knob beat the purely analytical derivation.  This package closes the
same loop for the TPU reproduction:

  candidates.py  — enumerate MXU-aligned ``BlockConfig`` candidates under
                   the VMEM budget (the search space of Figure 4), seeded
                   by and expanded around the analytical optimum of
                   :func:`repro.core.blocking.derive_block_config`.
  measure.py     — score candidates: a deterministic roofline cost model
                   (CI / tests) or real wall-clock timing of the Pallas
                   kernel (interpret on CPU, compiled on TPU).
  cache.py       — versioned on-disk JSON cache keyed by
                   ``(core-spec, dtype, shape bucket)`` with atomic writes;
                   lookup falls back to the analytical config on miss.
  ratio.py       — per-class throughput-ratio calibration (the Section
                   5.2.2 knob sweep) feeding ``AsymmetricMesh`` /
                   ``DynamicScheduler`` init ratios.
  tune.py        — the CLI: ``python -m repro.tuning.tune --spec tpu-v5e
                   --backend cost-model --shapes 512x512x512`` searches and
                   persists the cache consumed by ``kernels/gemm.py``.

Consumption is opt-in: set ``REPRO_TUNING_CACHE=/path/to/cache.json`` and
``gemm_pallas(a, b)`` (with ``cfg=None``) picks the tuned block shapes;
unset, the analytical derivation is used exactly as before.
"""

from repro.tuning.cache import TuningCache, shape_bucket_key
from repro.tuning.candidates import SPECS, analytical_config, enumerate_candidates
from repro.tuning.measure import cost_model_time, make_backend
from repro.tuning.ratio import Calibration, calibrate_class_ratios, sweep_ratio_knob

__all__ = [
    "TuningCache",
    "shape_bucket_key",
    "SPECS",
    "analytical_config",
    "enumerate_candidates",
    "cost_model_time",
    "make_backend",
    "Calibration",
    "calibrate_class_ratios",
    "sweep_ratio_knob",
]

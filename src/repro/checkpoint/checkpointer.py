"""Sharded checkpointing: npz payloads + JSON manifest, async save,
elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json        # tree paths, shapes, dtypes, step, timestamp
        shard_p0000.npz      # this host's param/opt shards (flat key -> array)
        COMMITTED            # written last; restore ignores uncommitted dirs

Design points for the 1000-node target:

  * every host writes only the addressable shards it owns
    (``jax.experimental.multihost_utils`` patterns); on this single-host
    container that degenerates to one file,
  * saves run on a background thread (compute is not blocked by I/O);
    ``wait()`` joins before the next save or shutdown,
  * atomic commit marker → a failure mid-save never corrupts the latest
    checkpoint; restore picks the newest committed step,
  * **elastic restore**: arrays are loaded as host numpy and re-placed with
    whatever shardings the *new* mesh prescribes — pod counts may change
    between runs (scale up/down) without converting checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.util.atomic import atomic_write_json, atomic_write_text, fsync_dir


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(tree_like, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    tdef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(tdef, leaves)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        """Snapshot to host memory synchronously, write asynchronously."""

        flat = _flatten(tree)  # device->host copy happens here, on purpose
        manifest = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        }
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, manifest), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, manifest)

    def _write(self, step: int, flat, manifest):
        d = os.path.join(self.dir, f"step_{step:08d}")
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        pid = getattr(jax, "process_index", lambda: 0)()
        shard = os.path.join(tmp, f"shard_p{pid:04d}.npz")
        np.savez(shard, **flat)
        # fsync-before-rename audit: the shard, the manifest, and the
        # commit marker must all be on disk before the rename publishes
        # the step dir — otherwise a crash right after the rename can
        # expose a committed-looking checkpoint with torn payloads.
        with open(shard, "rb") as f:
            os.fsync(f.fileno())
        atomic_write_json(os.path.join(tmp, "manifest.json"), manifest,
                          indent=None, sort_keys=False, newline=False)
        atomic_write_text(os.path.join(tmp, "COMMITTED"), "ok")
        fsync_dir(tmp)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        fsync_dir(self.dir)
        self._gc()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------------

    def committed_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            suffix = name[len("step_"):] if name.startswith("step_") else ""
            # `.tmp` staging dirs (interrupted saves) already hold COMMITTED
            # before the rename — only fully renamed step dirs count.
            if not suffix.isdigit():
                continue
            if os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(suffix))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, *, step: Optional[int] = None, shardings=None):
        """Load into the structure of ``tree_like``; re-place on devices.

        ``shardings``: matching pytree of NamedSharding for elastic
        re-placement onto a (possibly different) mesh; None → host arrays.
        """

        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        flat: dict[str, np.ndarray] = {}
        for name in sorted(os.listdir(d)):
            if name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    flat.update({k: z[k] for k in z.files})
        tree = _unflatten(tree_like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return tree, manifest


__all__ = ["Checkpointer"]

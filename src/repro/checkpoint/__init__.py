"""checkpoint substrate."""

"""Benchmarks: one module per paper figure + GEMM wall-clock + roofline."""

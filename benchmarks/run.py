"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (see bench_paper_figures) plus the real
GEMM wall-clock tier and scheduler overheads.  Prints ``name,us_per_call,
derived`` CSV; per-figure data lands in ``artifacts/bench/*.csv``.  If
dry-run artifacts exist, appends the roofline summary (§Roofline inputs).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import (
        bench_gemm,
        bench_paper_figures,
        bench_schedulers,
        bench_serving,
    )

    rows = []
    rows += bench_paper_figures.run()
    rows += bench_schedulers.run()
    rows += bench_gemm.run()
    rows += bench_serving.run()

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if os.path.isdir(art) and os.listdir(art):
        from repro.launch import roofline

        rows_r = roofline.load_rows(art, mesh="pod16x16")
        if rows_r:
            print("\n# Roofline (single-pod 16x16, per-device terms):")
            print(roofline.format_table(rows_r))


if __name__ == "__main__":
    main()

"""Benchmark harness: timing helper + CSV/JSON emission.

Every benchmark module exposes ``run() -> list[Row]``; ``run.py`` collects
them and prints the ``name,us_per_call,derived`` CSV required by the
assignment, plus writes per-figure CSV artifacts under ``artifacts/bench``.

``write_json`` artifacts are self-describing: every ``BENCH_*.json``
carries a ``meta`` block (git sha, jax version, timestamp, plus whatever
the benchmark passes — spec name, arch) alongside its ``records``, so
the perf trajectory across commits needs no out-of-band context.  Since
the meta block is volatile by design, baseline comparison goes through
``python -m benchmarks.harness --compare OLD NEW`` (records only) — the
CI gate for the committed ``BENCH_gemm.json``.
"""

from __future__ import annotations

import dataclasses
import os
import subprocess
import time
from typing import Callable, Optional

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str  # key metric, e.g. "gflops=11.42"


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in µs."""

    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def write_csv(fname: str, header: str, lines: list[str]) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, fname)
    with open(path, "w") as f:
        f.write(header + "\n")
        f.write("\n".join(lines) + "\n")
    return path


def run_metadata(**extra) -> dict:
    """Shared run provenance stamped into every ``BENCH_*.json``."""

    import datetime

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except OSError:
        sha = None
    try:
        import jax

        jax_version = jax.__version__
    except Exception:
        jax_version = None
    meta = {
        "git_sha": sha,
        "jax_version": jax_version,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
    }
    meta.update(extra)
    return meta


def write_json(fname: str, records: list[dict], **meta) -> str:
    """Machine-readable benchmark artifact: ``{"meta": ..., "records":
    [...]}`` — one record per measured cell, plus run provenance
    (``run_metadata`` fields merged with the keyword extras)."""

    from repro.util.atomic import atomic_write_json

    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, fname)
    return atomic_write_json(
        path, {"meta": run_metadata(**meta), "records": records},
        indent=1, sort_keys=True,
    )


def load_records(path: str) -> list:
    """Records of a ``write_json`` artifact (tolerates the pre-meta
    bare-list format so old baselines still compare)."""

    import json

    with open(path) as f:
        data = json.load(f)
    return data["records"] if isinstance(data, dict) else data


def compare_records(old_path: str, new_path: str) -> list[str]:
    """Structural record diff (meta excluded); empty list == identical."""

    old, new = load_records(old_path), load_records(new_path)
    diffs = []
    if len(old) != len(new):
        diffs.append(f"record count: {len(old)} -> {len(new)}")
    for i, (o, n) in enumerate(zip(old, new)):
        if o != n:
            if isinstance(o, dict) and isinstance(n, dict):
                keys = sorted(
                    k for k in set(o) | set(n) if o.get(k) != n.get(k)
                )
                diffs.append(
                    f"record[{i}]: " + ", ".join(
                        f"{k}: {o.get(k)!r} -> {n.get(k)!r}" for k in keys
                    )
                )
            else:
                diffs.append(f"record[{i}]: {o!r} -> {n!r}")
    return diffs


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.harness",
        description="Compare two BENCH_*.json artifacts by records "
                    "(volatile meta ignored).",
    )
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"), required=True)
    args = ap.parse_args()
    diffs = compare_records(*args.compare)
    for d in diffs:
        print(d)
    if diffs:
        print(f"{len(diffs)} record difference(s)")
        return 1
    print("records identical")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Benchmark harness: timing helper + CSV emission.

Every benchmark module exposes ``run() -> list[Row]``; ``run.py`` collects
them and prints the ``name,us_per_call,derived`` CSV required by the
assignment, plus writes per-figure CSV artifacts under ``artifacts/bench``.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str  # key metric, e.g. "gflops=11.42"


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in µs."""

    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def write_csv(fname: str, header: str, lines: list[str]) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, fname)
    with open(path, "w") as f:
        f.write(header + "\n")
        f.write("\n".join(lines) + "\n")
    return path


def write_json(fname: str, records: list[dict]) -> str:
    """Machine-readable benchmark artifact (one record per measured cell)."""

    import json

    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, fname)
    with open(path, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)
        f.write("\n")
    return path

"""Paper-figure reproductions via the calibrated simulator.

One function per figure/table of the paper; each emits a CSV artifact and
returns summary Rows.  The simulator's only calibration inputs are the
paper's single-cluster measurements (Section 3) — everything here is a
derived reproduction (validated in tests/test_simulator.py).
"""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, time_fn, write_csv
from repro.core import simulator as sim

R_GRID = [512, 1024, 2048, 3072, 4096, 5120, 6144]


def fig4_cache_search() -> list[Row]:
    """Figure 4: coarse->fine (m_c, k_c) search heatmap (analytic model).

    The paper measures GFLOPS per (m_c, k_c); without the A15 silicon we
    rank candidates with the capacity/amortization model of blocking.py and
    report the derived optimum next to the paper's empirical one.
    """

    from repro.core import blocking as B

    lines = []
    best = None
    for mc in range(32, 321, 8):
        for kc in range(64, 1201, 8):
            cfg = B.GotoBlocking(mc=mc, kc=kc, nc=4096)
            if cfg.a_panel_bytes() > B.CORTEX_A15.l2_bytes * B.CORTEX_A15.l2_fill:
                continue
            if cfg.b_micropanel_bytes() > B.CORTEX_A15.l1_bytes * B.CORTEX_A15.l1_fill:
                continue
            # amortization score: flops per byte moved through L2/L1
            score = 2.0 * mc * kc / (mc * kc + kc * cfg.nr + mc * cfg.nr)
            lines.append(f"{mc},{kc},{score:.3f}")
            if best is None or score > best[2]:
                best = (mc, kc, score)
    write_csv("fig4_cache_search.csv", "mc,kc,score", lines)
    return [
        Row(
            "fig4_cache_search",
            0.0,
            f"analytic_opt=(mc={best[0]},kc={best[1]}) paper_opt=(152,952)",
        )
    ]


def fig5_cluster_scaling() -> list[Row]:
    lines = []
    for cl in (sim.A15, sim.A7):
        for n in range(1, 5):
            for r in R_GRID:
                s = sim.simulate_single_cluster(r, cl, n)
                lines.append(f"{cl.name},{n},{r},{s.gflops:.3f},{s.gflops_per_w:.3f}")
    write_csv("fig5_cluster_scaling.csv", "cluster,cores,r,gflops,gflops_per_w", lines)
    a15 = sim.simulate_single_cluster(6144, sim.A15, 4)
    a7 = sim.simulate_single_cluster(6144, sim.A7, 4)
    us = time_fn(lambda: sim.simulate_single_cluster(6144, sim.A15, 4))
    return [
        Row("fig5_a15_peak", us, f"gflops={a15.gflops:.2f} (paper 9.6)"),
        Row("fig5_a7_peak", us, f"gflops={a7.gflops:.2f} (paper 2.4)"),
    ]


def fig7_sss() -> list[Row]:
    lines = []
    for r in R_GRID:
        sss = sim.simulate_static(r)
        a15 = sim.simulate_single_cluster(r, sim.A15, 4)
        ideal = sim.ideal_gflops(r)
        lines.append(
            f"{r},{sss.gflops:.3f},{a15.gflops:.3f},{ideal:.3f},{sss.gflops_per_w:.3f}"
        )
    write_csv("fig7_sss.csv", "r,sss_gflops,a15_gflops,ideal_gflops,sss_gflops_per_w", lines)
    frac = sim.simulate_static(6144).gflops / sim.simulate_single_cluster(6144, sim.A15, 4).gflops
    us = time_fn(lambda: sim.simulate_static(6144))
    return [Row("fig7_sss_fraction_of_a15", us, f"frac={frac:.2f} (paper ~0.40)")]


def fig9_sas_ratio() -> list[Row]:
    lines = []
    for r in R_GRID:
        for ratio in range(1, 8):
            s = sim.simulate_static(r, ratio=float(ratio))
            lines.append(f"{r},{ratio},{s.gflops:.3f},{s.gflops_per_w:.3f}")
    write_csv("fig9_sas_ratio.csv", "r,ratio,gflops,gflops_per_w", lines)
    res = sim.sweep_ratio(6144, ratios=range(1, 8))
    best = int(np.argmax([x.gflops for x in res])) + 1
    gain = max(x.gflops for x in res) / sim.simulate_single_cluster(6144, sim.A15, 4).gflops
    us = time_fn(lambda: sim.sweep_ratio(6144, ratios=range(1, 8)))
    return [Row("fig9_sas_best_ratio", us, f"best={best} (paper 5-6) gain_vs_a15={gain:.2f}")]


def fig10_11_ca_sas() -> list[Row]:
    lines = []
    for r in R_GRID:
        for ratio in (1, 3, 5):
            for ca in (False, True):
                s = sim.simulate_static(r, ratio=ratio, cache_aware=ca)
                lines.append(f"{r},{ratio},{int(ca)},{s.gflops:.3f},{s.gflops_per_w:.3f}")
    write_csv("fig10_ca_sas.csv", "r,ratio,cache_aware,gflops,gflops_per_w", lines)

    lines = []
    for coarse in ("loop1", "loop3"):
        for fine in ("loop4", "loop5"):
            s = sim.simulate_static(6144, ratio=5, cache_aware=True, coarse=coarse, fine=fine)
            lines.append(f"{coarse},{fine},{s.gflops:.3f}")
    write_csv("fig11_loop_grid.csv", "coarse,fine,gflops", lines)

    ca3 = sim.simulate_static(6144, ratio=3, cache_aware=True).gflops
    sas3 = sim.simulate_static(6144, ratio=3).gflops
    return [Row("fig10_ca_gain_at_ratio3", 0.0, f"ca/plain={ca3/sas3:.2f} (paper: CA wins below ratio 5)")]


def fig12_ca_das() -> list[Row]:
    lines = []
    for r in R_GRID:
        for ca in (False, True):
            for fine in ("loop4", "loop5"):
                s = sim.simulate_dynamic(r, cache_aware=ca, fine=fine)
                lines.append(f"{r},{int(ca)},{fine},{s.gflops:.3f},{s.gflops_per_w:.3f}")
        ref = sim.simulate_static(r, ratio=5, cache_aware=True)
        lines.append(f"{r},ca-sas5,loop4,{ref.gflops:.3f},{ref.gflops_per_w:.3f}")
    write_csv("fig12_ca_das.csv", "r,variant,fine,gflops,gflops_per_w", lines)
    cadas = sim.simulate_dynamic(6144, cache_aware=True)
    das = sim.simulate_dynamic(6144, cache_aware=False)
    us = time_fn(lambda: sim.simulate_dynamic(6144, cache_aware=True))
    return [
        Row("fig12_cadas", us, f"gflops={cadas.gflops:.2f} ideal={sim.ideal_gflops(6144):.2f}"),
        Row("fig12_das_vs_cadas", us, f"das/cadas={das.gflops/cadas.gflops:.2f} (paper: <1)"),
    ]


def run() -> list[Row]:
    rows = []
    rows += fig4_cache_search()
    rows += fig5_cluster_scaling()
    rows += fig7_sss()
    rows += fig9_sas_ratio()
    rows += fig10_11_ca_sas()
    rows += fig12_ca_das()
    return rows

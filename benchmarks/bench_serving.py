"""Serving benchmark: per-step-relayout baseline vs the persistent engine.

Measures the hot-path win the slot-table engine exists for (ISSUE 5 /
ROADMAP "Serving"): the baseline emulates the pre-engine serving loop in
which **every generated token** pays

  * a host chunk-table re-derivation + pod-major re-pad of the token
    batch (``pad_requests``) and its device transfer,
  * a full decode-state copy (state threaded through jit *without*
    donation),
  * a host round-trip for the argmax feedback token,

while the persistent engine keeps requests pinned to their slots (zero
per-step relayout), donates the decode state (in-place cache update), and
keeps the token feedback resident.  Both sides decode the identical
padded batch with the identical model program; the measurement interleaves
several rounds per side and compares **medians** of steady-state tokens/s
(jit compile excluded — reported separately as ``compile_s``), so a stray
scheduler hiccup on a loaded CI box cannot flip the verdict.  The gate
runs the single-program path (no shard_map) because the 8-forced-device
shard_map barrier adds CPU thread-scheduling noise an order of magnitude
above the measured effect; ``--mixed`` adds an informational class-sharded
row.  Results land in ``artifacts/bench/BENCH_serving.json`` with the
speedup; CI smoke-runs this module and asserts the engine is strictly
faster (``--check``).

Run::

    PYTHONPATH=src python -m benchmarks.bench_serving [--check] [--mixed]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import Row, write_json
from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.distributed import sharding as SH
from repro.models import model_zoo as Z


def _mk_asym():
    return AsymmetricMesh(
        biglittle_classes(chips_per_pod=1), strategy="ca-das", batch_tile=1
    )


def baseline_rounds(cfg, params, prompts, gen_len, seq_cap, reps):
    """The pre-engine loop, ``reps`` rounds: relayout + undonated state per token."""

    from repro.launch.serve import pad_requests

    asym = _mk_asym()
    b, plen = prompts.shape
    layout = asym.batch_layout(b)
    padded, order0 = pad_requests(prompts, layout)
    decode = jax.jit(Z.make_decode_fn(cfg))  # NO donation: full state copy/step
    prefill = jax.jit(Z.make_prefill_fn(cfg, with_cache=True))

    compile_s, rates = 0.0, []
    for rep in range(reps):
        state = Z.init_decode_state(cfg, padded.shape[0], seq_cap)
        t0 = time.perf_counter()
        logits, state = prefill(
            params, {"tokens": jnp.asarray(padded)}, state, jnp.int32(0)
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))[order0, None]
        if rep == 0:
            compile_s += time.perf_counter() - t0
        decode_s, steps = 0.0, 0
        for t in range(plen, plen + gen_len):
            t1 = time.perf_counter()
            # Host relayout, every token: re-derive, re-pad, re-upload.
            lay = asym.batch_layout(b)
            tok_padded, order = pad_requests(nxt, lay)
            logits, state = decode(
                params, {"tokens": jnp.asarray(tok_padded)}, state, jnp.int32(t)
            )
            nxt = np.asarray(
                jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            )[order, None]
            dt = time.perf_counter() - t1
            if rep == 0 and t == plen:
                compile_s += dt  # first decode call compiles
            else:
                decode_s += dt
                steps += 1
        rates.append(b * steps / decode_s)
    return {"compile_s": compile_s, "rates": rates}


def engine_rounds(cfg, params, prompts, gen_len, seq_cap, reps, *, mixed):
    """The persistent engine, ``reps`` waves through one long-lived engine."""

    from repro.runtime.serving import ServingEngine

    asym = _mk_asym()
    layout = asym.batch_layout(prompts.shape[0])
    eng = ServingEngine(
        cfg, params, asym, seq_cap=seq_cap, slots_per_pod=layout.c_max,
        class_sharded="auto" if mixed else "off",
    )
    rates = []
    prev_tokens = prev_s = 0.0
    for _ in range(reps):
        eng.generate(prompts, gen_len)
        st = eng.stats
        dtok, ds = st.tokens - prev_tokens, st.decode_s - prev_s
        prev_tokens, prev_s = st.tokens, st.decode_s
        rates.append(dtok / ds if ds else 0.0)
    return {
        "compile_s": eng.stats.compile_s,
        "rates": rates,
        "host_relayouts": eng.stats.host_relayouts,
        "rebalances": eng.stats.rebalances,
        "mixed": eng.mixed,
    }


def paged_ab(cfg, params, gen_len, seq_cap, reps, *, slots_per_pod=8,
             page_size=16):
    """Dense vs paged engine A/B: high slot count, mixed prompt lengths.

    Both sides run identical mixed-length request waves through a full
    slot table.  The dense engine allocates ``n_slots × seq_cap`` KV
    lanes up front; the paged engine's footprint is its page pool's
    high-water mark (``peak_kv_bytes`` — phantom lanes included), which
    at realistic request lengths is a small fraction of the dense
    reservation.  ``memory_reduction`` is the headline ratio; tokens are
    asserted bit-identical between the sides while we're here.
    """

    from repro.runtime.serving import ServingEngine

    def side(paged):
        asym = _mk_asym()
        eng = ServingEngine(
            cfg, params, asym, seq_cap=seq_cap, slots_per_pod=slots_per_pod,
            class_sharded="off", paged=paged,
            page_size=page_size if paged == "on" else None,
        )
        # One wave fills the whole table with heterogeneous prompts —
        # every length admits in the same continuous-batching round.
        plens = [4 + 2 * (i % 7) for i in range(eng.n_slots)]
        prompts = rng.integers(0, cfg.vocab, (eng.n_slots, max(plens)),
                               dtype=np.int32)
        rates, prev_t, prev_s = [], 0.0, 0.0
        for _ in range(reps):
            for i, pl in enumerate(plens):
                eng.submit(prompts[i][:pl], gen_len)
            eng.run()
            st = eng.stats
            dtok, ds = st.tokens - prev_t, st.decode_s - prev_s
            prev_t, prev_s = st.tokens, st.decode_s
            rates.append(dtok / ds if ds else 0.0)
        toks = {c.rid: c.tokens for c in eng.completions}
        return eng, float(np.median(rates)), toks

    # Re-seed per side so both submit identical prompt waves.
    rng = np.random.default_rng(2)
    dense_eng, dense_tps, dense_toks = side("off")
    rng = np.random.default_rng(2)
    paged_eng, paged_tps, paged_toks = side("on")
    assert set(dense_toks) == set(paged_toks)
    for rid in dense_toks:
        assert np.array_equal(dense_toks[rid], paged_toks[rid]), (
            f"paged tokens diverged from dense for rid={rid}"
        )

    dense_kv = dense_eng.kv_stats()
    paged_kv = paged_eng.kv_stats()
    reduction = dense_kv["kv_bytes"] / max(paged_kv["peak_kv_bytes"], 1)
    return {
        "slots": [paged_eng.n_pods, paged_eng.c_max],
        "seq_cap": seq_cap,
        "page_size": paged_kv["page_size"],
        "dense": {"tokens_per_s": round(dense_tps, 1),
                  "kv_bytes": dense_kv["kv_bytes"]},
        "paged": {"tokens_per_s": round(paged_tps, 1),
                  "peak_kv_bytes": paged_kv["peak_kv_bytes"],
                  "peak_live_pages": paged_kv["peak_live_pages"],
                  "phantom_pages": paged_kv["phantom_pages"],
                  "admission_deferrals": paged_eng.stats.admission_deferrals},
        "tokens_identical": True,
        "memory_reduction": round(reduction, 2),
    }


def objective_ab(cfg, params, gen_len, seq_cap, reps, *,
                 objectives=("energy",), wave=3, prompt_len=8,
                 slots_per_pod=4):
    """``perf`` vs objective-engine A/B at low offered load.

    Every side serves identical low-depth request waves (``wave`` requests
    against ``2 × slots_per_pod`` slots — the regime where the non-perf
    objectives park the big pod and serve from little).  Compared on the
    *modeled* power-clock columns (``energy_j`` / ``tokens_per_j`` /
    ``modeled_tokens_per_s``), which are deterministic across hosts; the
    wall-clock SPMD program is the same on every side, so tokens are
    asserted bit-identical and the existing speedup gate is untouched.
    The single ``perf`` reference run is shared across all requested
    ``objectives``; one block per objective is returned, each carrying the
    shared perf columns so every block is self-contained (the RPR202
    artifact shape).  The check gate asserts the requested objective
    actually buys joules (``energy_ratio`` strictly < 1) at a bounded
    modeled-throughput loss.
    """

    from repro.runtime.serving import ServingEngine

    def side(obj):
        asym = AsymmetricMesh(
            biglittle_classes(chips_per_pod=1), strategy="ca-das",
            batch_tile=1, objective=obj,
        )
        eng = ServingEngine(
            cfg, params, asym, seq_cap=seq_cap, slots_per_pod=slots_per_pod,
            class_sharded="off",
        )
        rng = np.random.default_rng(2)
        outs = []
        for _ in range(reps):
            prompts = rng.integers(0, cfg.vocab, (wave, prompt_len),
                                   dtype=np.int32)
            outs.append(eng.generate(prompts, gen_len))
        return eng, outs

    def cols(st):
        return {
            "energy_j": round(st.energy_j, 4),
            "tokens_per_j": round(st.tokens_per_j, 3),
            "modeled_tokens_per_s": round(st.modeled_tokens_per_s, 1),
            "pod_parks": st.pod_parks,
            "pod_unparks": st.pod_unparks,
        }

    perf_eng, perf_outs = side("perf")
    ps = perf_eng.stats
    blocks = {}
    for objective in objectives:
        obj_eng, obj_outs = side(objective)
        for a, b in zip(perf_outs, obj_outs):
            assert np.array_equal(a, b), (
                f"{objective}-objective tokens diverged from perf"
            )
        os_ = obj_eng.stats
        energy_ratio = os_.energy_j / ps.energy_j if ps.energy_j else 0.0
        throughput_ratio = (
            os_.modeled_tokens_per_s / ps.modeled_tokens_per_s
            if ps.modeled_tokens_per_s else 0.0
        )
        blocks[objective] = {
            "objective": objective,
            "wave": wave,
            "reps": reps,
            "gen_len": gen_len,
            "perf": cols(ps),
            objective: cols(os_),
            "tokens_identical": True,
            "energy_ratio": round(energy_ratio, 3),
            "throughput_ratio": round(throughput_ratio, 3),
        }
    return blocks


def run(arch: str = "internlm2-1.8b", batch: int = 8, prompt_len: int = 8,
        gen_len: int = 48, seq_cap: int = 512, reps: int = 3,
        mixed: bool = False, obs: bool = False, paged: bool = False,
        objective: str | None = None) -> list[Row]:
    """Both sides on identical prompts/layout; writes ``BENCH_serving.json``.

    ``seq_cap`` is deliberately larger than prompt+gen: the decode-state
    size (what the undonated baseline copies every token) scales with it,
    exactly as production caches dwarf the per-token math.
    """

    cfg = get_config(arch).reduced()
    SH.use_mesh_for_activations(None)
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (batch, prompt_len), dtype=np.int32)
    seq_cap = max(seq_cap, prompt_len + gen_len)

    base = baseline_rounds(cfg, params, prompts, gen_len, seq_cap, reps)
    eng = engine_rounds(cfg, params, prompts, gen_len, seq_cap, reps, mixed=False)

    base_tps = float(np.median(base["rates"]))
    eng_tps = float(np.median(eng["rates"]))
    speedup = eng_tps / base_tps if base_tps else 0.0
    record = {
        "arch": cfg.name,
        "batch": batch,
        "prompt_len": prompt_len,
        "gen_len": gen_len,
        "seq_cap": seq_cap,
        "reps": reps,
        "baseline": {"tokens_per_s": round(base_tps, 1),
                     "rounds": [round(r, 1) for r in base["rates"]],
                     "compile_s": round(base["compile_s"], 3)},
        "engine": {"tokens_per_s": round(eng_tps, 1),
                   "rounds": [round(r, 1) for r in eng["rates"]],
                   "compile_s": round(eng["compile_s"], 3),
                   "host_relayouts": eng["host_relayouts"],
                   "rebalances": eng["rebalances"]},
        "speedup": round(speedup, 3),
    }
    rows = [
        Row("serve_baseline_relayout", 1e6 / max(base_tps, 1e-9),
            f"tokens_per_s={base_tps:.1f}"),
        Row("serve_engine_persistent", 1e6 / max(eng_tps, 1e-9),
            f"tokens_per_s={eng_tps:.1f}"),
        Row("serve_engine_speedup", 0.0, f"speedup={speedup:.3f}"),
    ]
    if mixed:
        # Informational: the class-sharded engine (two per-class programs
        # in one SPMD step) — noisy on forced host devices, not gated.
        emix = engine_rounds(cfg, params, prompts, gen_len, seq_cap, reps,
                             mixed=True)
        mix_tps = float(np.median(emix["rates"]))
        record["engine_mixed"] = {
            "tokens_per_s": round(mix_tps, 1), "mixed": emix["mixed"],
        }
        rows.append(Row("serve_engine_mixed", 1e6 / max(mix_tps, 1e-9),
                        f"tokens_per_s={mix_tps:.1f}"))
    if obs:
        # Informational: the engine with tracing + the default step-time
        # probe active — the measured enabled-path overhead of the
        # observability contract.  Not gated (the gate runs disabled).
        from repro import observability as OBS

        OBS.enable()
        try:
            eobs = engine_rounds(cfg, params, prompts, gen_len, seq_cap, reps,
                                 mixed=False)
        finally:
            buf = OBS.disable()
        obs_tps = float(np.median(eobs["rates"]))
        overhead = 1.0 - obs_tps / eng_tps if eng_tps else 0.0
        record["engine_observed"] = {
            "tokens_per_s": round(obs_tps, 1),
            "overhead_pct": round(100.0 * overhead, 1),
            "trace_events": len(buf.events) if buf else 0,
        }
        rows.append(Row("serve_engine_traced", 1e6 / max(obs_tps, 1e-9),
                        f"tokens_per_s={obs_tps:.1f} "
                        f"overhead_pct={100.0 * overhead:.1f}"))
    if paged:
        # The paged-KV A/B: memory proportional to live tokens instead of
        # slots × seq_cap, tokens bit-identical.  Gated on the memory side
        # (--check asserts memory_reduction >= 2); tokens/s informational.
        ab = paged_ab(cfg, params, gen_len, seq_cap, reps)
        record["paged_ab"] = ab
        rows.append(Row(
            "serve_engine_paged",
            1e6 / max(ab["paged"]["tokens_per_s"], 1e-9),
            f"tokens_per_s={ab['paged']['tokens_per_s']:.1f} "
            f"memory_reduction={ab['memory_reduction']:.2f}"))
    records = [record]
    if objective:
        # The objective A/B on the modeled power clock: lower modeled
        # joules than the perf run on the same trace, tokens bit-identical.
        # Both non-perf objectives run against ONE shared perf reference;
        # the requested one lands in this record's ``objective_ab`` (and
        # is what --check gates), the other becomes its own informational
        # record so BENCH_serving.json always carries the energy-vs-edp
        # comparison.
        both = ("energy", "edp")
        blocks = objective_ab(cfg, params, gen_len, seq_cap, reps,
                              objectives=both)
        record["objective_ab"] = blocks[objective]
        for obj in both:
            ab = blocks[obj]
            if obj != objective:
                records.append(
                    {"name": f"serve_objective_{obj}", "objective_ab": ab}
                )
            rows.append(Row(
                f"serve_engine_{obj}", 0.0,
                f"energy_ratio={ab['energy_ratio']:.3f} "
                f"throughput_ratio={ab['throughput_ratio']:.3f} "
                f"tokens_per_j={ab[obj]['tokens_per_j']:.3f}"))
    path = write_json("BENCH_serving.json", records, bench="serving",
                      arch=cfg.name)
    print(f"wrote {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=48)
    ap.add_argument("--seq-cap", type=int, default=512)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--mixed", action="store_true",
                    help="add the informational class-sharded engine row")
    ap.add_argument("--obs", action="store_true",
                    help="add the informational tracing-enabled engine row "
                         "(measures the observability enabled-path overhead)")
    ap.add_argument("--paged", action="store_true",
                    help="add the paged-vs-dense KV A/B rows (high slot "
                         "count, mixed lengths, memory_reduction field)")
    ap.add_argument("--objective", default=None, choices=["energy", "edp"],
                    help="add the perf-vs-objective engine A/B (modeled "
                         "energy_j / tokens_per_j columns; tokens must stay "
                         "bit-identical)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the engine is strictly faster "
                         "(with --paged, the paged pool at least halves peak "
                         "KV memory; with --objective, modeled joules drop "
                         "strictly below the perf run at a bounded modeled-"
                         "throughput loss)")
    args = ap.parse_args()
    rows = run(args.arch, args.batch, args.prompt_len, args.gen_len,
               args.seq_cap, args.reps, args.mixed, args.obs, args.paged,
               args.objective)
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
    if args.check:
        speed = float(rows[2].derived.split("=")[1])
        if speed <= 1.0:
            raise SystemExit(f"persistent engine not faster: speedup={speed}")
        if args.paged:
            paged_row = next(r for r in rows if r.name == "serve_engine_paged")
            red = float(paged_row.derived.split("memory_reduction=")[1])
            if red < 2.0:
                raise SystemExit(
                    f"paged KV pool reduction below 2x: {red}"
                )
        if args.objective:
            obj_row = next(
                r for r in rows if r.name == f"serve_engine_{args.objective}"
            )
            eratio = float(
                obj_row.derived.split("energy_ratio=")[1].split()[0]
            )
            tratio = float(
                obj_row.derived.split("throughput_ratio=")[1].split()[0]
            )
            if eratio >= 1.0:
                raise SystemExit(
                    f"{args.objective} objective saved no modeled energy: "
                    f"energy_ratio={eratio}"
                )
            if tratio < 0.2:
                raise SystemExit(
                    f"{args.objective} objective lost too much modeled "
                    f"throughput: throughput_ratio={tratio}"
                )


if __name__ == "__main__":
    main()

"""Fleet benchmark: bursty mixed-length trace, with and without a kill.

Exercises the fault-tolerant fleet layer (ISSUE 10 / ROADMAP
"Multi-engine fleet") on the **modeled clock** — the deterministic
power-model time base every engine accumulates per decode step
(``stats.modeled_decode_s``), host-independent by construction:

  * **no-fault lane** — the N-engine fleet against a single engine on
    the identical bursty arrival trace; engines tick in lockstep (they
    would run concurrently in production), so the fleet's modeled span
    is the *max* over engines and the speedup gate is real parallelism,
    not bookkeeping.
  * **kill lane** — the same trace with a seeded ``pod_death`` injected
    at tick K through :mod:`repro.runtime.faults`; the bench measures
    the surviving engine's post-kill throughput against its standalone
    (single-engine) rate — *recovered* means the fleet redistributed the
    dead engine's queued work and kept the survivor saturated.

Every lane asserts the exactness contract while it is here: each
submitted request completes exactly once (``completed == submitted``,
zero duplicates) with tokens bit-identical across the single-engine,
no-fault-fleet, and kill-fleet runs.  Results land in
``artifacts/bench/BENCH_fleet.json``; CI smoke-runs this module with
``--check`` (no-fault speedup >= 1.5x, kill lane recovered).

Run::

    PYTHONPATH=src python -m benchmarks.bench_fleet [--check]
"""

from __future__ import annotations

import argparse
import contextlib

import jax
import numpy as np

from benchmarks.harness import Row, write_json
from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.distributed import sharding as SH
from repro.models import model_zoo as Z
from repro.runtime import faults

# Bursty arrival trace: BURSTS arrivals land at once every GAP ticks.
# Prompt lengths cycle over a small set so the compile-key space stays
# bounded (each distinct length compiles one prefill per engine).
PROMPT_LENS = (4, 8, 12)
KILL_TICK = 6


def _mk_engine(cfg, params, seq_cap, slots_per_pod):
    from repro.runtime.serving import ServingEngine

    asym = AsymmetricMesh(
        biglittle_classes(chips_per_pod=1), strategy="ca-das", batch_tile=1
    )
    return ServingEngine(
        cfg, params, asym, seq_cap=seq_cap, slots_per_pod=slots_per_pod,
        class_sharded="off",
    )


def make_trace(cfg, *, bursts=3, burst_size=8, gap=4, seed=7):
    """``[(arrival_tick, prompt), ...]`` — identical for every lane."""

    rng = np.random.default_rng(seed)
    trace = []
    for b in range(bursts):
        for _ in range(burst_size):
            plen = int(rng.choice(PROMPT_LENS))
            prompt = rng.integers(0, cfg.vocab, (plen,), dtype=np.int32)
            trace.append((b * gap, prompt))
    return trace


def drive(fleet, trace, gen_len, *, plan=None, snap_tick=None, snap_engine=None):
    """Submit per the arrival trace and tick the fleet to completion.

    Returns ``(tokens_by_rid, postkill)`` where ``postkill`` is the
    ``(tokens, modeled_s)`` delta of ``snap_engine`` from just before
    internal tick ``snap_tick`` (the tick the plan's kill fires on) to
    the end of the run — its post-kill throughput numerator/denominator.
    """

    ctx = faults.injected(plan) if plan is not None else contextlib.nullcontext()
    snap = None
    with ctx:
        i, tick = 0, 0
        while True:
            while i < len(trace) and trace[i][0] <= tick:
                fleet.submit(trace[i][1], gen_len)
                i += 1
            if i >= len(trace) and len(fleet.completions) == len(trace):
                break
            # tick() moves the fleet to internal tick ``tick + 1`` — so a
            # snapshot taken here, at ``tick == snap_tick - 1``, brackets
            # everything from the kill tick onward.
            if snap_tick is not None and tick == snap_tick - 1:
                e = fleet.engines[snap_engine]
                snap = (e.stats.tokens, e.stats.modeled_decode_s)
            fleet.tick()
            tick += 1
            if tick > 10_000:
                raise RuntimeError("bench_fleet: fleet failed to converge")
    postkill = None
    if snap is not None:
        e = fleet.engines[snap_engine]
        postkill = (e.stats.tokens - snap[0], e.stats.modeled_decode_s - snap[1])
    toks = {c.rid: np.asarray(c.tokens) for c in fleet.completions}
    return toks, postkill


def _fleet_tps(fleet):
    """Tokens per modeled second with engines running in lockstep: the
    span is the slowest (max) engine's modeled time."""

    tokens = sum(e.stats.tokens for e in fleet.engines)
    span = max(e.stats.modeled_decode_s for e in fleet.engines)
    return tokens / span if span > 0 else 0.0


def run(arch: str = "internlm2-1.8b", n_engines: int = 2, gen_len: int = 8,
        slots_per_pod: int = 2, seq_cap: int = 32) -> list[Row]:
    """Three lanes on one trace; writes ``BENCH_fleet.json``."""

    from repro.runtime.fleet import Fleet

    cfg = get_config(arch).reduced()
    SH.use_mesh_for_activations(None)
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    trace = make_trace(cfg)

    # Lane 1: single engine, the reference for tokens and throughput.
    single = Fleet([_mk_engine(cfg, params, seq_cap, slots_per_pod)])
    single_toks, _ = drive(single, trace, gen_len)
    single_tps = _fleet_tps(single)

    # Lane 2: the no-fault fleet.
    fleet = Fleet(
        [_mk_engine(cfg, params, seq_cap, slots_per_pod)
         for _ in range(n_engines)]
    )
    fleet_toks, _ = drive(fleet, trace, gen_len)
    fleet_tps = _fleet_tps(fleet)
    speedup = fleet_tps / single_tps if single_tps else 0.0

    # Lane 3: same fleet shape, engine 0 killed at tick KILL_TICK.
    plan = faults.FaultPlan(
        [faults.FaultEvent(point="pod_death", engine=0, tick=KILL_TICK)]
    )
    kfleet = Fleet(
        [_mk_engine(cfg, params, seq_cap, slots_per_pod)
         for _ in range(n_engines)]
    )
    survivor = 1
    kill_toks, postkill = drive(
        kfleet, trace, gen_len,
        plan=plan, snap_tick=KILL_TICK, snap_engine=survivor,
    )
    pk_tokens, pk_s = postkill
    postkill_tps = pk_tokens / pk_s if pk_s > 0 else 0.0
    # Recovered: after the kill the survivor sustains at least 80% of
    # what it delivers standing alone on this whole trace — i.e. the
    # fleet actually moved the dead engine's work over and kept the
    # survivor saturated rather than stranding requests.
    recovered = postkill_tps >= 0.8 * single_tps

    # Exactness across all three lanes: same rids, bit-identical tokens.
    for name, toks in (("fleet", fleet_toks), ("kill", kill_toks)):
        assert set(toks) == set(single_toks), f"{name}: request set diverged"
        for rid in single_toks:
            assert np.array_equal(toks[rid], single_toks[rid]), (
                f"{name}: tokens diverged from single-engine run for "
                f"rid={rid}"
            )
    for f in (single, fleet, kfleet):
        assert f.stats.completed == f.stats.submitted, (
            f"conservation: {f.stats.completed}/{f.stats.submitted}"
        )
        assert f.stats.duplicate_completions == 0

    record = {
        "arch": cfg.name,
        "n_engines": n_engines,
        "requests": len(trace),
        "gen_len": gen_len,
        "slots_per_pod": slots_per_pod,
        "kill_tick": KILL_TICK,
        "single": {"modeled_tokens_per_s": round(single_tps, 1)},
        "fleet": {
            "modeled_tokens_per_s": round(fleet_tps, 1),
            "speedup_vs_single": round(speedup, 3),
            **{k: v for k, v in fleet.stats.snapshot().items()
               if k in ("submitted", "completed", "migrated", "retries",
                        "duplicate_completions", "ticks")},
        },
        "kill": {
            "postkill_tokens_per_s": round(postkill_tps, 1),
            "recovered": recovered,
            **{k: v for k, v in kfleet.stats.snapshot().items()
               if k in ("submitted", "completed", "migrated", "retries",
                        "duplicate_completions", "engine_kills", "ticks")},
        },
        "tokens_identical": True,
    }
    rows = [
        Row("fleet_single_engine", 0.0,
            f"modeled_tokens_per_s={single_tps:.1f}"),
        Row("fleet_nofault", 0.0,
            f"modeled_tokens_per_s={fleet_tps:.1f} "
            f"speedup_vs_single={speedup:.3f} "
            f"submitted={fleet.stats.submitted} "
            f"completed={fleet.stats.completed} "
            f"duplicates={fleet.stats.duplicate_completions}"),
        Row("fleet_engine_kill", 0.0,
            f"postkill_tokens_per_s={postkill_tps:.1f} "
            f"recovered={recovered} "
            f"submitted={kfleet.stats.submitted} "
            f"completed={kfleet.stats.completed} "
            f"duplicates={kfleet.stats.duplicate_completions} "
            f"migrated={kfleet.stats.migrated} "
            f"retries={kfleet.stats.retries}"),
    ]
    path = write_json("BENCH_fleet.json", [record], bench="fleet",
                      arch=cfg.name)
    print(f"wrote {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--engines", type=int, default=2)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--slots-per-pod", type=int, default=2)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the no-fault fleet beats the "
                         "single engine by >= 1.5x on the modeled clock and "
                         "the kill lane recovers")
    args = ap.parse_args()
    rows = run(args.arch, args.engines, args.gen_len, args.slots_per_pod)
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
    if args.check:
        nofault = next(r for r in rows if r.name == "fleet_nofault")
        speed = float(
            nofault.derived.split("speedup_vs_single=")[1].split()[0]
        )
        if speed < 1.5:
            raise SystemExit(f"fleet speedup below 1.5x: {speed}")
        kill = next(r for r in rows if r.name == "fleet_engine_kill")
        if "recovered=True" not in kill.derived:
            raise SystemExit(
                "kill lane did not recover: " + kill.derived
            )


if __name__ == "__main__":
    main()

"""Scheduler micro-benchmarks: partitioner overhead must be negligible vs a
training step (it runs on the host every step under CA-DAS).

Also the wallclock feed for the Section-5.2.2 ratio calibration:
:func:`measure_class_step_times` times the probe GEMM under each device
class's execution context and returns the per-class
:class:`~repro.tuning.ratio.ClassMeasurement` records that
``AsymmetricMesh.from_calibration(backend="wallclock", measurements=...)``
consumes.  On this one-CPU host the classes measure ~equal (the honest
answer — the hardware *is* symmetric); on a real fleet the same records
come from per-pod step times and the calibration lands on the true ratio.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.harness import Row, time_fn
from repro.core import schedule as S
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh
from repro.tuning.ratio import ClassMeasurement


def measure_class_step_times(
    classes, probe_shape=(384, 384, 384), reps: int = 3
) -> list[ClassMeasurement]:
    """Wallclock per-class probe steps: the probe GEMM under each class's
    execution context (its control tree picks backend + block shapes).

    ``units`` is the probe's row count — the same unit the chunk tables
    partition — so the records plug straight into
    ``calibrate_class_ratios(backend="wallclock", measurements=...)``.
    """

    am = AsymmetricMesh(classes, tree_shape=probe_shape)
    m, k, n = probe_shape
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = []
    for c in classes:
        with am.execution_context(c.name):
            us = time_fn(lambda: jax.block_until_ready(ops.gemm(a, b)), reps=reps)
        out.append(ClassMeasurement(name=c.name, units=m, seconds=us * 1e-6))
    return out


def mixed_step(
    n_rounds: int = 6,
    global_batch: int = 64,
    probe_shape=(256, 256, 256),
    reps: int = 2,
) -> list[Row]:
    """True CA-SAS mixed step + per-shard timing feedback (DAS, §5.4).

    Runs the probe GEMM as *one* SPMD step through ``class_sharded`` — each
    pod's row shard under its own class's control tree — then times each
    class's shard separately under that class's context (the per-shard
    timings a fleet reads from per-pod step telemetry) and feeds them to
    ``DynamicScheduler.observe``.  Converges to the same ratio the §5.2.2
    wallclock calibration measures; on this one-CPU host both are ~1
    (the hardware really is symmetric) and the interesting output is that
    the loop closes: real timings in, re-derived chunk table out.
    """

    if jax.device_count() < 2:
        return [Row("sched_mixed_step", 0.0, "skipped: needs >=2 host devices")]

    classes = biglittle_classes(chips_per_pod=1)
    am = AsymmetricMesh(classes, strategy="ca-das", batch_tile=2,
                        tree_shape=probe_shape, backend="xla")
    mesh = make_host_mesh(pod=am.n_pods)
    m, k, n = probe_shape
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)

    step = am.class_sharded(
        lambda x, w: ops.gemm(x, w),
        mesh=mesh, in_specs=(P("pod"), P()), out_specs=P("pod"),
    )
    jstep = jax.jit(step)

    step_us = 0.0
    for _ in range(n_rounds):
        layout = am.batch_layout(global_batch)
        c_max = layout.c_max
        x = jnp.asarray(
            rng.normal(size=(len(layout.sizes) * c_max, k)), jnp.float32
        )
        step_us += time_fn(lambda: jax.block_until_ready(jstep(x, b)), reps=1)
        # Real per-shard timings: each class's assigned rows, under that
        # class's own execution context (what per-pod telemetry reports).
        times = []
        for i, c in enumerate(classes):
            shard = x[i * c_max : i * c_max + layout.sizes[i]]
            with am.execution_context(c.name):
                us = time_fn(
                    lambda: jax.block_until_ready(ops.gemm(shard, b)), reps=reps
                )
            times.append(us * 1e-6)
        am.observe_step(layout.sizes, times)

    final = am.batch_layout(global_batch)
    sched_ratio = S.balanced_ratio(list(am.scheduler.rates))
    cal = AsymmetricMesh.from_calibration(
        classes, backend="wallclock",
        measurements=measure_class_step_times(classes, probe_shape=probe_shape),
    ).calibration
    cal_ratio = S.balanced_ratio(list(cal.ratios))
    prov = ",".join(f"{p.pod}:{p.device_class}" for p in step.provenance)
    return [
        Row("sched_mixed_step", step_us / n_rounds,
            f"per-class programs in one step; shards=[{prov}]"),
        Row("sched_mixed_step_feedback", step_us / n_rounds,
            f"observed ratio={sched_ratio:.2f} calibrated={cal_ratio:.2f} "
            f"split={final.sizes}"),
    ]


def run() -> list[Row]:
    rows = []
    us = time_fn(lambda: S.sas_partition(4096, [3.0, 1.0], tiles=[152, 32]), reps=20)
    rows.append(Row("sched_sas_partition_4096", us, "per-step host overhead"))

    us = time_fn(lambda: S.das_schedule(4096, [4.0, 1.0], [152, 32]), reps=20)
    rows.append(Row("sched_das_schedule_4096", us, "discrete-event greedy"))

    am = AsymmetricMesh(
        [DeviceClass("a", chips_per_pod=256),
         DeviceClass("b", chips_per_pod=256, rel_throughput=0.35)],
        strategy="ca-das",
    )
    us = time_fn(lambda: am.batch_layout(256), reps=20)
    imb = am.imbalance(am.batch_layout(256))
    rows.append(Row("sched_batch_layout_256", us, f"imbalance={imb:.3f}"))

    # Wallclock ratio calibration off measured per-class step times (the
    # ROADMAP item: feed calibrate_class_ratios real measurements).
    classes = biglittle_classes(chips_per_pod=1)
    meas = measure_class_step_times(classes)
    cal_mesh = AsymmetricMesh.from_calibration(
        classes, backend="wallclock", measurements=meas,
        strategy="ca-das", batch_tile=2,
    )
    total_us = sum(m.seconds for m in meas) * 1e6
    ratios = [round(float(r), 3) for r in cal_mesh.calibration.ratios]
    rows.append(
        Row("sched_wallclock_calibration", total_us,
            f"ratios={ratios} split={cal_mesh.batch_layout(64).sizes}")
    )

    # The mixed-step path (one SPMD step, per-class programs) when the
    # host has a device per pod; a skip row otherwise.
    rows += mixed_step()
    return rows


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench_schedulers",
        description="Scheduler benchmarks (partitioner overhead + mixed step).",
    )
    ap.add_argument(
        "--mixed-step", action="store_true",
        help="only the class-sharded mixed-step rows (the CI smoke mode)",
    )
    args = ap.parse_args(argv)
    rows = mixed_step() if args.mixed_step else run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")


if __name__ == "__main__":
    main()

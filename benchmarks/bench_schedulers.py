"""Scheduler micro-benchmarks: partitioner overhead must be negligible vs a
training step (it runs on the host every step under CA-DAS)."""

from __future__ import annotations

from benchmarks.harness import Row, time_fn
from repro.core import schedule as S
from repro.core.asymmetric import AsymmetricMesh, DeviceClass


def run() -> list[Row]:
    rows = []
    us = time_fn(lambda: S.sas_partition(4096, [3.0, 1.0], tiles=[152, 32]), reps=20)
    rows.append(Row("sched_sas_partition_4096", us, "per-step host overhead"))

    us = time_fn(lambda: S.das_schedule(4096, [4.0, 1.0], [152, 32]), reps=20)
    rows.append(Row("sched_das_schedule_4096", us, "discrete-event greedy"))

    am = AsymmetricMesh(
        [DeviceClass("a", chips_per_pod=256),
         DeviceClass("b", chips_per_pod=256, rel_throughput=0.35)],
        strategy="ca-das",
    )
    us = time_fn(lambda: am.batch_layout(256), reps=20)
    imb = am.imbalance(am.batch_layout(256))
    rows.append(Row("sched_batch_layout_256", us, f"imbalance={imb:.3f}"))
    return rows

"""Scheduler micro-benchmarks: partitioner overhead must be negligible vs a
training step (it runs on the host every step under CA-DAS).

Also the wallclock feed for the Section-5.2.2 ratio calibration:
:func:`measure_class_step_times` times the probe GEMM under each device
class's execution context and returns the per-class
:class:`~repro.tuning.ratio.ClassMeasurement` records that
``AsymmetricMesh.from_calibration(backend="wallclock", measurements=...)``
consumes.  On this one-CPU host the classes measure ~equal (the honest
answer — the hardware *is* symmetric); on a real fleet the same records
come from per-pod step times and the calibration lands on the true ratio.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import Row, time_fn
from repro.core import schedule as S
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.kernels import ops
from repro.tuning.ratio import ClassMeasurement


def measure_class_step_times(
    classes, probe_shape=(384, 384, 384), reps: int = 3
) -> list[ClassMeasurement]:
    """Wallclock per-class probe steps: the probe GEMM under each class's
    execution context (its control tree picks backend + block shapes).

    ``units`` is the probe's row count — the same unit the chunk tables
    partition — so the records plug straight into
    ``calibrate_class_ratios(backend="wallclock", measurements=...)``.
    """

    am = AsymmetricMesh(classes, tree_shape=probe_shape)
    m, k, n = probe_shape
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = []
    for c in classes:
        with am.execution_context(c.name):
            us = time_fn(lambda: jax.block_until_ready(ops.gemm(a, b)), reps=reps)
        out.append(ClassMeasurement(name=c.name, units=m, seconds=us * 1e-6))
    return out


def run() -> list[Row]:
    rows = []
    us = time_fn(lambda: S.sas_partition(4096, [3.0, 1.0], tiles=[152, 32]), reps=20)
    rows.append(Row("sched_sas_partition_4096", us, "per-step host overhead"))

    us = time_fn(lambda: S.das_schedule(4096, [4.0, 1.0], [152, 32]), reps=20)
    rows.append(Row("sched_das_schedule_4096", us, "discrete-event greedy"))

    am = AsymmetricMesh(
        [DeviceClass("a", chips_per_pod=256),
         DeviceClass("b", chips_per_pod=256, rel_throughput=0.35)],
        strategy="ca-das",
    )
    us = time_fn(lambda: am.batch_layout(256), reps=20)
    imb = am.imbalance(am.batch_layout(256))
    rows.append(Row("sched_batch_layout_256", us, f"imbalance={imb:.3f}"))

    # Wallclock ratio calibration off measured per-class step times (the
    # ROADMAP item: feed calibrate_class_ratios real measurements).
    classes = biglittle_classes(chips_per_pod=1)
    meas = measure_class_step_times(classes)
    cal_mesh = AsymmetricMesh.from_calibration(
        classes, backend="wallclock", measurements=meas,
        strategy="ca-das", batch_tile=2,
    )
    total_us = sum(m.seconds for m in meas) * 1e6
    ratios = [round(float(r), 3) for r in cal_mesh.calibration.ratios]
    rows.append(
        Row("sched_wallclock_calibration", total_us,
            f"ratios={ratios} split={cal_mesh.batch_layout(64).sizes}")
    )
    return rows

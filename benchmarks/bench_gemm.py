"""Real wall-clock GEMM benchmarks on this host.

Three tiers:
  * XLA jnp.dot baseline (what the dry-run path lowers),
  * the blocked TPU-ref oracle (same arithmetic order as the Pallas grid),
  * the Pallas kernel in interpret mode on a small shape (correct-path
    sanity only — interpret mode is not a performance statement; the real
    perf path is Mosaic on TPU).

Also times the paper's coarse->fine empirical search protocol (Section 3.3)
over Pallas block configs using the XLA backend as the stand-in executor,
and compares the ``repro.tuning`` searched config against the analytical
default under the deterministic cost model (tuned-vs-analytical mode).

Besides the human-readable rows, every shape emits a machine-readable
record: the full (host-dependent wallclock) run writes
``artifacts/bench/BENCH_gemm_full.json``; ``--cost-model`` writes the
deterministic ``BENCH_gemm.json`` — the *committed* CI baseline — so a
local full-bench run never dirties the tracked perf trajectory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import Row, time_fn, write_csv, write_json
from repro.core.blocking import TPU_V5E, BlockConfig, derive_block_config, search_grid
from repro.kernels.gemm import GEMM_KERNELS
from repro.kernels.ref import blocked_gemm_tpu_ref, gemm_ref


def _gflops(m, k, n, us):
    return 2.0 * m * k * n / (us * 1e-6) / 1e9


def _record(impl, m, k, n, us, **extra):
    return {
        "bench": "gemm",
        "impl": impl,
        "shape": f"{m}x{k}x{n}",
        "m": m,
        "k": k,
        "n": n,
        "us_per_call": us,
        "gflops": _gflops(m, k, n, us),
        **extra,
    }


def tuned_vs_analytical(
    shapes=((512, 512, 512), (1024, 1024, 1024), (300, 1100, 200))
) -> tuple[list[Row], list[dict]]:
    """Cost-model comparison: searched config vs analytical default.

    Uses the deterministic ``repro.tuning`` cost-model backend so the
    comparison is reproducible on any host; on TPU the same search can be
    re-run with ``--backend wallclock`` via the tune CLI.  The micro-kernel
    variant is part of the search space; every record carries the chosen
    ``backend`` so the committed baseline guards the variant-selection
    path too.
    """

    from repro.tuning.candidates import KERNEL_BACKENDS
    from repro.tuning.measure import make_backend
    from repro.tuning.tune import search_shape

    rows, records = [], []
    backend = make_backend("cost-model", spec=TPU_V5E)
    for m, k, n in shapes:
        res = search_shape(
            m, k, n, spec=TPU_V5E, dtype_bytes=2, backend=backend,
            kernel_backends=KERNEL_BACKENDS,
        )
        rows.append(
            Row(
                f"gemm_tuned_vs_analytical_{m}x{k}x{n}",
                res.best_time_s * 1e6,
                f"speedup={res.speedup:.3f} tuned=({res.best.bm},{res.best.bk},"
                f"{res.best.bn})@{res.best_backend} analytical=({res.analytical.bm},"
                f"{res.analytical.bk},{res.analytical.bn})",
            )
        )
        records.append(
            _record(
                "tuned_cost_model", m, k, n, res.best_time_s * 1e6,
                analytical_us=res.analytical_time_s * 1e6,
                speedup_vs_analytical=res.speedup,
                tuned_block=[res.best.bm, res.best.bk, res.best.bn],
                analytical_block=[res.analytical.bm, res.analytical.bk, res.analytical.bn],
                backend=res.best_backend,
                n_candidates=res.n_candidates,
            )
        )
    return rows, records


def run(pallas_backends=None) -> list[Row]:
    if pallas_backends is None:
        pallas_backends = tuple(GEMM_KERNELS)  # every registered variant
    rows = []
    records = []
    rng = np.random.default_rng(0)

    # XLA baseline across sizes.  One jitted callable for every size:
    # jit's own trace cache handles the per-shape retrace.
    lines = []
    f = jax.jit(gemm_ref)
    for m in (256, 512, 1024):
        a = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)
        us = time_fn(lambda: jax.block_until_ready(f(a, b)), reps=7)
        g = _gflops(m, m, m, us)
        lines.append(f"xla,{m},{us:.1f},{g:.2f}")
        records.append(_record("xla", m, m, m, us))
        if m == 1024:
            rows.append(Row("gemm_xla_1024", us, f"gflops={g:.2f}"))

    # Blocked-ref (Pallas arithmetic order) vs XLA at 512.
    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    cfg = BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
    fb = jax.jit(lambda a, b: blocked_gemm_tpu_ref(a, b, cfg))
    us = time_fn(lambda: jax.block_until_ready(fb(a, b)), reps=5)
    lines.append(f"blocked_ref,512,{us:.1f},{_gflops(512,512,512,us):.2f}")
    records.append(_record("blocked_ref", 512, 512, 512, us))
    rows.append(Row("gemm_blocked_ref_512", us, f"gflops={_gflops(512,512,512,us):.2f}"))

    # Pallas interpret-mode correctness-path timing (small), per variant.
    ai = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    bi = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    for name in pallas_backends:
        kern = GEMM_KERNELS[name]
        us = time_fn(
            lambda: jax.block_until_ready(kern(ai, bi, cfg, interpret=True)),
            reps=3, warmup=1,
        )
        lines.append(f"{name}_interpret,256,{us:.1f},{_gflops(256,256,256,us):.2f}")
        records.append(
            _record(f"{name}_interpret", 256, 256, 256, us, note="not perf")
        )
        rows.append(
            Row(f"gemm_{name}_interpret_256", us, "correctness-path (not perf)")
        )
    write_csv("gemm_wallclock.csv", "impl,m,us,gflops", lines)

    # Section 3.3 protocol: coarse sweep -> refine around the winner.
    m = k = n = 512
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)

    def run_cfg(cfg):
        f = jax.jit(lambda a, b: blocked_gemm_tpu_ref(a, b, cfg))
        return time_fn(lambda: jax.block_until_ready(f(a, b)), reps=3, warmup=1)

    coarse = [c for c in search_grid(coarse=True) if c.bm <= 512 and c.bk <= 512][:6]
    results = [(run_cfg(c), c) for c in coarse]
    best_us, best_cfg = min(results, key=lambda x: x[0])
    analytic = derive_block_config(m, k, n, dtype_bytes=4)
    rows.append(
        Row(
            "gemm_cache_search_protocol",
            best_us,
            f"empirical=(bm={best_cfg.bm},bk={best_cfg.bk}) "
            f"analytic=(bm={analytic.bm},bk={analytic.bk})",
        )
    )
    records.append(
        _record(
            "cache_search_protocol", m, k, n, best_us,
            empirical_block=[best_cfg.bm, best_cfg.bk, best_cfg.bn],
            analytical_block=[analytic.bm, analytic.bk, analytic.bn],
        )
    )

    # Tuned-vs-analytical under the repro.tuning cost model.
    trows, trecords = tuned_vs_analytical()
    rows += trows
    records += trecords

    # Host-dependent wallclock records go to their own file — the plain
    # BENCH_gemm.json name is reserved for the committed CI baseline.
    write_json("BENCH_gemm_full.json", records, bench="gemm_full",
               spec=TPU_V5E.name)
    return rows


def run_cost_model() -> list[Row]:
    """CI mode: only the deterministic cost-model records.

    Writes ``artifacts/bench/BENCH_gemm.json`` with the tuned-vs-analytical
    cells — bit-stable across hosts, so the committed baseline diffs clean
    unless the tuned-config path itself changes (search, cost model, or
    analytical derivation): the perf-trajectory regression guard.
    """

    rows, records = tuned_vs_analytical()
    write_json("BENCH_gemm.json", records, bench="gemm_cost_model",
               spec=TPU_V5E.name)
    return rows


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench_gemm",
        description="GEMM benchmarks (wallclock tiers + tuned-vs-analytical).",
    )
    ap.add_argument(
        "--cost-model", action="store_true",
        help="deterministic tuned-vs-analytical records only (the CI baseline)",
    )
    ap.add_argument(
        "--backend", default="all", choices=sorted(GEMM_KERNELS) + ["all"],
        help="which Pallas micro-kernel variant the interpret tier times "
             "(wallclock mode only; the cost-model baseline always searches "
             "every variant)",
    )
    args = ap.parse_args(argv)
    variants = (
        tuple(GEMM_KERNELS)
        if args.backend == "all"  # repro: noqa=RPR005 -- CLI sentinel meaning "every variant", never dispatched
        else (args.backend,)
    )
    rows = run_cost_model() if args.cost_model else run(pallas_backends=variants)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")


if __name__ == "__main__":
    main()

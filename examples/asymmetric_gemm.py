"""The paper's exact experiment, end to end: one large GEMM whose row
space (Loop 3) is partitioned across two unequal device classes.

On this host both "classes" are CPU threads of the same speed, so the
*measured* imbalance is simulated by assigning the little class a slower
per-row rate — the partitioners, control trees, and blocked kernels are the
real production objects.  Prints the paper's Figure-9-style sweep.

Run:  PYTHONPATH=src python examples/asymmetric_gemm.py [--size 1536]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocking as B
from repro.core import schedule as S
from repro.core.control_tree import build_control_trees
from repro.core.execution import context_for_tree
from repro.kernels.ops import gemm
from repro.kernels.ref import gemm_ref


def run_partition(a, bm, table, trees):
    """Execute C = A @ B row-block-wise per the chunk table; returns C.

    Each class's row panel runs under *its own* execution context — the
    paper's Section-5.3 routing: the ambient control tree picks the block
    config and micro-kernel, the call site stays bare.
    """

    out = []
    for chunk in table.chunks:
        if chunk.size == 0:
            continue
        cls = "big" if chunk.cls == 0 else "little"
        rows = a[chunk.start : chunk.stop]
        with context_for_tree(trees[cls]):
            out.append(gemm(rows, bm))
    return jnp.concatenate(out, axis=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=1536)
    args = ap.parse_args()
    n = args.size

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    ref = gemm_ref(a, bmat)

    specs = {
        "big": B.TPU_V5E,
        "little": B.TpuCoreSpec(name="little", vmem_bytes=8 * 1024 * 1024),
    }
    trees = build_control_trees(specs, n, n, n, coarse_loop="rows")
    print("control trees:",
          {k: (t.block.bm, t.block.bk, t.block.bn) for k, t in trees.items()})

    # Simulated class rates (rows/s), big 4x little — the paper's ratio 4.
    rates = {"big": 4.0, "little": 1.0}

    print(f"\n{'schedule':24s} {'split':>12s} {'sim makespan':>13s} {'max|err|':>9s}")
    results = {}
    for name, table in [
        ("SSS (oblivious)", S.sss_partition(n, 2)),
        ("SAS ratio=2", S.sas_partition(n, [2.0, 1.0])),
        ("SAS ratio=4 (matched)", S.sas_partition(n, [4.0, 1.0])),
        ("CA-SAS ratio=4", S.ca_sas_partition(n, [4.0, 1.0], tiles=[152, 32])),
    ]:
        sizes = table.sizes()
        makespan = max(sizes[0] / rates["big"], sizes[1] / rates["little"])
        c = run_partition(a, bmat, table, trees)
        err = float(jnp.max(jnp.abs(c - ref)))
        results[name] = makespan
        print(f"{name:24s} {str(sizes):>12s} {makespan:12.1f}u {err:9.2e}")

    das = S.das_schedule(n, rates=[4.0, 1.0], strides=[152, 32])  # paper's m_c
    print(f"{'CA-DAS (no knob)':24s} {str(das.sizes()):>12s} {das.makespan:12.1f}u")
    assert das.makespan <= results["SSS (oblivious)"] * 0.55, "dynamic must beat SSS"
    print("\nCA-DAS reaches the matched-ratio makespan without knowing the ratio —")
    print("the paper's §5.4 result, on the production partitioners.")

    # -- the same routing as ONE SPMD step (true CA-SAS, §5.3) -------------
    # Above, each class's panel ran as a separate python-loop call.  With a
    # device per class the whole product runs as a single shard_map step in
    # which each pod's row shard executes under its own class's control
    # tree simultaneously.
    if jax.device_count() >= 2 and n % 2 == 0:
        from jax.sharding import PartitionSpec as P

        from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
        from repro.launch.mesh import make_host_mesh

        am = AsymmetricMesh(biglittle_classes(chips_per_pod=1),
                            tree_shape=(n // 2, n, n))
        step = am.class_sharded(
            lambda x, w: gemm(x, w),
            mesh=make_host_mesh(pod=2),
            in_specs=(P("pod"), P()), out_specs=P("pod"),
        )
        c = jax.jit(step)(a, bmat)
        err = float(jnp.max(jnp.abs(c - ref)))
        # Provenance names the micro-kernel variant per shard: on TPU at
        # large tree shapes little runs the VMEM-lean "pallas_lean" while
        # big keeps the pipelined "pallas" — two kernels, one SPMD step.
        shards = ", ".join(f"pod{p.pod}->{p.device_class}@{p.backend}"
                           for p in step.provenance)
        print(f"\nclass-sharded single step: {shards}; max|err|={err:.2e}")
        assert err < 1e-3


if __name__ == "__main__":
    main()

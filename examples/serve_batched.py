"""Serving example: batched greedy decoding with KV caches (and SSM states),
with the request batch split across heterogeneous classes by the paper's
schedulers.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch mixtral-8x7b]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.models import model_zoo as Z


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = Z.init_params(jax.random.PRNGKey(0), cfg)

    asym = AsymmetricMesh(biglittle_classes(chips_per_pod=1), strategy="ca-das",
                          batch_tile=1)
    print("request batch split across classes:", asym.chunk_table(args.batch).sizes())

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    seq_cap = args.prompt_len + args.gen_len
    decode = jax.jit(Z.make_decode_fn(cfg))
    state = Z.init_decode_state(cfg, args.batch, seq_cap)

    # Decode under the serving class's control tree: the ambient context
    # configures every projection matmul while the decode fn traces.
    exec_ctx = asym.execution_context()
    print(f"serving under device class {exec_ctx.device_class!r} "
          f"(backend={exec_ctx.backend()})")
    t0 = time.time()
    logits = None
    toks = [prompts]
    with exec_ctx:
        for t in range(args.prompt_len):
            logits, state = decode(params, {"tokens": prompts[:, t:t+1]}, state, jnp.int32(t))
        for t in range(args.prompt_len, seq_cap):
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            toks.append(nxt)
            logits, state = decode(params, {"tokens": nxt}, state, jnp.int32(t))
    out = jnp.concatenate(toks, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {args.gen_len} tokens x {args.batch} reqs "
          f"in {dt:.2f}s ({args.batch*args.gen_len/dt:.1f} tok/s)")
    print("sample continuation:", np.asarray(out[0, args.prompt_len:]).tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("done.")


if __name__ == "__main__":
    main()

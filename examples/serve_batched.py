"""Serving example: the persistent slot-table engine.

Demonstrates the request lifecycle the one-shot demo cannot: two waves of
requests flow through one long-lived :class:`ServingEngine` — per-class
queues, fused bulk prefill into the admitted slots, steady-state decode
with donated state and zero host relayout, slot reuse after completion —
with the request batch split across heterogeneous classes by the paper's
schedulers.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch mixtral-8x7b]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.models import model_zoo as Z
from repro.runtime.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = Z.init_params(jax.random.PRNGKey(0), cfg)

    asym = AsymmetricMesh(biglittle_classes(chips_per_pod=1), strategy="ca-das",
                          batch_tile=1)
    print("request batch split across classes:", asym.chunk_table(args.batch).sizes())

    rng = np.random.default_rng(0)
    seq_cap = args.prompt_len + args.gen_len + 4
    eng = ServingEngine(cfg, params, asym, seq_cap=seq_cap,
                        slots_per_pod=max(2, args.batch), class_sharded="auto")
    print(f"engine: {eng.n_pods} pods x {eng.c_max} slots, "
          f"class_sharded={eng.mixed}")

    # Wave 1: a homogeneous batch routed per the chunk table.
    wave1 = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32)
    out = eng.generate(wave1, args.gen_len)
    print(f"wave 1: {len(eng.completions)} done, sample continuation:",
          out[0, args.prompt_len:].tolist())

    # Wave 2: streaming submits with mixed prompt lengths — admitted over
    # successive rounds into the slots wave 1 freed, decoding concurrently
    # at heterogeneous slot positions.
    short = rng.integers(0, cfg.vocab, (args.prompt_len // 2,), dtype=np.int32)
    long = rng.integers(0, cfg.vocab, (args.prompt_len,), dtype=np.int32)
    eng.submit(short, args.gen_len)
    eng.submit(long, args.gen_len)
    done = {c.rid: c for c in eng.run()}
    for rid in sorted(done):
        c = done[rid]
        print(f"  rid={rid} pod={c.pod} class={c.device_class} slot={c.slot} "
              f"tokens={c.tokens[c.prompt_len:].tolist()}")

    st = eng.stats
    print(f"admitted={st.admitted} completed={st.completed} "
          f"admission_rounds={st.admission_rounds} host_relayouts={st.host_relayouts}")
    print(f"compile_s={st.compile_s:.2f} steady tokens/s={st.tokens_per_s:.1f}")
    print("done.")


if __name__ == "__main__":
    main()

"""End-to-end driver: train an LM with asymmetric CA-DAS scheduling,
fault injection, and checkpoint/restart — the full production loop on one
host (reduced config; pass --full on a real pod for the published dims).

Run:  PYTHONPATH=src python examples/train_asymmetric.py [--steps 60]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import shutil

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, DeviceClass
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)

    # A heterogeneous two-class fleet: pod1 runs at ~35 % throughput.
    asym = AsymmetricMesh(
        [DeviceClass("big", chips_per_pod=1),
         DeviceClass("little", chips_per_pod=1, rel_throughput=0.35)],
        strategy="ca-das",
        batch_tile=2,
    )

    fail_at = {args.steps // 2}

    def failure(step):
        if step in fail_at:
            fail_at.discard(step)
            print(f"  !! injected node failure at step {step} — restoring")
            raise SimulatedFailure(step)

    def pod_times(step):
        sizes = asym.batch_layout(16).sizes
        return [sizes[0] / 1.0 + 1e-9, sizes[1] / 0.35 + 1e-9]

    trainer = Trainer(
        cfg,
        make_host_mesh(),
        tcfg=TrainerConfig(steps=args.steps, global_batch=16, seq_len=64,
                           ckpt_dir=ckpt, ckpt_every=10),
        opt_cfg=AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=5),
        asym=asym,
        failure_hook=failure,
        pod_time_hook=pod_times,
    )
    print(f"training under device class {trainer.exec_ctx.device_class!r} "
          f"(backend={trainer.exec_ctx.backend()})")
    hist = trainer.run()
    print(f"arch={cfg.name} steps={len(hist)} restarts={trainer.restarts}")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    print(f"final CA-DAS batch split (big vs little): {asym.batch_layout(16).sizes}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print("done.")


if __name__ == "__main__":
    main()

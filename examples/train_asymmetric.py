"""End-to-end driver: train an LM with asymmetric CA-DAS scheduling,
fault injection, and checkpoint/restart — the full production loop on one
host (reduced config; pass --full on a real pod for the published dims).

Run:  PYTHONPATH=src python examples/train_asymmetric.py [--steps 60]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import shutil

import jax

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, DeviceClass
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)

    # A heterogeneous two-class fleet: pod1 runs at ~35 % throughput.
    asym = AsymmetricMesh(
        [DeviceClass("big", chips_per_pod=1),
         DeviceClass("little", chips_per_pod=1, rel_throughput=0.35)],
        strategy="ca-das",
        batch_tile=2,
    )

    fail_at = {args.steps // 2}

    def failure(step):
        if step in fail_at:
            fail_at.discard(step)
            print(f"  !! injected node failure at step {step} — restoring")
            raise SimulatedFailure(step)

    def pod_times(step):
        sizes = asym.batch_layout(16).sizes
        return [sizes[0] / 1.0 + 1e-9, sizes[1] / 0.35 + 1e-9]

    # With a host device per pod the step runs class-sharded: one SPMD
    # program in which the big pod's shard executes under big's control
    # tree and the little pod's under little's (the paper's two control
    # trees, §5.3 — not an approximation with a single primary tree).
    mesh = make_host_mesh(pod=asym.n_pods) if jax.device_count() >= asym.n_pods \
        else make_host_mesh()
    trainer = Trainer(
        cfg,
        mesh,
        tcfg=TrainerConfig(steps=args.steps, global_batch=16, seq_len=64,
                           ckpt_dir=ckpt, ckpt_every=10),
        opt_cfg=AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=5),
        asym=asym,
        failure_hook=failure,
        pod_time_hook=pod_times,
    )
    if trainer.class_sharded_step is not None:
        shards = ", ".join(f"pod{p.pod}->{p.device_class}[{p.block_source}]"
                           for p in trainer.class_sharded_step.provenance)
        print(f"class-sharded step: {shards}")
    else:
        print(f"training under device class {trainer.exec_ctx.device_class!r} "
              f"(backend={trainer.exec_ctx.backend()})")
    hist = trainer.run()
    print(f"arch={cfg.name} steps={len(hist)} restarts={trainer.restarts}")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    print(f"final CA-DAS batch split (big vs little): {asym.batch_layout(16).sizes}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print("done.")


if __name__ == "__main__":
    main()

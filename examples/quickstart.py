"""Quickstart: the paper's pipeline in five steps on one host.

  1. derive cache-aware blocking for two device classes (control trees),
  2. run the blocked Pallas GEMM (interpret mode) against the oracle,
  3. partition the GEMM row space across the classes with SSS vs CA-DAS,
  4. compare makespans on the calibrated big.LITTLE simulator,
  5. show the dynamic scheduler converging onto a straggler.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocking as B
from repro.core import schedule as S
from repro.core import simulator as sim
from repro.core.control_tree import build_control_trees
from repro.kernels.gemm import gemm_pallas
from repro.kernels.ref import gemm_ref

# 1. control trees -----------------------------------------------------------
specs = {
    "big": B.TPU_V5E,
    "little": B.TpuCoreSpec(name="tpu-little", vmem_bytes=8 * 1024 * 1024),
}
trees = build_control_trees(specs, 2048, 2048, 2048, coarse_loop="rows")
for name, t in trees.items():
    blk = t.block
    print(f"[1] {name:6s}: bm={blk.bm} bk={blk.bk} bn={blk.bn} "
          f"vmem={blk.vmem_bytes()/2**20:.1f} MiB")
print(f"    (paper analogue: A15 (m_c,k_c)=(152,952), A7 shared-k_c m_c=32)")

# 2. blocked GEMM vs oracle ---------------------------------------------------
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(256, 384)), jnp.float32)
bm = jnp.asarray(rng.normal(size=(384, 256)), jnp.float32)
cfg = B.BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
out = gemm_pallas(a, bm, cfg, interpret=True)
err = float(jnp.max(jnp.abs(out - gemm_ref(a, bm))))
print(f"[2] pallas blocked GEMM max|err| vs oracle: {err:.2e}")

# 2b. class-routed execution: the same call under each class's context —
# no config/backend threading; the ambient control tree decides.
from repro.core.execution import context_for_tree
from repro.kernels.ops import gemm

for name, t in trees.items():
    with context_for_tree(t):
        out_ctx = gemm(a, bm)
    err = float(jnp.max(jnp.abs(out_ctx - gemm_ref(a, bm))))
    print(f"[2b] gemm under {name!r} context (backend={t.backend}): "
          f"max|err|={err:.2e}")

# 3. partitioning -------------------------------------------------------------
sss = S.sss_partition(2048, 2)
cadas = S.das_schedule(2048, rates=[4.0, 1.0], strides=[152, 32])
print(f"[3] SSS row split: {sss.sizes()}   CA-DAS row split: {cadas.sizes()}")

# 4. simulator ----------------------------------------------------------------
r = 6144
res = {
    "A15-only": sim.simulate_single_cluster(r, sim.A15, 4).gflops,
    "SSS (oblivious)": sim.simulate_static(r).gflops,
    "SAS ratio=5": sim.simulate_static(r, ratio=5).gflops,
    "CA-DAS": sim.simulate_dynamic(r).gflops,
    "ideal": sim.ideal_gflops(r),
}
print("[4] simulated GFLOPS @", r)
for k, v in res.items():
    print(f"      {k:16s} {v:6.2f}")

# 5. dynamic convergence ------------------------------------------------------
d = S.DynamicScheduler(2, init_ratios=[1.0, 1.0], tiles=[8, 8])
for step in range(8):
    t = d.table(256)
    sizes = t.sizes()
    d.observe(sizes, [sizes[0] / 4.0 + 1e-9, sizes[1] / 1.0 + 1e-9])  # pod1 4x slower
print(f"[5] CA-DAS after observing a 4x straggler: split={d.table(256).sizes()}")
print("done.")

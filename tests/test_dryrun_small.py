"""Dry-run machinery integration test on a small forced-device mesh.

Runs in a subprocess because XLA pins the host device count at first init;
uses 8 placeholder devices (2 pods × 2 data × 2 model) to exercise the full
lower→compile→analyze path for one representative arch per family without
the production mesh's compile cost.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch.dryrun import build_cell
from repro.launch import hlo_analysis as H
from repro.launch.mesh import _mk

mesh = _mk((2, 2, 2), ("pod", "data", "model"))
out = {}
for arch, shape, donate in [
    ("internlm2-1.8b", "train_4k", (0, 1)),
    ("mixtral-8x7b", "decode_32k", (2,)),
    ("mamba2-1.3b", "long_500k", (2,)),
]:
    fn, args, in_sh, out_sh = build_cell(arch, shape, mesh)
    with mesh:
        compiled = jax.jit(
            fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
        ).lower(*args).compile()
    cost = H.analyze(compiled.as_text())
    ma = compiled.memory_analysis()
    out[f"{arch}:{shape}"] = {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_all_families():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(out) == 3
    for cell, stats in out.items():
        assert stats["flops"] > 0, cell
        assert stats["bytes"] > 0, cell
    # the multi-pod train cell must actually communicate
    assert out["internlm2-1.8b:train_4k"]["collective_bytes"] > 0
    # SSM long-context decode state is tiny
    assert out["mamba2-1.3b:long_500k"]["temp_gib"] < 4.0

"""Tests for ``repro.analysis`` — the project static verifier.

The fixture corpus under ``tests/fixtures/analysis`` reproduces the three
bug classes this repo actually shipped, each of which must surface under
its own stable code:

* PR-5: ``np.asarray`` pinning a donated trainer state  → **RPR002**
* PR-4: a tuned block exceeding its lane-padded problem → **RPR201**
* PR-2: backend-string vocabulary drift                 → **RPR005**

The corpus directory is pruned from recursive discovery (the repo tree
must stay clean) but analyzed when named explicitly — both sides are
tested here.
"""

import json
import os

import pytest

from repro.analysis import CODES, Diagnostic, analyze_file
from repro.analysis import cli as analysis_cli
from repro.analysis import configcheck, registry
from repro.analysis.diagnostics import format_github, format_json, render
from repro.core.execution import validate_registry

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fx(name):
    return os.path.join(FIXTURES, name)


def code_lines(diags):
    return sorted((d.code, d.line) for d in diags)


# ---------------------------------------------------------------------------
# Registry contracts (satellite: execution.validate_registry)
# ---------------------------------------------------------------------------


class TestRegistryContracts:
    def test_validate_registry_clean(self):
        assert validate_registry() == []

    def test_registry_check_clean(self):
        assert registry.check_registry() == []

    def test_shipped_trees_clean(self):
        assert configcheck.check_shipped_trees() == []

    def test_vocabulary_spans_both_registries(self):
        vocab = analysis_cli.build_vocabulary()
        assert {"xla", "pallas", "pallas_lean", "auto"} <= vocab
        # Measurement-scorer names are a separate vocabulary and must not
        # be flagged as backend drift.
        assert {"cost-model", "wallclock"} <= vocab

    def test_fault_point_vocabulary_tracks_live_registry(self):
        from repro.runtime.faults import FAULT_POINTS

        points = analysis_cli.build_fault_points()
        assert points == frozenset(FAULT_POINTS)
        assert {"engine_stall", "pod_death", "admission_fail",
                "latency_spike"} <= points


# ---------------------------------------------------------------------------
# AST passes over the fixture corpus
# ---------------------------------------------------------------------------


class TestFixtureCorpus:
    def test_donation_pin_bug_class(self):
        # The PR-5 class: both the inline host copy and the named one are
        # RPR002; the read-after-donate is RPR001; the same-statement
        # rebind idiom is untouched.
        diags = analyze_file(fx("donation_pin.py"))
        assert code_lines(diags) == [
            ("RPR001", 31),
            ("RPR002", 20),
            ("RPR002", 24),
        ]

    def test_jit_in_loop(self):
        diags = analyze_file(fx("jit_in_loop.py"))
        assert code_lines(diags) == [("RPR003", 10), ("RPR003", 11)]

    def test_contextvar_discipline(self):
        # Raw set flagged; finally-paired and __exit__-paired sets pass.
        diags = analyze_file(fx("contextvar_set.py"))
        assert code_lines(diags) == [("RPR004", 9)]

    def test_backend_drift_bug_class(self):
        # The PR-2 class: all four trigger forms, one line each.
        diags = analyze_file(fx("backend_drift.py"))
        assert code_lines(diags) == [
            ("RPR005", 11),
            ("RPR005", 12),
            ("RPR005", 13),
            ("RPR005", 14),
        ]

    def test_objective_drift_bug_class(self):
        # Same bug class, objective arm: comparison, keyword, and
        # validate_objective funnel — the valid tokens and the argparse
        # choices enum must pass.
        diags = analyze_file(fx("objective_drift.py"))
        assert code_lines(diags) == [
            ("RPR005", 11),
            ("RPR005", 12),
            ("RPR005", 13),
        ]
        assert all("schedule.OBJECTIVES" in d.message for d in diags)

    def test_fault_point_drift_bug_class(self):
        # The ISSUE-10 class: each trigger form (funnel argument, point=
        # keyword, FAULT_POINTS subscript) fires once; the valid-token
        # twin function passes.
        diags = analyze_file(fx("fault_point_drift.py"))
        assert code_lines(diags) == [
            ("RPR006", 13),
            ("RPR006", 15),
            ("RPR006", 16),
            ("RPR006", 17),
        ]
        assert all("injection registry" in d.message for d in diags)

    def test_fault_point_checks_off_without_vocabulary(self):
        # fault_points=None disables only the RPR006 arm.
        from repro.analysis import ast_checks

        with open(fx("fault_point_drift.py"), encoding="utf-8") as f:
            src = f.read()
        vocab = analysis_cli.build_vocabulary()
        assert ast_checks.run_ast_checks(
            fx("fault_point_drift.py"), src, vocab,
            objectives=analysis_cli.build_objectives(), fault_points=None,
        ) == []

    def test_objective_checks_off_without_vocabulary(self):
        # objectives=None disables only the objective arm; backend drift
        # still fires.
        from repro.analysis import ast_checks

        with open(fx("objective_drift.py"), encoding="utf-8") as f:
            src = f.read()
        vocab = analysis_cli.build_vocabulary()
        assert ast_checks.run_ast_checks(
            fx("objective_drift.py"), src, vocab, objectives=None
        ) == []

    def test_suppression_semantics(self):
        # A justified noqa silences its finding; a reason-less noqa
        # silences it too but is itself reported; a noqa on a multi-line
        # statement's closing line covers the statement.
        diags = analyze_file(fx("suppressed.py"))
        assert code_lines(diags) == [("RPR000", 19)]

    def test_clean_file_is_clean(self):
        assert analyze_file(fx("clean.py")) == []

    def test_three_bug_classes_have_distinct_codes(self):
        donation = {d.code for d in analyze_file(fx("donation_pin.py"))}
        drift = {d.code for d in analyze_file(fx("backend_drift.py"))}
        cache = {
            d.code
            for d in configcheck.check_tuning_cache_file(
                fx("oversized_block_cache.json")
            )
        }
        assert "RPR002" in donation and "RPR005" not in donation
        assert drift == {"RPR005"}
        assert cache == {"RPR201"}


# ---------------------------------------------------------------------------
# Config/artifact contracts over the fixture corpus
# ---------------------------------------------------------------------------


class TestConfigContracts:
    def test_oversized_block_is_pr4_class(self):
        diags = configcheck.check_tuning_cache_file(
            fx("oversized_block_cache.json")
        )
        assert len(diags) == 1
        (d,) = diags
        assert d.code == "RPR201"
        assert "lane-padded" in d.message and "PR-4" in d.message

    def test_good_cache_is_clean(self):
        assert configcheck.check_tuning_cache_file(fx("good_cache.json")) == []

    def test_non_cache_json_is_ignored(self):
        assert (
            configcheck.check_tuning_cache_file(fx("BENCH_malformed.json"))
            == []
        )

    def test_bench_artifact_schema(self):
        diags = configcheck.check_bench_artifact(fx("BENCH_malformed.json"))
        assert {d.code for d in diags} == {"RPR202"}
        msgs = " ".join(d.message for d in diags)
        assert "jax_version" in msgs  # missing provenance key named
        assert "records" in msgs

    def test_artifacts_dir_globs_bench_files(self):
        diags = configcheck.check_artifacts_dir(FIXTURES)
        assert diags and all(d.code == "RPR202" for d in diags)

    def test_objective_ab_block_schema(self, tmp_path):
        # The serving bench's energy A/B block: a well-formed block is
        # clean; dropping a column, faking the objective name, or losing
        # token identity each surface as RPR202.
        def artifact(ab):
            payload = {
                "meta": {"git_sha": "x", "jax_version": "y", "timestamp": "z"},
                "records": [{"name": "serve", "objective_ab": ab}],
            }
            p = tmp_path / "BENCH_serving.json"
            p.write_text(json.dumps(payload))
            return str(p)

        good = {
            "objective": "energy",
            "perf": {"energy_j": 7.5, "tokens_per_j": 4.0},
            "energy": {"energy_j": 2.9, "tokens_per_j": 10.2},
            "tokens_identical": True,
            "energy_ratio": 0.39,
            "throughput_ratio": 0.33,
        }
        assert configcheck.check_bench_artifact(artifact(good)) == []

        no_col = json.loads(json.dumps(good))
        del no_col["energy"]["energy_j"]
        diags = configcheck.check_bench_artifact(artifact(no_col))
        assert {d.code for d in diags} == {"RPR202"}
        assert "energy_j" in diags[0].message

        perf_named = dict(good, objective="perf")
        diags = configcheck.check_bench_artifact(artifact(perf_named))
        assert any("non-perf" in d.message for d in diags)

        diverged = dict(good, tokens_identical=False)
        diags = configcheck.check_bench_artifact(artifact(diverged))
        assert any("tokens_identical" in d.message for d in diags)


# ---------------------------------------------------------------------------
# CLI behavior
# ---------------------------------------------------------------------------


class TestCli:
    def test_corpus_run_is_dirty_and_exits_nonzero(self, capsys):
        rc = analysis_cli.main([FIXTURES, "--no-contracts", "--format", "json"])
        assert rc == 1
        payload = json.loads(capsys.readouterr().out)
        codes = {d["code"] for d in payload["diagnostics"]}
        assert {"RPR000", "RPR001", "RPR002", "RPR003", "RPR004", "RPR005",
                "RPR006", "RPR201"} <= codes

    def test_clean_file_exits_zero(self, capsys):
        rc = analysis_cli.main([fx("clean.py"), "--no-contracts"])
        capsys.readouterr()
        assert rc == 0

    def test_missing_path_exits_two(self, capsys):
        rc = analysis_cli.main(["no/such/path"])
        capsys.readouterr()
        assert rc == 2

    def test_fixtures_pruned_from_recursive_discovery(self):
        py, js = analysis_cli.discover([os.path.join(REPO_ROOT, "tests")])
        assert py and all("fixtures" not in p for p in py)
        assert all("fixtures" not in p for p in js)

    def test_repo_tree_is_clean(self, capsys, monkeypatch):
        # The acceptance gate: the analyzer over the real tree ends clean.
        monkeypatch.chdir(REPO_ROOT)
        rc = analysis_cli.main(["src", "tests", "benchmarks"])
        out = capsys.readouterr()
        assert rc == 0, out.out

    def test_list_codes(self, capsys):
        assert analysis_cli.main(["--list-codes"]) == 0
        assert set(json.loads(capsys.readouterr().out)) == set(CODES)


# ---------------------------------------------------------------------------
# Diagnostic model / output formats
# ---------------------------------------------------------------------------


class TestDiagnostics:
    def test_unknown_code_rejected(self):
        with pytest.raises(ValueError, match="unknown diagnostic code"):
            Diagnostic(code="RPR999", path="x.py", line=1, message="nope")

    def test_github_format_is_annotation(self):
        d = Diagnostic(code="RPR001", path="a.py", line=3, message="m", col=7)
        out = format_github([d])
        assert out.startswith("::error file=a.py,line=3,col=7,title=RPR001::")

    def test_json_format_round_trips(self):
        d = Diagnostic(code="RPR005", path="a.py", line=2, message="m")
        payload = json.loads(format_json([d]))
        assert payload["diagnostics"][0]["code"] == "RPR005"
        assert payload["codes"] == CODES

    def test_render_sorts_and_rejects_unknown_format(self):
        d1 = Diagnostic(code="RPR003", path="b.py", line=9, message="m")
        d2 = Diagnostic(code="RPR003", path="a.py", line=1, message="m")
        assert render([d1, d2], "text").splitlines()[0].startswith("a.py:1")
        with pytest.raises(ValueError, match="unknown format"):
            render([], "sarif")

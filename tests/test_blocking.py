"""Unit tests for the cache/VMEM blocking derivations (paper Section 3.3)."""

import pytest

from repro.core import blocking as B


class TestGotoDerivation:
    def test_a15_kc_matches_paper(self):
        # Paper's empirical optimum: k_c = 952.  The analytic L1 bound
        # lands within 5 %.
        d = B.derive_goto_blocking(B.CORTEX_A15)
        assert abs(d.kc - 952) / 952 < 0.05

    def test_a15_mc_order_of_paper(self):
        d = B.derive_goto_blocking(B.CORTEX_A15)
        assert 100 <= d.mc <= 220  # paper: 152

    def test_paper_values_satisfy_capacity(self):
        # The published optima must fit the caches they were tuned for.
        for cache, cfg in [(B.CORTEX_A15, B.PAPER_A15), (B.CORTEX_A7, B.PAPER_A7)]:
            assert cfg.b_micropanel_bytes() <= cache.l1_bytes
            assert cfg.a_panel_bytes() <= cache.l2_bytes

    def test_shared_kc_shrinks_mc(self):
        # Section 5.3: shared k_c = 952 forces the A7's m_c down
        # (paper finds 32; analytic bound must agree it is << 80).
        d = B.derive_goto_blocking(B.CORTEX_A7, shared_kc=952)
        assert d.kc == 952
        assert d.mc < B.PAPER_A7.mc
        assert B.GotoBlocking(mc=32, kc=952, nc=4096).a_panel_bytes() <= B.CORTEX_A7.l2_bytes

    def test_nc_without_l3(self):
        assert B.derive_goto_blocking(B.CORTEX_A15).nc == 4096

    def test_bigger_l2_bigger_mc(self):
        a15 = B.derive_goto_blocking(B.CORTEX_A15)
        a7 = B.derive_goto_blocking(B.CORTEX_A7)
        assert a15.mc > a7.mc


class TestTpuDerivation:
    def test_fits_vmem(self):
        cfg = B.derive_block_config(4096, 4096, 4096)
        assert cfg.fits(B.TPU_V5E)

    def test_mxu_alignment(self):
        cfg = B.derive_block_config(4096, 8192, 4096)
        assert cfg.bm % 128 == 0 and cfg.bn % 128 == 0 and cfg.bk % 128 == 0

    def test_small_problem_clamps(self):
        cfg = B.derive_block_config(64, 64, 64)
        assert cfg.bm == 128 and cfg.bn == 128  # min MXU tile

    def test_smaller_vmem_smaller_blocks(self):
        small = B.TpuCoreSpec(vmem_bytes=4 * 1024 * 1024)
        big_cfg = B.derive_block_config(4096, 4096, 4096)
        small_cfg = B.derive_block_config(4096, 4096, 4096, spec=small)
        assert small_cfg.vmem_bytes() < big_cfg.vmem_bytes()
        assert small_cfg.vmem_bytes() <= small.vmem_bytes * small.vmem_fill

    def test_intensity_monotone_in_block(self):
        a = B.BlockConfig(bm=256, bk=512, bn=256)
        b = B.BlockConfig(bm=128, bk=512, bn=128)
        assert a.arithmetic_intensity() > b.arithmetic_intensity()

    def test_pad_to_blocks(self):
        cfg = B.BlockConfig(bm=128, bk=256, bn=128)
        assert B.pad_to_blocks(130, 300, 127, cfg) == (256, 512, 128)

    def test_search_grid_all_fit(self):
        for cfg in B.search_grid(coarse=True):
            assert cfg.fits(B.TPU_V5E)
        assert len(B.search_grid(coarse=False)) > len(B.search_grid(coarse=True))

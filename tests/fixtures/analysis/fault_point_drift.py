"""Fixture: fault-injection point-name drift (RPR006).

Every literal below names an injection point the live
``runtime.faults.FAULT_POINTS`` registry does not know; each trigger
form gets one.  A misspelled point never fires — the plan silently
tests nothing.
"""

from repro.runtime.faults import FAULT_POINTS, FaultEvent, fault_active, validate_point


def plan_tick(engine, tick):
    if fault_active("pod_deth", engine=engine, tick=tick):  # line 13: RPR006 (funnel argument)
        return None
    validate_point("engine_stalled")  # line 15: RPR006 (funnel argument)
    ev = FaultEvent(point="admission_failure", engine=engine, tick=tick)  # line 16: RPR006 (keyword)
    doc = FAULT_POINTS["latency_spikes"]  # line 17: RPR006 (subscript)
    return ev, doc


def valid_tokens_pass(engine, tick):
    if fault_active("pod_death", engine=engine, tick=tick):
        return None
    validate_point("engine_stall")
    ev = FaultEvent(point="admission_fail", engine=engine, tick=tick)
    doc = FAULT_POINTS["latency_spike"]
    return ev, doc

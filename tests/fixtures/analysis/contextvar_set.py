"""Fixture: raw ContextVar.set outside the blessed helpers (RPR004)."""

import contextvars

_MODE = contextvars.ContextVar("mode", default=None)


def leaks_ambient_state(mode):
    _MODE.set(mode)  # line 9: RPR004 — no paired reset anywhere
    return compute()


def paired_with_finally(mode):
    token = _MODE.set(mode)  # paired: reset in finally — not flagged
    try:
        return compute()
    finally:
        _MODE.reset(token)


class ModeScope:
    def __init__(self, mode):
        self._mode = mode
        self._token = None

    def __enter__(self):
        self._token = _MODE.set(self._mode)  # paired via __exit__ below
        return self

    def __exit__(self, *exc):
        _MODE.reset(self._token)
        return False


def compute():
    return _MODE.get()

"""Fixture: suppression semantics.

One justified noqa (silences its finding), one reason-less noqa (is
itself the finding, RPR000), one multi-line statement carrying its noqa
on a continuation line.
"""

import jax


def justified(xs):
    for x in xs:
        f = jax.jit(lambda v: v)  # repro: noqa=RPR003 -- fixture: shape changes every pass anyway
        yield f(x)


def reasonless(xs):
    for x in xs:
        f = jax.jit(lambda v: v)  # repro: noqa=RPR003
        yield f(x)


def continuation_line(xs, kernel):
    for x in xs:
        call = jax.jit(
            kernel,
            static_argnums=(1,),
        )  # repro: noqa=RPR003 -- fixture: noqa rides the closing paren
        yield call(x, 0)

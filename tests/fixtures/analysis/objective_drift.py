"""Fixture: scheduling-objective string drift (RPR005, objective arm).

Every literal below is a misspelled or invented objective the live
``schedule.OBJECTIVES`` tuple does not know; each trigger form gets one.
"""

from repro.core.schedule import validate_objective


def pick(objective):
    if objective == "engery":  # line 11: RPR005 (comparison)
        return run(objective="performance")  # line 12: RPR005 (keyword)
    validate_objective("edp2")  # line 13: RPR005 (funnel argument)
    return objective


def valid_tokens_pass(objective, ap):
    if objective == "energy":
        return run(objective="perf")
    validate_objective("edp")
    # argparse enumerates its own choices; strings here are exempt.
    ap.add_argument("--objective", choices=("perf", "energy", "edp2"))
    return objective


def run(objective):
    return objective

"""Fixture: idiomatic code every pass must leave untouched."""

import jax
import numpy as np


def _train_step(params, batch, state):
    return state


STEP = jax.jit(_train_step, donate_argnums=(2,))


def train(params, batches, state):
    # Same-statement rebind: donation-safe; jit hoisted out of the loop.
    for batch in batches:
        state = STEP(params, batch, state)
    return state


def export(state):
    # Host copy of a *non-donated* value is fine.
    return np.asarray(state)


def pick(backend):
    if backend == "pallas_lean":
        return "lean"
    return "pipelined"

"""Fixture: the PR-2 backend-string drift bug class (RPR005).

Every literal below is a misspelled or legacy backend token the registry
does not know; each trigger form gets one.
"""

from repro.core.execution import BACKENDS, resolve_backend


def pick(backend):
    if backend == "palas":  # line 11: RPR005 (comparison)
        return run(backend="palas_lean")  # line 12: RPR005 (keyword)
    fn = BACKENDS["mosaic"]  # line 13: RPR005 (registry subscript)
    resolve_backend("xla_lite")  # line 14: RPR005 (funnel argument)
    return fn


def valid_tokens_pass(backend):
    if backend == "pallas_lean":
        return run(backend="xla")
    return resolve_backend("auto")


def run(backend):
    return backend

"""Fixture: the PR-5 donation-pin bug class (RPR002) + use-after-donate.

Never imported — parsed by the analyzer only.  Line numbers are asserted
by tests/test_analysis.py; keep edits append-only or update the tests.
"""

import jax
import numpy as np


def _step_fn(params, batch, state):
    return state


STEP = jax.jit(_step_fn, donate_argnums=(2,))


def train_pinned_direct(params, batch, state):
    # np host copy handed straight into the donated position.
    return STEP(params, batch, np.asarray(state))  # line 20: RPR002


def train_pinned_via_name(params, batch, state):
    host_state = np.asarray(state)  # line 24: RPR002 (origin of the pin)
    state = STEP(params, batch, host_state)
    return state


def train_use_after_donate(params, batch, state):
    new_state = STEP(params, batch, state)
    loss = state.mean()  # line 31: RPR001 — `state` was donated above
    return new_state, loss


def train_safe(params, batch, state):
    # The canonical safe idiom: rebind in the donating statement.
    state = STEP(params, batch, state)
    return state.mean(), state

"""Fixture: jit/pallas_call constructed per loop iteration (RPR003)."""

import jax
import jax.experimental.pallas as pl


def retraces_every_pass(xs, kernel):
    outs = []
    for x in xs:
        f = jax.jit(lambda v: v * 2)  # line 10: RPR003
        call = pl.pallas_call(kernel, out_shape=x)  # line 11: RPR003
        outs.append(f(call))
    return outs


def hoisted_is_fine(xs):
    f = jax.jit(lambda v: v * 2)
    return [f(x) for x in xs]


def nested_def_resets_scope(xs):
    for _ in xs:
        def helper(v):
            return jax.jit(lambda u: u)(v)  # nested scope: not flagged
    return helper

"""Fault-tolerant fleet layer: scheduling, injection, exactness.

The ISSUE-10 acceptance criteria, as tests:

  * **fault matrix** — under each injectable fault (engine stall, pod
    death, admission failure, latency spike) every submitted request
    completes exactly once with tokens **bit-identical** to a fault-free
    single-engine run, including requests migrated while queued and
    requests retried after an engine death;
  * **fault injection off is free** — no plan armed means the fault
    points reduce to one module-global ``None`` check and the fleet
    never consults a plan;
  * **health hysteresis** — ``unhealthy_after`` consecutive bad ticks
    trip an engine, ``healthy_after`` good ticks restore it;
  * **fleet parking** — under the energy objective the least efficient
    engine drains and gates at low load and re-admits as load ramps;
  * the engine's fleet surface (``withdraw`` / ``export_queued``) rolls
    the router's counts back so future routing reflects kept work only.

Real engines (row-local arch — greedy decode is a pure function of each
request's own prompt) prove bit-identity; the numpy ``fleetstub`` engine
covers the control-plane paths (health, parking, deadlines, streaming)
where jit time would buy nothing.
"""

import asyncio

import numpy as np
import pytest

import jax

from fleetstub import StubEngine, stub_tokens
from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, DeviceClass
from repro.core.schedule import deficit_route, fleet_scheduler
from repro.distributed import sharding as SH
from repro.models import model_zoo as Z
from repro.runtime import faults
from repro.runtime.fleet import Fleet
from repro.runtime.serving import ServingEngine

GEN_LEN = 6
SEQ_CAP = 32


@pytest.fixture(scope="module")
def zoo():
    cfg = get_config("internlm2-1.8b").reduced()
    SH.use_mesh_for_activations(None)
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, *, slots_per_pod=2):
    asym = AsymmetricMesh(
        [DeviceClass("only", chips_per_pod=1)], strategy="ca-das", batch_tile=1
    )
    return ServingEngine(
        cfg, params, asym, seq_cap=SEQ_CAP, slots_per_pod=slots_per_pod,
        class_sharded="off",
    )


def _requests(cfg, n=10):
    rng = np.random.default_rng(3)
    return [
        rng.integers(0, cfg.vocab, (4 if i % 2 else 8,), dtype=np.int32)
        for i in range(n)
    ]


def _run(fleet, prompts, plan=None):
    with faults.injected(plan) if plan else _null():
        for p in prompts:
            fleet.submit(p, GEN_LEN)
        fleet.run()
    return {c.rid: np.asarray(c.tokens) for c in fleet.completions}


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


@pytest.fixture(scope="module")
def reference(zoo):
    """Fault-free single-engine tokens: the exactness yardstick."""

    cfg, params = zoo
    fleet = Fleet([_engine(cfg, params)])
    return _run(fleet, _requests(cfg))


# ---------------------------------------------------------------------------
# The fault matrix: exactly-once, bit-identical under every fault type
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("point", sorted(faults.FAULT_POINTS))
def test_fault_matrix_bit_identical(zoo, reference, point):
    cfg, params = zoo
    prompts = _requests(cfg)
    plan = faults.FaultPlan(
        [faults.FaultEvent(point=point, engine=0, tick=2, duration=3)]
    )
    fleet = Fleet([_engine(cfg, params) for _ in range(2)])
    toks = _run(fleet, prompts, plan)

    assert fleet.stats.submitted == len(prompts)
    assert fleet.stats.completed == len(prompts)
    assert fleet.stats.duplicate_completions == 0
    assert set(toks) == set(reference)
    for rid in reference:
        assert np.array_equal(toks[rid], reference[rid]), (
            f"{point}: tokens diverged from fault-free run for rid={rid}"
        )
    if point == "pod_death":
        assert fleet.stats.engine_kills == 1
        assert sum(fleet._alive) == 1
        # The dead engine's queue migrated and its in-flight retried.
        assert fleet.stats.migrated > 0
        assert fleet.stats.retries > 0
    if point == "engine_stall":
        assert fleet.stats.stalled_ticks == 3
    if point == "admission_fail":
        assert fleet.stats.admission_faults == 3
    if point == "latency_spike":
        assert fleet.stats.latency_spikes == 3
        assert fleet.stats.migrated == 0  # perf fault, not a correctness one


def test_nofault_fleet_bit_identical(zoo, reference):
    cfg, params = zoo
    fleet = Fleet([_engine(cfg, params) for _ in range(2)])
    toks = _run(fleet, _requests(cfg))
    assert fleet.stats.completed == fleet.stats.submitted
    for rid in reference:
        assert np.array_equal(toks[rid], reference[rid])
    # Both engines actually served (the scheduler split the trace).
    assert all(e.stats.tokens > 0 for e in fleet.engines)


def test_queued_requests_migrate_off_dead_engine(zoo, reference):
    """Tiny slot tables force deep queues; the kill must migrate them."""

    cfg, params = zoo
    plan = faults.FaultPlan(
        [faults.FaultEvent(point="pod_death", engine=0, tick=2)]
    )
    fleet = Fleet([_engine(cfg, params, slots_per_pod=1) for _ in range(2)])
    toks = _run(fleet, _requests(cfg), plan)
    assert fleet.stats.completed == fleet.stats.submitted
    assert fleet.stats.migrated > 0
    for rid in reference:
        assert np.array_equal(toks[rid], reference[rid])
    # Everything finished on the survivor.
    assert all(c.engine == 1 for c in fleet.completions
               if c.attempts > 1 or c.migrations > 0)


# ---------------------------------------------------------------------------
# Fault plumbing: off is free, arming, validation, seeded plans
# ---------------------------------------------------------------------------


def test_fault_injection_off_is_free():
    # The off path is one module-global None check, mirroring trace._BUFFER.
    assert faults._PLAN is None
    assert not faults.armed()
    assert faults.fault_active("pod_death", engine=0, tick=1) is None


def test_arm_disarm_and_injected_restores():
    plan = faults.FaultPlan(
        [faults.FaultEvent(point="engine_stall", engine=0, tick=1)]
    )
    faults.arm(plan)
    try:
        assert faults.armed()
        assert faults.fault_active("engine_stall", engine=0, tick=1) is not None
        assert faults.fault_active("engine_stall", engine=1, tick=1) is None
        assert faults.fault_active("pod_death", engine=0, tick=1) is None
    finally:
        faults.disarm()
    assert not faults.armed()
    with pytest.raises(RuntimeError):
        with faults.injected(plan):
            assert faults.armed()
            raise RuntimeError("boom")
    assert not faults.armed()  # the context disarms on exceptions too


def test_fault_validation():
    with pytest.raises(ValueError):
        faults.validate_point("not_a_point")  # repro: noqa=RPR006 -- negative test: validation must reject drift
    with pytest.raises(ValueError):
        faults.FaultEvent(point="not_a_point", engine=0, tick=1)  # repro: noqa=RPR006 -- negative test: validation must reject drift
    with pytest.raises(ValueError):
        faults.FaultEvent(point="engine_stall", engine=-1, tick=1)
    plan = faults.FaultPlan(
        [faults.FaultEvent(point="engine_stall", engine=0, tick=1)]
    )
    with pytest.raises(ValueError):
        plan.active("not_a_point", 0, 1)


def test_seeded_plan_deterministic_and_keeps_survivor():
    a = faults.FaultPlan.seeded(11, n_engines=3, horizon=20, n_events=6)
    b = faults.FaultPlan.seeded(11, n_engines=3, horizon=20, n_events=6)
    assert a.events == b.events
    assert len(a.events) <= 6
    killed = {e.engine for e in a.events if e.point == "pod_death"}
    assert len(killed) < 3  # at least one engine survives every seeded plan
    for ev in a.events:
        assert ev.point in faults.FAULT_POINTS
        assert 0 <= ev.engine < 3


def test_pod_death_is_permanent():
    ev = faults.FaultEvent(point="pod_death", engine=0, tick=5)
    assert not ev.covers(4)
    assert ev.covers(5) and ev.covers(500)
    stall = faults.FaultEvent(point="engine_stall", engine=0, tick=5, duration=2)
    assert stall.covers(5) and stall.covers(6) and not stall.covers(7)


# ---------------------------------------------------------------------------
# Scheduling adapter: deficit routing over DAS shares
# ---------------------------------------------------------------------------


def test_deficit_route_tracks_weights():
    routed = [0, 0]
    for _ in range(30):
        routed[deficit_route([2.0, 1.0], routed)] += 1
    assert routed == [20, 10]


def test_deficit_route_validation():
    with pytest.raises(ValueError):
        deficit_route([0.0, 0.0], [0, 0])
    with pytest.raises(ValueError):
        deficit_route([1.0], [0, 0])
    with pytest.raises(ValueError):
        fleet_scheduler([])
    with pytest.raises(ValueError):
        fleet_scheduler([1.0, 0.0])


def test_fleet_routes_proportional_to_throughput():
    fast, slow = StubEngine(n_slots=8, speed=3.0), StubEngine(n_slots=8, speed=1.0)
    fleet = Fleet([fast, slow])
    for i in range(40):
        fleet.submit(np.asarray([i], np.int32), 2)
    assert abs(fleet._routed[0] - 30) <= 2  # ~3:1 split by calibrated tps


# ---------------------------------------------------------------------------
# Control plane on the stub: health, parking, deadlines, streaming
# ---------------------------------------------------------------------------


def _stub_fleet(n=2, **kw):
    return Fleet([StubEngine(n_slots=2) for _ in range(n)], **kw)


def test_health_hysteresis_trip_and_recover():
    fleet = _stub_fleet(unhealthy_after=2, healthy_after=2)
    plan = faults.FaultPlan(
        [faults.FaultEvent(point="engine_stall", engine=0, tick=1, duration=3)]
    )
    with faults.injected(plan):
        for i in range(12):
            fleet.submit(np.asarray([i], np.int32), 2)
        for _ in range(8):
            fleet.tick()
        assert fleet.stats.health_trips == 1
        assert fleet.stats.health_recoveries == 1
        assert fleet.health()["unhealthy"] == []
        fleet.run()
    assert fleet.stats.completed == fleet.stats.submitted
    assert fleet.stats.duplicate_completions == 0


def test_energy_objective_parks_and_unparks_engines():
    thrifty = StubEngine(n_slots=2, watts=1.0)
    hungry = StubEngine(n_slots=2, watts=100.0)
    fleet = Fleet([thrifty, hungry], objective="energy")
    fleet.submit(np.asarray([1], np.int32), 2)
    fleet.tick()
    assert fleet.health()["parked"] == [1]  # watts/rate orders the parking
    assert fleet.stats.engine_parks >= 1
    for i in range(6):  # load past the survivor's capacity re-admits
        fleet.submit(np.asarray([i], np.int32), 4)
    fleet.tick()
    assert fleet.stats.engine_unparks >= 1
    fleet.run()
    assert fleet.stats.completed == fleet.stats.submitted


def test_perf_objective_never_parks():
    fleet = _stub_fleet()
    fleet.submit(np.asarray([1], np.int32), 2)
    fleet.run()
    assert fleet.stats.engine_parks == 0


def test_deadline_requeues_stranded_request():
    # Skew routing hard onto engine 0 (1 slot), so the third request
    # queues behind a full table and its deadline moves it to engine 1.
    fleet = Fleet(
        [StubEngine(n_slots=1), StubEngine(n_slots=1)],
        rel_throughput=[1000.0, 1.0],
    )
    for i in range(3):
        fleet.submit(np.asarray([10 + i], np.int32), 8, deadline=1)
    for _ in range(4):
        fleet.tick()
    assert fleet.stats.deadline_requeues >= 1
    fleet.run()
    assert fleet.stats.completed == 3
    assert fleet.stats.duplicate_completions == 0


def test_withdraw_and_export_rollback_router_counts(zoo):
    cfg, params = zoo
    eng = _engine(cfg, params)
    rids = [eng.submit(p, GEN_LEN) for p in _requests(cfg, n=4)]
    routed_before = list(eng._routed)
    req = eng.withdraw(rids[1])
    assert req is not None and req.rid == rids[1]
    assert eng.withdraw(rids[1]) is None  # gone means gone
    assert sum(eng._routed) == sum(routed_before) - 1
    rest = eng.export_queued()
    assert [r.rid for r in rest] == [rids[0], rids[2], rids[3]]
    assert all(len(q) == 0 for q in eng.queues)
    assert sum(eng._routed) == 0


def test_stub_engine_matches_contract():
    eng = StubEngine(n_slots=2)
    prompt = np.asarray([5, 6, 7], np.int32)
    eng.submit(prompt, 4)
    eng.admit()
    while not eng.completions:
        eng.step()
    c = eng.completions[0]
    assert np.array_equal(c.tokens[:3], prompt)
    assert np.array_equal(c.tokens[3:], stub_tokens(prompt, 4))


# ---------------------------------------------------------------------------
# Async surface: streaming across the tick loop
# ---------------------------------------------------------------------------


def test_stream_yields_generated_tokens():
    async def main():
        fleet = _stub_fleet()
        prompt = np.asarray([3, 1, 4], np.int32)
        rid = await fleet.submit_async(prompt, 5)
        chunks = []

        async def consume():
            async for ch in fleet.stream(rid):
                chunks.append(np.asarray(ch))

        task = asyncio.ensure_future(consume())
        await fleet.run_async()
        await task
        got = np.concatenate(chunks)
        assert np.array_equal(got, stub_tokens(prompt, 5))
        done = await fleet.complete_async(rid)
        assert done.rid == rid

    asyncio.run(main())


def test_stream_consistent_across_engine_kill():
    async def main():
        plan = faults.FaultPlan(
            [faults.FaultEvent(point="pod_death", engine=0, tick=2)]
        )
        fleet = Fleet(
            [StubEngine(n_slots=1), StubEngine(n_slots=1)],
            rel_throughput=[1000.0, 1.0],  # pin the request to the victim
        )
        prompt = np.asarray([9, 9], np.int32)
        with faults.injected(plan):
            rid = await fleet.submit_async(prompt, 6)
            chunks = []

            async def consume():
                async for ch in fleet.stream(rid):
                    chunks.append(np.asarray(ch))

            task = asyncio.ensure_future(consume())
            await fleet.run_async()
            await task
        # The retry reproduces the identical prefix, so the stitched
        # stream is exactly the generated tokens, no repeats or holes.
        got = np.concatenate(chunks)
        assert np.array_equal(got, stub_tokens(prompt, 6))

    asyncio.run(main())


def test_all_engines_dead_raises():
    # Conservation failures are loud: losing the last engine with work
    # pending raises (from the kill's forced re-place or the run loop).
    fleet = Fleet([StubEngine(n_slots=1)])
    plan = faults.FaultPlan(
        [faults.FaultEvent(point="pod_death", engine=0, tick=1)]
    )
    with faults.injected(plan):
        fleet.submit(np.asarray([1], np.int32), 4)
        with pytest.raises(RuntimeError, match="engine"):
            fleet.run()

"""Property-based tests (hypothesis) for the system's core invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import blocking as B
from repro.core import schedule as S
from repro.core.asymmetric import AsymmetricMesh, DeviceClass


# ---------------------------------------------------------------------------
# Partitioners: exact coverage, proportionality, alignment
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 100000),
    k=st.integers(1, 8),
)
@settings(max_examples=200, deadline=None)
def test_sss_exact_coverage(n, k):
    t = S.sss_partition(n, k)
    t.validate()
    assert sum(t.sizes()) == n


@given(
    n=st.integers(1, 100000),
    ratios=st.lists(st.floats(0.01, 100.0), min_size=1, max_size=6),
)
@settings(max_examples=200, deadline=None)
def test_sas_exact_coverage(n, ratios):
    t = S.sas_partition(n, ratios)
    t.validate()
    assert sum(t.sizes()) == n


@given(
    n=st.integers(1, 50000),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_ca_sas_alignment_and_coverage(n, data):
    k = data.draw(st.integers(1, 4))
    ratios = data.draw(st.lists(st.floats(0.1, 20.0), min_size=k, max_size=k))
    tiles = data.draw(st.lists(st.integers(1, 256), min_size=k, max_size=k))
    t = S.ca_sas_partition(n, ratios, tiles)
    t.validate()
    sizes = t.sizes()
    assert sum(sizes) == n
    # Alignment holds unless a tile exceeds its class's proportional share
    # (the documented partial-panel fallback).
    raw = S.sas_partition(n, ratios).sizes()
    feasible = all(tl <= max(r, 1) for tl, r in zip(tiles, raw) if r > 0)
    if feasible:
        sink = int(np.argmin(tiles))
        for i, (sz, tile) in enumerate(zip(sizes, tiles)):
            if i != sink and sz > 0:
                assert sz % tile == 0, f"class {i} size {sz} not aligned to {tile}"


@given(
    n=st.integers(0, 200000),
    r=st.floats(0.5, 16.0),
)
@settings(max_examples=100, deadline=None)
def test_sas_monotone_in_ratio(n, r):
    """More ratio -> the fast class never gets less work."""

    lo = S.sas_partition(max(n, 1), [r, 1.0]).sizes()[0]
    hi = S.sas_partition(max(n, 1), [r * 1.5, 1.0]).sizes()[0]
    assert hi >= lo


@given(
    n=st.integers(1, 20000),
    rates=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=4),
)
@settings(max_examples=100, deadline=None)
def test_das_coverage_and_busy_consistency(n, rates):
    strides = [max(1, int(10 * r)) for r in rates]
    res = S.das_schedule(n, rates, strides)
    assert sum(res.sizes()) == n
    assert res.makespan >= max(res.busy) * 0.999
    # makespan equals some class's busy time (the last finisher)
    assert any(abs(res.makespan - b) < 1e-9 for b in res.busy)


@given(
    n=st.integers(2, 10000),
    fast=st.floats(1.5, 20.0),
)
@settings(max_examples=100, deadline=None)
def test_das_fast_class_gets_more(n, fast):
    res = S.das_schedule(n, [fast, 1.0], [8, 8])
    s = res.sizes()
    assert s[0] >= s[1] - 8  # within one chunk granule


# ---------------------------------------------------------------------------
# Blocking: VMEM capacity invariant over the whole search space
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 65536),
    k=st.integers(1, 65536),
    n=st.integers(1, 65536),
    vmem_mb=st.sampled_from([4, 8, 16, 32]),
)
@settings(max_examples=150, deadline=None)
def test_derived_blocks_always_fit(m, k, n, vmem_mb):
    spec = B.TpuCoreSpec(vmem_bytes=vmem_mb * 1024 * 1024)
    cfg = B.derive_block_config(m, k, n, spec=spec)
    assert cfg.vmem_bytes() <= spec.vmem_bytes * spec.vmem_fill
    assert cfg.bm % spec.mxu == 0 and cfg.bn % spec.mxu == 0 and cfg.bk % spec.mxu == 0


@given(
    l1=st.integers(8 * 1024, 256 * 1024),
    l2=st.integers(128 * 1024, 8 * 1024 * 1024),
)
@settings(max_examples=100, deadline=None)
def test_goto_derivation_capacity_invariant(l1, l2):
    cache = B.CacheHierarchy("x", l1_bytes=l1, l2_bytes=l2)
    d = B.derive_goto_blocking(cache)
    assert d.b_micropanel_bytes() <= l1
    assert d.a_panel_bytes() <= l2


# ---------------------------------------------------------------------------
# Asymmetric batch layout: masking preserves every row exactly once
# ---------------------------------------------------------------------------


@given(
    gb=st.integers(1, 512),
    r2=st.floats(0.05, 1.0),
    tile=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=100, deadline=None)
def test_batch_layout_mask_consistency(gb, r2, tile):
    am = AsymmetricMesh(
        [
            DeviceClass("big", chips_per_pod=4),
            DeviceClass("little", chips_per_pod=4, rel_throughput=r2),
        ],
        strategy="sas",
        batch_tile=tile,
    )
    layout = am.batch_layout(gb)
    assert sum(layout.sizes) == gb
    assert layout.mask.sum() == gb
    assert layout.c_max % tile == 0
    assert layout.c_max >= max(layout.sizes)


# ---------------------------------------------------------------------------
# Fleet: request conservation under arbitrary seeded fault plans
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 10_000),
    n_engines=st.integers(2, 4),
    n_requests=st.integers(1, 12),
    n_events=st.integers(0, 6),
)
@settings(max_examples=50, deadline=None)
def test_fleet_conservation_under_faults(seed, n_engines, n_requests, n_events):
    """Under ANY seeded fault plan: every request completes exactly once,
    tokens match the fault-free deterministic decode, and the fleet's
    counters reconcile with its trace instants (no silent drops, no
    silent duplicates, no unrecorded recovery actions)."""

    from fleetstub import StubEngine, stub_tokens
    from repro import observability as OBS
    from repro.runtime import faults as F
    from repro.runtime.fleet import Fleet

    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, 997, (int(rng.integers(1, 6)),)).astype(np.int32)
        for _ in range(n_requests)
    ]
    plan = F.FaultPlan.seeded(
        seed, n_engines=n_engines, horizon=12, n_events=n_events
    )
    engines = [
        StubEngine(
            n_slots=int(rng.integers(1, 3)), speed=float(rng.integers(1, 4))
        )
        for _ in range(n_engines)
    ]
    fleet = Fleet(engines, retry_backoff=1)
    OBS.enable()
    try:
        with F.injected(plan):
            for p in prompts:
                fleet.submit(p, 3)
            fleet.run()
    finally:
        buf = OBS.disable()

    # Exactly once: no drops, no duplicates, every rid accounted for.
    assert fleet.stats.completed == fleet.stats.submitted == n_requests
    assert fleet.stats.duplicate_completions == 0
    assert sorted(c.rid for c in fleet.completions) == list(range(n_requests))
    # Bit-identical to the fault-free decode of each request's own prompt.
    for c in fleet.completions:
        got = np.asarray(c.tokens)
        assert np.array_equal(got[: c.prompt_len], prompts[c.rid])
        assert np.array_equal(got[c.prompt_len:], stub_tokens(prompts[c.rid], 3))
    # Counters reconcile with the trace: each recovery action left a mark.
    names = [e.name for e in buf.events if e.ph == "i"]
    assert names.count("fleet.migrate") == fleet.stats.migrated
    assert names.count("fleet.retry") == fleet.stats.retries
    assert names.count("fleet.engine_kill") == fleet.stats.engine_kills

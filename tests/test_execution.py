"""Class-routed execution contexts: dispatch, nesting, per-class routing.

Covers the PR-2 acceptance criteria: with no context active, ``ops.gemm``
behaves bit-identically to the pre-context defaults; with a ``biglittle``
context active (and a tuning cache set), each class's matmuls run under
its own tuned control tree.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import blocking as B
from repro.core import execution as X
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.core.control_tree import build_control_trees
from repro.kernels import ref
from repro.kernels.gemm import gemm_pallas
from repro.kernels.ops import gemm, gemm_with_tree
from repro.tuning import cache as C
from repro.tuning import ratio as R

RNG = np.random.default_rng(3)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _ctx(backend="xla", spec=B.TPU_V5E, shape=(256, 256, 256), name="t"):
    tree = build_control_trees({name: spec}, *shape, backend=backend)[name]
    return X.context_for_tree(tree)


# ---------------------------------------------------------------------------
# Context nesting / restore semantics
# ---------------------------------------------------------------------------


class TestContextScoping:
    def test_nesting_and_restore(self):
        assert X.current_context() is None
        a, b = _ctx(name="a"), _ctx(name="b")
        with a:
            assert X.current_context() is a
            with b:
                assert X.current_context() is b
                with a:  # reentrancy: the same object can nest again
                    assert X.current_context() is a
                assert X.current_context() is b
            assert X.current_context() is a
        assert X.current_context() is None

    def test_restore_on_exception(self):
        ctx = _ctx()
        with pytest.raises(RuntimeError):
            with ctx:
                raise RuntimeError("boom")
        assert X.current_context() is None

    def test_shared_context_concurrent_threads(self):
        # One long-lived context (e.g. a Trainer's) entered from several
        # threads: token stacks are thread-local, so exits never pop
        # another thread's token.
        import threading

        ctx = _ctx()
        errors = []

        def worker():
            try:
                for _ in range(50):
                    with ctx:
                        assert X.current_context() is ctx
                assert X.current_context() is None
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors

    def test_shared_context_interleaved_async_tasks(self):
        # Two asyncio tasks on one thread enter/exit the same context in
        # interleaved order; token stacks are per-task (ContextVar), so
        # neither task can pop the other's token.
        import asyncio

        ctx = _ctx()

        async def main():
            a_in, b_in, a_out = asyncio.Event(), asyncio.Event(), asyncio.Event()

            async def task_a():
                with ctx:
                    a_in.set()
                    await b_in.wait()  # b enters while a is inside
                a_out.set()
                assert X.current_context() is None

            async def task_b():
                await a_in.wait()
                with ctx:
                    b_in.set()
                    await a_out.wait()  # a exits while b is inside
                assert X.current_context() is None

            await asyncio.gather(task_a(), task_b())

        asyncio.run(main())
        assert X.current_context() is None

    def test_backend_table_is_the_vocabulary(self):
        assert set(X.BACKEND_NAMES) == {
            "xla",
            "pallas",
            "pallas_interpret",
            "pallas_lean",
            "pallas_lean_interpret",
            "paged_attn_xla",
            "paged_attn_pallas",
            "paged_attn_pallas_interpret",
        }
        # The table spans two op families; the GEMM view is the old set.
        assert set(X.GEMM_BACKEND_NAMES) == {
            "xla",
            "pallas",
            "pallas_interpret",
            "pallas_lean",
            "pallas_lean_interpret",
        }
        assert set(X.BACKEND_OPS) == set(X.BACKENDS)
        with pytest.raises(ValueError, match="unknown backend"):
            X.resolve_backend("mosaic")  # repro: noqa=RPR005 -- negative test: unknown name must raise
        # Op-family guards: a GEMM resolver must reject an attention
        # kernel and vice versa — a tree or CLI flag can never route a
        # GEMM into a paged-attention kernel.
        with pytest.raises(ValueError, match="not a GEMM"):
            X.resolve_backend("paged_attn_xla")
        with pytest.raises(ValueError, match="not a paged-attention"):
            X.resolve_paged_attn_backend("pallas")
        assert X.resolve_paged_attn_backend("auto") in X.BACKENDS
        # auto resolves to a concrete table entry (xla on this CPU host).
        assert X.resolve_backend("auto") in X.BACKENDS
        # Every table entry has a CPU-runnable interpret twin and a
        # buffering model — the invariants the parity harness and the
        # control trees rely on.
        for name in X.BACKENDS:
            assert X.interpret_twin(name) in X.BACKENDS
            assert isinstance(X.backend_double_buffers(name), bool)
        assert X.interpret_twin("pallas_lean") == "pallas_lean_interpret"
        assert not X.backend_double_buffers("pallas_lean")
        assert X.align_backend_family("pallas_lean", "pallas_interpret") \
            == "pallas_lean_interpret"
        assert X.align_backend_family("pallas_lean", "pallas") == "pallas_lean"
        # The family mapping is symmetric (regression): an interpret name
        # that leaked into a cache must come back compiled on a hardware
        # tree, never run the Python interpreter silently.
        assert X.align_backend_family("pallas_lean_interpret", "pallas") \
            == "pallas_lean"
        assert X.align_backend_family("pallas_interpret", "pallas") == "pallas"


# ---------------------------------------------------------------------------
# No context == today's defaults (bit-identical)
# ---------------------------------------------------------------------------


class TestNoContextDefaults:
    def test_bare_gemm_matches_explicit_xla(self):
        a, b = _rand((130, 70)), _rand((70, 50))
        base = gemm(a, b)  # auto -> xla on CPU, no context
        explicit = gemm(a, b, backend="xla")
        assert np.array_equal(np.asarray(base), np.asarray(explicit))

    def test_xla_context_is_behavior_neutral(self):
        a, b = _rand((2, 3, 64)), _rand((64, 32))
        base = gemm(a, b)
        with _ctx(backend="xla"):
            under_ctx = gemm(a, b)
        assert np.array_equal(np.asarray(base), np.asarray(under_ctx))

    def test_explicit_args_win_over_context(self):
        a, b = _rand((130, 70)), _rand((70, 50))
        base = gemm(a, b, backend="xla")
        with _ctx(backend="pallas_interpret", shape=(130, 70, 50)):
            forced = gemm(a, b, backend="xla")
        assert np.array_equal(np.asarray(base), np.asarray(forced))

    def test_resolve_block_config_defaults_analytical(self, monkeypatch):
        monkeypatch.delenv(C.ENV_VAR, raising=False)
        cfg, src = X.resolve_block_config(256, 256, 256, dtype_bytes=4,
                                          dtype_name="float32")
        assert src == "analytical"
        assert cfg == B.derive_block_config(256, 256, 256, dtype_bytes=4)


# ---------------------------------------------------------------------------
# Per-class routing under a biglittle mesh
# ---------------------------------------------------------------------------


class TestPerClassRouting:
    def test_biglittle_trees_differ(self):
        am = AsymmetricMesh(biglittle_classes(), tree_shape=(4096, 4096, 4096))
        trees = am.control_trees()
        big, little = trees["big"], trees["little"]
        assert big.block.bk == little.block.bk  # shared B panel (Loop 3)
        assert little.block.bm <= big.block.bm
        assert little.block.vmem_bytes() <= B.TPU_LITTLE.vmem_bytes * B.TPU_LITTLE.vmem_fill
        assert big.spec is B.TPU_V5E and little.spec is B.TPU_LITTLE

    def test_default_context_is_fastest_class(self):
        am = AsymmetricMesh(biglittle_classes())
        assert am.execution_context().device_class == "big"
        assert am.execution_context("little").device_class == "little"
        with pytest.raises(KeyError):
            am.execution_context("medium")

    def test_context_selects_class_tree(self):
        am = AsymmetricMesh(biglittle_classes(), tree_shape=(4096, 4096, 4096))
        trees = am.control_trees()
        with am.execution_context("little") as ctx:
            assert X.current_context().tree is trees["little"]
            assert ctx.spec is B.TPU_LITTLE
        with am.execution_context("big"):
            assert X.current_context().tree is trees["big"]

    def test_gemm_under_class_context_matches_oracle(self):
        # End to end through the interpret kernel: each class's context
        # produces the correct product with its own block shapes.
        a, b = _rand((256, 256)), _rand((256, 256))
        am = AsymmetricMesh(
            biglittle_classes(), tree_shape=(256, 256, 256),
            backend="pallas_interpret",
        )
        expect = np.asarray(ref.gemm_ref(a, b))
        for name in ("big", "little"):
            with am.execution_context(name):
                out = gemm(a, b)
            np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-4)

    def test_anchor_is_fastest_class_regardless_of_listing_order(self):
        # Slow class listed first must NOT anchor the shared B panel: the
        # trees sort by throughput, so big's bk anchors and little
        # re-derives — identical to the big-first listing.
        big, little = biglittle_classes()
        reversed_mesh = AsymmetricMesh([little, big], tree_shape=(4096, 4096, 4096))
        canonical = AsymmetricMesh([big, little], tree_shape=(4096, 4096, 4096))
        for name in ("big", "little"):
            assert (
                reversed_mesh.control_trees()[name].block
                == canonical.control_trees()[name].block
            )
        assert reversed_mesh.execution_context().device_class == "big"

    def test_gemm_with_tree_uses_trees_block(self):
        # The canonical-shape call reuses tree.block verbatim (shared-panel
        # structure preserved) — bit-identical to the explicit-config call.
        a, b = _rand((256, 256)), _rand((256, 256))
        tree = build_control_trees(
            {"x": B.TPU_V5E}, 256, 256, 256, backend="pallas_interpret"
        )["x"]
        via_tree = gemm_with_tree(a, b, tree)
        explicit = gemm_pallas(a, b, tree.block, interpret=True)
        assert np.array_equal(np.asarray(via_tree), np.asarray(explicit))


# ---------------------------------------------------------------------------
# Cache-hit vs analytical-fallback paths
# ---------------------------------------------------------------------------


def _write_biglittle_cache(tmp_path, big_cfg, little_cfg, m, k, n,
                           dtype_name="float32"):
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put(B.TPU_V5E.name, dtype_name, m, k, n, big_cfg, backend="test")  # repro: noqa=RPR005 -- fixture provenance label, not a dispatch token
    cache.put(B.TPU_LITTLE.name, dtype_name, m, k, n, little_cfg, backend="test")  # repro: noqa=RPR005 -- fixture provenance label, not a dispatch token
    cache.save()
    return path


class TestTunedRouting:
    def test_trees_consume_per_class_cache(self, tmp_path, monkeypatch):
        # Distinctive tuned entries the analytical route would not pick;
        # same bk so the shared-B-panel constraint admits both.
        big_cfg = B.BlockConfig(bm=256, bk=128, bn=128, dtype_bytes=4)
        little_cfg = B.BlockConfig(bm=128, bk=128, bn=256, dtype_bytes=4)
        path = _write_biglittle_cache(tmp_path, big_cfg, little_cfg, 256, 256, 256)
        monkeypatch.setenv(C.ENV_VAR, path)

        trees = build_control_trees(
            {"big": B.TPU_V5E, "little": B.TPU_LITTLE}, 256, 256, 256,
            dtype_bytes=4,
        )
        assert trees["big"].block_source == "tuned"
        assert trees["big"].block == big_cfg
        assert trees["little"].block_source == "tuned"
        assert trees["little"].block == little_cfg

    def test_tuned_entry_with_mismatched_bk_rejected(self, tmp_path, monkeypatch):
        # Under Loop-3 row partitioning the B panel is shared: a little
        # entry disagreeing on bk must fall back to the bm re-derivation.
        big_cfg = B.BlockConfig(bm=256, bk=128, bn=128, dtype_bytes=4)
        little_cfg = B.BlockConfig(bm=128, bk=256, bn=128, dtype_bytes=4)
        path = _write_biglittle_cache(tmp_path, big_cfg, little_cfg, 256, 256, 256)
        monkeypatch.setenv(C.ENV_VAR, path)

        trees = build_control_trees(
            {"big": B.TPU_V5E, "little": B.TPU_LITTLE}, 256, 256, 256,
            dtype_bytes=4,
        )
        assert trees["little"].block_source == "analytical"
        assert trees["little"].block.bk == big_cfg.bk  # shared bk wins

    def test_analytical_fallback_without_cache(self, monkeypatch):
        monkeypatch.delenv(C.ENV_VAR, raising=False)
        trees = build_control_trees(
            {"big": B.TPU_V5E, "little": B.TPU_LITTLE}, 512, 512, 512
        )
        assert {t.block_source for t in trees.values()} == {"analytical"}

    def test_biglittle_matmuls_run_under_own_tuned_tree(self, tmp_path, monkeypatch):
        """The acceptance criterion end to end: REPRO_TUNING_CACHE set,
        biglittle contexts active — each class's gemm demonstrably executes
        with its own tuned block config (bit-equal to the explicit call)."""

        m = k = n = 256
        # Distinctive bm/bn per class; bk=256 agrees with the (bf16) tree's
        # shared B panel, so the rows-coarse guard admits both entries.
        big_cfg = B.BlockConfig(bm=256, bk=256, bn=128, dtype_bytes=4)
        little_cfg = B.BlockConfig(bm=128, bk=256, bn=256, dtype_bytes=4)
        path = _write_biglittle_cache(tmp_path, big_cfg, little_cfg, m, k, n)
        monkeypatch.setenv(C.ENV_VAR, path)

        am = AsymmetricMesh(
            biglittle_classes(), tree_shape=(m, k, n), backend="pallas_interpret"
        )
        a, b = _rand((m, k)), _rand((k, n))
        for name, tuned in (("big", big_cfg), ("little", little_cfg)):
            with am.execution_context(name) as ctx:
                # Per-call resolution hits this class's cache entry (the
                # mesh trees themselves are bf16-keyed; the f32 call
                # re-resolves against the class's spec).
                assert ctx.block_config(m, k, n, "float32", 4) == tuned
                out = gemm(a, b)
            explicit = gemm_pallas(a, b, tuned, interpret=True)
            assert np.array_equal(np.asarray(out), np.asarray(explicit)), name

    def test_dtype_relabel_preserves_shared_panel(self, monkeypatch):
        # A float32 call at the canonical shape of a bf16-keyed tree keeps
        # the tree's block *shapes* (shared bk intact), only re-labelling
        # the operand bytes — it must not silently re-derive per spec.
        monkeypatch.delenv(C.ENV_VAR, raising=False)
        am = AsymmetricMesh(biglittle_classes(), tree_shape=(512, 512, 512))
        trees = am.control_trees()
        little = am.execution_context("little")
        cfg = little.block_config(512, 512, 512, "float32", 4)
        blk = trees["little"].block
        assert (cfg.bm, cfg.bk, cfg.bn) == (blk.bm, blk.bk, blk.bn)
        assert cfg.dtype_bytes == 4
        assert cfg.bk == trees["big"].block.bk  # shared B panel survives

    def test_context_rejects_tuned_entry_off_shared_bk(self, tmp_path,
                                                       monkeypatch):
        # Same rule as build_control_trees: under a rows-coarse tree, a
        # per-call tuned entry disagreeing on the shared bk is rejected —
        # the dtype-relabelled tree block (panel intact) wins instead.
        m = k = n = 256
        off_bk = B.BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
        path = _write_biglittle_cache(tmp_path, off_bk, off_bk, m, k, n)
        monkeypatch.setenv(C.ENV_VAR, path)

        am = AsymmetricMesh(biglittle_classes(), tree_shape=(m, k, n))
        trees = am.control_trees()  # bf16-keyed: bk=256 shared panel
        assert trees["little"].block.bk == 256
        ctx = am.execution_context("little")
        cfg = ctx.block_config(m, k, n, "float32", 4)
        assert cfg.bk == 256  # relabelled tree block, not the off-bk entry
        assert cfg.dtype_bytes == 4

    def test_dtype_relabel_falls_back_when_vmem_overflows(self, monkeypatch):
        # At 1024^3 the bf16 blocks nearly fill VMEM; the f32 relabel does
        # not fit, so safety wins: re-derive a block this class can hold.
        monkeypatch.delenv(C.ENV_VAR, raising=False)
        am = AsymmetricMesh(biglittle_classes(), tree_shape=(1024, 1024, 1024))
        little = am.execution_context("little")
        cfg = little.block_config(1024, 1024, 1024, "float32", 4)
        assert cfg.fits(B.TPU_LITTLE)

    def test_hand_built_tree_block_is_authoritative(self):
        # ControlTree built directly (problem_shape=None): gemm_with_tree
        # must honor its block verbatim, as before the context layer.
        from repro.core.control_tree import ControlTree

        custom = B.BlockConfig(bm=128, bk=128, bn=256, dtype_bytes=4)
        tree = ControlTree(device_class="x", block=custom,
                           backend="pallas_interpret")
        a, b = _rand((256, 256)), _rand((256, 256))
        via_tree = gemm_with_tree(a, b, tree)
        explicit = gemm_pallas(a, b, custom, interpret=True)
        assert np.array_equal(np.asarray(via_tree), np.asarray(explicit))

    def test_hand_built_tree_clamps_to_smaller_call_shapes(self):
        # Regression: a hand-built tree applies to every call shape; a
        # 512-row block reused for a 128-row matmul must clamp to the
        # lane-padded call dims (pre-validation it silently padded; the
        # kernels' shape validation would now reject the oversize block).
        from repro.core.control_tree import ControlTree

        custom = B.BlockConfig(bm=512, bk=128, bn=256, dtype_bytes=4)
        tree = ControlTree(device_class="x", block=custom,
                           backend="pallas_interpret")
        ctx = X.context_for_tree(tree)
        cfg = ctx.block_config(128, 128, 64, "float32", 4)
        assert (cfg.bm, cfg.bk, cfg.bn) == (128, 128, 128)
        a, b = _rand((128, 128)), _rand((128, 64))
        via_tree = gemm_with_tree(a, b, tree)
        np.testing.assert_allclose(
            np.asarray(via_tree), np.asarray(ref.gemm_ref(a, b)),
            rtol=1e-5, atol=1e-4,
        )

    def test_hand_built_tree_beats_cache_across_dtypes(self, tmp_path,
                                                       monkeypatch):
        # A tuned cache entry must not override a hand-picked block even
        # when the call dtype differs from the block's: the relabelled
        # hand-built shapes win over the cache.
        from repro.core.control_tree import ControlTree

        cached = B.BlockConfig(bm=512, bk=128, bn=256, dtype_bytes=4)
        path = str(tmp_path / "cache.json")
        cache = C.TuningCache(path=path)
        cache.put(B.TPU_V5E.name, "float32", 256, 256, 256, cached, backend="t")  # repro: noqa=RPR005 -- fixture provenance label, not a dispatch token
        cache.save()
        monkeypatch.setenv(C.ENV_VAR, path)

        custom = B.BlockConfig(bm=256, bk=128, bn=128, dtype_bytes=2)
        tree = ControlTree(device_class="x", block=custom)
        ctx = X.context_for_tree(tree)
        cfg = ctx.block_config(256, 256, 256, "float32", 4)
        assert (cfg.bm, cfg.bk, cfg.bn) == (256, 128, 128)
        assert cfg.dtype_bytes == 4

    def test_context_block_config_resolves_off_bucket_shapes(self, tmp_path,
                                                             monkeypatch):
        # A call outside the tree's shape bucket re-resolves per spec: the
        # little class must get a block fitting its own (smaller) VMEM.
        monkeypatch.delenv(C.ENV_VAR, raising=False)
        am = AsymmetricMesh(biglittle_classes(), tree_shape=(256, 256, 256))
        ctx = am.execution_context("little")
        cfg = ctx.block_config(4096, 4096, 4096, "bfloat16", 2)
        assert cfg.fits(B.TPU_LITTLE)
        assert cfg == B.derive_block_config(4096, 4096, 4096, spec=B.TPU_LITTLE)


# ---------------------------------------------------------------------------
# CA tiles regression (satellite: slower classes get smaller strides)
# ---------------------------------------------------------------------------


class TestCaTiles:
    def test_biglittle_tiles_distinct(self):
        am = AsymmetricMesh(biglittle_classes(), strategy="ca-das", batch_tile=8)
        tiles = am.scheduler.tiles
        assert tiles == [8, 2]  # little at 0.25 rel throughput -> 8 * 0.25
        assert len(set(tiles)) == len(am.classes)

    def test_tiles_proportional_and_floored(self):
        am = AsymmetricMesh(
            [DeviceClass("a"), DeviceClass("b", rel_throughput=0.5),
             DeviceClass("c", rel_throughput=0.01)],
            strategy="ca-sas", batch_tile=4,
        )
        assert am.scheduler.tiles == [4, 2, 1]  # floored at 1, never 0

    def test_plain_strategies_keep_common_tile(self):
        am = AsymmetricMesh(biglittle_classes(), strategy="das", batch_tile=8)
        assert am.scheduler.tiles == [8, 8]


# ---------------------------------------------------------------------------
# Wallclock calibration off measured step times (satellite)
# ---------------------------------------------------------------------------


class TestWallclockCalibration:
    def test_measurements_enable_heterogeneous_wallclock(self):
        classes = biglittle_classes(chips_per_pod=1)
        meas = [
            R.ClassMeasurement(name="big", units=512, seconds=0.1),
            R.ClassMeasurement(name="little", units=512, seconds=0.4),
        ]
        cal = R.calibrate_class_ratios(classes, backend="wallclock",
                                       measurements=meas)
        assert cal.ratios[0] == 1.0
        assert cal.ratios[1] == pytest.approx(0.25)
        assert cal.times_s == (0.1, 0.4)

    def test_measurements_normalize_per_chip(self):
        # A wide pod must not look fast merely by having more chips.
        classes = [DeviceClass("wide", chips_per_pod=4),
                   DeviceClass("narrow", chips_per_pod=1)]
        meas = [R.ClassMeasurement("wide", units=400, seconds=1.0),
                R.ClassMeasurement("narrow", units=100, seconds=1.0)]
        cal = R.calibrate_class_ratios(classes, backend="wallclock",
                                       measurements=meas)
        assert cal.ratios == (1.0, 1.0)

    def test_missing_class_measurement_raises(self):
        classes = biglittle_classes(chips_per_pod=1)
        with pytest.raises(ValueError, match="missing"):
            R.calibrate_class_ratios(
                classes, backend="wallclock",
                measurements=[R.ClassMeasurement("big", 1, 1.0)],
            )

    def test_from_calibration_wallclock_measurements(self):
        classes = biglittle_classes(chips_per_pod=1)
        meas = [R.ClassMeasurement("big", 512, 0.1),
                R.ClassMeasurement("little", 512, 0.2)]
        mesh = AsymmetricMesh.from_calibration(
            classes, backend="wallclock", measurements=meas,
            strategy="ca-das", batch_tile=2,
        )
        assert mesh.calibration.backend == "wallclock"
        assert mesh.classes[1].rel_throughput == pytest.approx(0.5)
        layout = mesh.batch_layout(96)
        assert sum(layout.sizes) == 96
        assert layout.sizes[0] > layout.sizes[1]

    def test_heterogeneous_wallclock_still_rejected_without_measurements(self):
        with pytest.raises(ValueError, match="heterogeneous"):
            R.calibrate_class_ratios(biglittle_classes(), backend="wallclock")


# ---------------------------------------------------------------------------
# Two-stage coarse -> fine search (satellite)
# ---------------------------------------------------------------------------


class TestTwoStageSearch:
    def test_prefilter_prunes_expensive_timings(self):
        from repro.tuning import measure as M
        from repro.tuning import tune as T

        m = k = n = 1024
        calls = []

        def counting_backend(mm, kk, nn, cfg):
            calls.append(cfg)
            return M.cost_model_time(mm, kk, nn, cfg)

        full = T.search_shape(m, k, n, spec=B.TPU_V5E, dtype_bytes=2,
                              backend=counting_backend)
        n_full = len(calls)
        calls.clear()

        pruned = T.search_shape(
            m, k, n, spec=B.TPU_V5E, dtype_bytes=2, backend=counting_backend,
            prefilter=lambda mm, kk, nn, cfg: M.cost_model_time(mm, kk, nn, cfg),
            coarse_keep=4,
        )
        assert len(calls) < n_full
        assert pruned.n_pruned > 0
        # The prefilter is the same objective here, so no quality loss.
        assert pruned.best_time_s == pytest.approx(full.best_time_s)
        assert pruned.best_time_s <= pruned.analytical_time_s

    def test_tune_shapes_auto_enables_for_wallclock(self, tmp_path):
        from repro.tuning import tune as T

        # cost-model backend: two_stage auto stays off -> exhaustive count.
        res = T.tune_shapes([(512, 512, 512)], spec=B.TPU_V5E,
                            backend_name="cost-model")[0]
        assert res.n_pruned == 0

        res2 = T.tune_shapes([(512, 512, 512)], spec=B.TPU_V5E,
                             backend_name="cost-model", two_stage=True,
                             coarse_keep=3)[0]
        assert res2.n_pruned > 0
        assert res2.best_time_s <= res2.analytical_time_s

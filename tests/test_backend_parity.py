"""Cross-backend kernel parity harness.

Every entry of ``execution.BACKENDS`` — present and future — is run
against a pure-jnp oracle over a grid of shapes and dtypes (f32, bf16)
with per-dtype tolerances; the grid is chosen per *op family*
(``execution.BACKEND_OPS``): GEMM backends against ``kernels/ref.gemm_
ref`` over ragged/non-multiple-of-block/1-row/1-col edges, paged-
attention backends against ``kernels/ref.paged_attention_ref`` over
GQA/MHA head layouts, page sizes, sentinel-holding tables, and ring-
wrapped positions.  The parametrizations iterate the dispatch table
itself, so **adding a backend automatically adds its parity coverage**:
a new entry that lacks an interpret twin (the CPU route,
``execution.INTERPRET_TWIN``) fails ``test_every_backend_has_a_
cpu_route`` before it can ship untested, and an entry missing from
``BACKEND_OPS`` fails the vocabulary test in tests/test_execution.py.

Pallas variants execute through their interpret twins (the kernel *body*
is identical; Mosaic compilation is the only thing interpret mode skips),
which is how this suite runs on the CPU-only CI host.  A hypothesis sweep
(marked ``slow``; the CI parity lane raises its example count via
``$REPRO_PARITY_EXAMPLES``) fuzzes shapes and block configs beyond the
fixed grid.
"""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import execution as X
from repro.core.blocking import TPU_V5E, BlockConfig, derive_block_config
from repro.kernels import ref

RNG = np.random.default_rng(11)

# Ragged, non-multiple-of-block, and degenerate 1-row/1-col problems.
SHAPES = [
    (128, 128, 128),     # exact single block
    (256, 512, 128),     # multi-block, exact
    (300, 200, 180),     # ragged in all dims
    (64, 1024, 96),      # sub-block m/n, long k
    (1, 384, 128),       # 1-row edge
    (128, 256, 1),       # 1-col edge
    (1, 128, 1),         # 1x1 output
    (257, 129, 131),     # off-by-one past block boundaries
]

# allclose tolerance per accumulation dtype: fp32 accumulators everywhere,
# but bf16 operands quantize the inputs.
TOLS = {jnp.float32: dict(rtol=1e-4, atol=1e-4), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}

DTYPES = sorted(TOLS, key=str)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _run_backend(backend, a, b, cfg):
    """Dispatch through the table via the backend's CPU-runnable twin."""

    return X.BACKENDS[X.interpret_twin(backend)](a, b, cfg, a.dtype)


def test_every_backend_has_a_cpu_route():
    """The growth guard: a BACKENDS entry without a registered interpret
    twin cannot be parity-tested and must not exist."""

    for name in X.BACKENDS:
        twin = X.interpret_twin(name)  # raises on a missing registration
        assert twin in X.BACKENDS
    # And the twin map carries no stale names for removed backends.
    assert set(X.INTERPRET_TWIN) == set(X.BACKENDS)


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("backend", sorted(X.GEMM_BACKEND_NAMES))
def test_backend_matches_oracle(backend, shape, dtype):
    m, k, n = shape
    a, b = _rand((m, k), dtype), _rand((k, n), dtype)
    # A fixed single-tile config exercises the padding paths on every
    # ragged/edge shape; XLA ignores it.
    cfg = BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=a.dtype.itemsize)
    out = _run_backend(backend, a, b, cfg)
    expect = ref.gemm_ref(a, b)
    assert out.shape == expect.shape and out.dtype == expect.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOLS[dtype]
    )


@pytest.mark.parametrize("backend", sorted(X.GEMM_BACKEND_NAMES))
def test_backend_default_config_resolution(backend):
    """cfg=None resolves per backend (lean derives single-buffered) and
    still matches the oracle."""

    a, b = _rand((130, 70), jnp.float32), _rand((70, 50), jnp.float32)
    out = X.BACKENDS[X.interpret_twin(backend)](a, b, None, a.dtype)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.gemm_ref(a, b)), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# Paged-attention parity (op family "paged_attn")
# ---------------------------------------------------------------------------

PAGED_BACKENDS = sorted(n for n, op in X.BACKEND_OPS.items() if op == "paged_attn")

# (batch, Hq, Hkv, Dh, page_size, table_width, arena_pages): GQA and MHA
# head layouts, single- and multi-page lanes, arenas larger than any one
# row needs (so tables hold genuinely scattered page ids).
PAGED_CASES = [
    (3, 4, 2, 16, 8, 4, 16),
    (5, 8, 8, 32, 16, 2, 12),
    (2, 4, 1, 8, 4, 8, 40),
    (4, 2, 2, 64, 32, 1, 6),
]


def _paged_case(b, hq, hkv, dh, ps, w, pages, dtype, seed=0):
    """Random arena + per-row tables: allocated prefix pages, SENTINEL
    beyond, positions drawn past ``s_cache`` too (the ring-wrapped row
    attends its whole logical cache)."""

    from repro.runtime.paging import SENTINEL

    rng = np.random.default_rng(seed + b * 131 + hq * 17 + ps)
    q = jnp.asarray(rng.normal(size=(b, hq, dh)), dtype)
    pk = jnp.asarray(rng.normal(size=(pages, ps, hkv, dh)), dtype)
    pv = jnp.asarray(rng.normal(size=(pages, ps, hkv, dh)), dtype)
    pos = rng.integers(0, w * ps + ps, size=(b,))
    table = np.full((b, w), SENTINEL, np.int32)
    for r in range(b):
        need = min(int(pos[r]) // ps + 1, w)
        table[r, :need] = rng.choice(pages, size=need, replace=False)
    return q, pk, pv, jnp.asarray(table), jnp.asarray(pos, jnp.int32)


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize(
    "case", PAGED_CASES, ids=lambda c: "b{}h{}kv{}d{}ps{}w{}p{}".format(*c)
)
@pytest.mark.parametrize("backend", PAGED_BACKENDS)
def test_paged_attention_matches_oracle(backend, case, dtype):
    q, pk, pv, table, pos = _paged_case(*case, dtype)
    out = X.BACKENDS[X.interpret_twin(backend)](q, pk, pv, table, pos)
    expect = ref.paged_attention_ref(q, pk, pv, table, pos)
    assert out.shape == expect.shape and out.dtype == expect.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOLS[dtype]
    )


def test_paged_dispatch_routes_by_op_family():
    """The funnel: auto resolves inside the paged_attn family, and the
    result matches the oracle (tolerance — auto may pick either route)."""

    q, pk, pv, table, pos = _paged_case(*PAGED_CASES[0], jnp.float32)
    out = X.dispatch_paged_attention(q, pk, pv, table, pos, backend="auto")
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.paged_attention_ref(q, pk, pv, table, pos), np.float32),
        rtol=1e-4, atol=1e-4,
    )


# ---------------------------------------------------------------------------
# Hypothesis sweep (the CI parity lane: pytest -m slow tests/test_backend_parity.py)
# ---------------------------------------------------------------------------

# Only the fuzz sweep needs hypothesis; the fixed grid above must keep
# running without it (so no module-level importorskip).
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is an optional dep
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _EXAMPLES = int(os.environ.get("REPRO_PARITY_EXAMPLES", "10"))

    dims = st.integers(min_value=1, max_value=300)
    blocks = st.sampled_from([64, 128, 256])

    @pytest.mark.slow
    @settings(
        max_examples=_EXAMPLES,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(m=dims, k=dims, n=dims, bm=blocks, bk=blocks, bn=blocks, data=st.data())
    def test_backend_parity_fuzz(m, k, n, bm, bk, bn, data):
        """Random (shape, block, backend, dtype): every backend agrees
        with the oracle whenever the config passes shape validation."""

        backend = data.draw(
            st.sampled_from(sorted(X.GEMM_BACKEND_NAMES)), label="backend"
        )
        dtype = data.draw(st.sampled_from(DTYPES), label="dtype")
        # Deterministic data per drawn example (hypothesis replays shrink
        # candidates; a shared advancing RNG would make failures flaky).
        rng = np.random.default_rng(m * 7919 + k * 104729 + n)
        a = jnp.asarray(rng.normal(size=(m, k)), dtype)
        b = jnp.asarray(rng.normal(size=(k, n)), dtype)
        cfg = BlockConfig(bm=bm, bk=bk, bn=bn, dtype_bytes=a.dtype.itemsize)
        from repro.kernels.gemm import validate_block_config

        try:
            validate_block_config(m, k, n, cfg)
        except ValueError:
            # Oversized blocks are a loud error by contract (the bugfix);
            # parity only covers valid configs.
            return
        out = _run_backend(backend, a, b, cfg)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref.gemm_ref(a, b), np.float32),
            **TOLS[dtype],
        )

    @pytest.mark.slow
    @settings(max_examples=max(5, _EXAMPLES // 2), deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=600),
        k=st.integers(min_value=1, max_value=600),
        n=st.integers(min_value=1, max_value=600),
    )
    def test_lean_bitwise_matches_pipelined(m, k, n):
        """The lean kernel is a *scheduling* change, not a numeric one:
        same blocks, same accumulation order, bit-identical to the
        default kernel."""

        from repro.kernels.gemm import gemm_pallas, gemm_pallas_lean

        rng = np.random.default_rng(m * 7919 + k * 104729 + n)
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        cfg = derive_block_config(m, k, n, spec=TPU_V5E, dtype_bytes=4)
        assert np.array_equal(
            np.asarray(gemm_pallas(a, b, cfg, interpret=True)),
            np.asarray(gemm_pallas_lean(a, b, cfg, interpret=True)),
        )

import os

# Tests run on the single host CPU device; the dry-run (and only the
# dry-run) forces 512 placeholder devices in its own subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.distributed import sharding as SH  # noqa: E402


@pytest.fixture(autouse=True)
def _clear_activation_mesh():
    """Keep the global activation-constraint mesh from leaking across tests."""

    yield
    SH.use_mesh_for_activations(None)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)

import os

# Tests run on 8 forced host CPU devices so the class-sharded shard_map
# paths (2-pod meshes) are exercised everywhere; the dry-run (and only the
# dry-run) forces 512 placeholder devices in its own subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
    ).strip()

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.distributed import sharding as SH  # noqa: E402


@pytest.fixture(autouse=True)
def _clear_activation_mesh():
    """Keep the global activation-constraint mesh from leaking across tests."""

    yield
    SH.use_mesh_for_activations(None)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)

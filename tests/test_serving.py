"""Persistent serving runtime: slot table, router, fused prefill, donation.

The ISSUE-5 acceptance criteria, as tests:

  * the fused bulk prefill writes a cache **bit-identical** to the
    token-by-token replay for every token-in zoo arch;
  * admission routes each request into its class's slot region, slots are
    reused after completion, and the slot budgets re-derive only past the
    scheduler's hysteresis threshold;
  * steady-state decode performs **zero** per-step host relayout (no
    ``pad_requests`` / chunk-table work inside the decode loop);
  * the donated decode-state path returns tokens identical to the
    undonated one (and the trainer's donated step identical params);
  * the mixed class-sharded engine's tokens are bit-identical to the
    one-shot ``pad_requests`` path on the 8 forced host devices, with
    ``ShardProvenance`` still proving the per-class programs.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.distributed import sharding as SH
from repro.launch import serve
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as Z
from repro.runtime.serving import ServingEngine

TOKEN_IN = [
    n for n in list_configs()
    if not get_config(n).embed_inputs and get_config(n).family != "encdec"
]

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for name in TOKEN_IN:
        cfg = get_config(name).reduced()
        out[name] = (cfg, Z.init_params(jax.random.PRNGKey(0), cfg))
    return out


def _biglittle(**kw):
    kw.setdefault("strategy", "ca-das")
    kw.setdefault("batch_tile", 1)
    return AsymmetricMesh(biglittle_classes(chips_per_pod=1), **kw)


def _single(**kw):
    kw.setdefault("strategy", "ca-das")
    kw.setdefault("batch_tile", 1)
    return AsymmetricMesh([DeviceClass("only", chips_per_pod=1)], **kw)


def _oneshot_mixed(cfg, params, prompts, gen_len, seq_cap, asym):
    """The legacy path verbatim: pad once, replay prompt token-by-token."""

    layout = asym.batch_layout(len(prompts))
    mesh = make_host_mesh(pod=asym.n_pods)
    step = serve.mixed_decode_step(
        cfg, asym, mesh, len(layout.sizes) * layout.c_max, seq_cap
    )
    padded, order = serve.pad_requests(prompts, layout)
    decode = jax.jit(step)
    state = Z.init_decode_state(cfg, padded.shape[0], seq_cap)
    tok = jnp.asarray(padded)
    plen = prompts.shape[1]
    logits = None
    for t in range(plen):
        logits, state = decode(params, {"tokens": tok[:, t:t + 1]}, state, jnp.int32(t))
    out = [padded]
    for t in range(plen, plen + gen_len):
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt))
        logits, state = decode(params, {"tokens": nxt}, state, jnp.int32(t))
    return np.concatenate(out, axis=1)[order], step


# ---------------------------------------------------------------------------
# Fused bulk prefill: cache bit-identity with the token-by-token replay
# ---------------------------------------------------------------------------


class TestBulkPrefill:
    @pytest.mark.parametrize("arch", TOKEN_IN)
    def test_cache_bit_identical_to_replay(self, zoo, arch):
        """One fused forward over the whole prompt must write exactly the
        state the per-token decode replay writes — KV caches (linear and
        ring), SSM/conv states, shared-attention caches — plus the same
        last-position logits.  Prompt length exceeds mixtral's reduced
        window (8) so the ring wrap is exercised."""

        cfg, params = zoo[arch]
        b, plen = 2, 10
        seq_cap = plen + 4
        prompts = jnp.asarray(RNG.integers(0, cfg.vocab, (b, plen)), jnp.int32)

        state = Z.init_decode_state(cfg, b, seq_cap)
        decode = jax.jit(Z.make_decode_fn(cfg))
        logits = None
        for t in range(plen):
            logits, state = decode(
                params, {"tokens": prompts[:, t:t + 1]}, state, jnp.int32(t)
            )

        bulk = jax.jit(Z.make_prefill_fn(cfg, with_cache=True))
        logits2, state2 = bulk(
            params, {"tokens": prompts}, Z.init_decode_state(cfg, b, seq_cap),
            jnp.int32(0),
        )
        for a, bb in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
            assert np.array_equal(np.asarray(a), np.asarray(bb))
        assert np.array_equal(
            np.asarray(logits, np.float32), np.asarray(logits2, np.float32)
        )

    def test_vector_positions_bit_identical_to_scalar(self, zoo):
        """The slot engine's (B,) per-row position vector is value-identical
        to the scalar-position decode when the positions coincide — the
        property that lets persistent slots reproduce static batching."""

        cfg, params = zoo["mixtral-8x7b"]  # ring cache + MoE routing
        b, seq_cap = 3, 12
        state = Z.init_decode_state(cfg, b, seq_cap)
        tok = jnp.asarray(RNG.integers(0, cfg.vocab, (b, 1)), jnp.int32)
        decode = jax.jit(Z.make_decode_fn(cfg))
        l1, s1 = decode(params, {"tokens": tok}, state, jnp.int32(5))
        l2, s2 = decode(params, {"tokens": tok}, state, jnp.full((b,), 5, jnp.int32))
        assert np.array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))
        for a, bb in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
            assert np.array_equal(np.asarray(a), np.asarray(bb))

    def test_heterogeneous_positions_decode(self, zoo):
        """Slots at different ages decode in one step (finite logits, and a
        position past the cache length writes nothing — retired lanes)."""

        cfg, params = zoo["internlm2-1.8b"]
        b, seq_cap = 3, 8
        state = Z.init_decode_state(cfg, b, seq_cap)
        tok = jnp.asarray(RNG.integers(0, cfg.vocab, (b, 1)), jnp.int32)
        pos = jnp.asarray([2, 5, seq_cap + 3], jnp.int32)  # last: phantom lane
        logits, s2 = jax.jit(Z.make_decode_fn(cfg))(
            params, {"tokens": tok}, state, pos
        )
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # The out-of-range row wrote no cache entry.
        assert np.array_equal(np.asarray(s2["k"])[:, 2], np.asarray(state["k"])[:, 2])

    def test_rejects_non_token_batches(self, zoo):
        cfg, _ = zoo["internlm2-1.8b"]
        f = Z.make_prefill_fn(cfg, with_cache=True)
        with pytest.raises(ValueError, match="token-in"):
            f(None, {"embeds": jnp.zeros((1, 2, 4))}, None, 0)


# ---------------------------------------------------------------------------
# Admission router + slot table
# ---------------------------------------------------------------------------


class TestRouterAndSlots:
    def _engine(self, zoo, asym=None, **kw):
        cfg, params = zoo["internlm2-1.8b"]
        kw.setdefault("seq_cap", 32)
        kw.setdefault("slots_per_pod", 4)
        kw.setdefault("class_sharded", "off")
        return cfg, ServingEngine(cfg, params, asym or _biglittle(), **kw)

    def test_admission_lands_in_class_region(self, zoo):
        """Requests routed to a class must occupy slots inside that class's
        pods' regions, and the router split must track the chunk table."""

        cfg, eng = self._engine(zoo)
        prompts = RNG.integers(0, cfg.vocab, (6, 4), dtype=np.int32)
        rid_class = {}
        for p in prompts:
            rid = eng.submit(p, 3)
            ci = next(
                ci for ci, q in enumerate(eng.queues) if any(r.rid == rid for r in q)
            )
            rid_class[rid] = ci
        # Router split == chunk-table split aggregated by class.
        sizes = eng.asym.chunk_table(6).sizes()
        by_class = [0] * len(eng.asym.classes)
        for pod, s in enumerate(sizes):
            by_class[eng.asym.pod_class_indices()[pod]] += s
        assert sorted(rid_class.values()) == sorted(
            ci for ci, n in enumerate(by_class) for _ in range(n)
        )
        eng.admit()
        for slot, rid in enumerate(eng.slot_rid):
            if rid < 0:
                continue
            pod = slot // eng.c_max
            assert eng.asym.pod_class_indices()[pod] == rid_class[rid]

    def test_slot_reuse_after_completion(self, zoo):
        """A second wave reuses the freed slots, and (dense arch: row-local
        math) its tokens are bit-identical to a fresh engine's."""

        cfg, eng = self._engine(zoo, asym=_single())
        w1 = RNG.integers(0, cfg.vocab, (4, 6), dtype=np.int32)
        w2 = RNG.integers(0, cfg.vocab, (4, 6), dtype=np.int32)
        eng.generate(w1, 4)
        slots1 = sorted(c.slot for c in eng.completions)
        got = eng.generate(w2, 4)
        slots2 = sorted(c.slot for c in eng.completions[4:])
        assert slots1 == slots2  # the freed slots were re-admitted

        _, fresh = self._engine(zoo, asym=_single())
        assert np.array_equal(got, fresh.generate(w2, 4))
        assert eng.stats.completed == 8

    def test_mixed_prompt_lengths_stream(self, zoo):
        """Requests with different prompt lengths admit in ONE continuous-
        batching round (right-padded to the round max, each row's first
        token selected at its own last real prompt token) and decode
        concurrently at heterogeneous slot positions."""

        cfg, eng = self._engine(zoo, asym=_single(), seq_cap=64)
        short = RNG.integers(0, cfg.vocab, (4,), dtype=np.int32)
        long = RNG.integers(0, cfg.vocab, (9,), dtype=np.int32)
        r1 = eng.submit(short, 3)
        r2 = eng.submit(long, 5)
        done = {c.rid: c for c in eng.run()}
        assert set(done) == {r1, r2}
        assert len(done[r1].tokens) == 4 + 3
        assert len(done[r2].tokens) == 9 + 5
        assert eng.stats.admission_rounds == 1
        assert np.array_equal(done[r1].tokens[:4], short)
        assert np.array_equal(done[r2].tokens[:9], long)

    def test_submit_validation(self, zoo):
        cfg, eng = self._engine(zoo, seq_cap=8)
        with pytest.raises(ValueError, match="seq_cap"):
            eng.submit(np.zeros(6, np.int32), 4)
        with pytest.raises(ValueError, match="max_new"):
            eng.submit(np.zeros(2, np.int32), 0)

    def test_rebalance_only_past_hysteresis(self, zoo):
        """Slot budgets re-derive only when the calibrated ratio drifts past
        the scheduler threshold — noise-level jitter never resizes the
        regions; a genuine straggler does."""

        cfg, params = zoo["internlm2-1.8b"]
        prompts = RNG.integers(0, cfg.vocab, (6, 4), dtype=np.int32)
        # Per-pod times consistent with the calibrated 4:1 ratio (the [5,1]
        # split gives per-pod times [5/4, 1/1]) plus ±2% measurement noise:
        # normalized-rate drift stays under the 5% threshold.
        jitter = ServingEngine(
            cfg, params, _biglittle(), seq_cap=32, slots_per_pod=5,
            class_sharded="off",
            pod_time_hook=lambda step: [1.25 * (1.02 if step % 2 else 0.98),
                                        1.00 * (0.99 if step % 3 else 1.01)],
        )
        jitter.generate(prompts, 4)
        jitter.generate(prompts, 4)  # second admission: budgets refresh
        assert jitter.stats.rebalances == 0

        straggler = ServingEngine(
            cfg, params, _biglittle(), seq_cap=32, slots_per_pod=5,
            class_sharded="off",
            # big pod suddenly 20x slower per unit than calibrated
            pod_time_hook=lambda step: [5.0, 0.1],
        )
        straggler.generate(prompts, 4)
        straggler.generate(prompts, 4)
        assert straggler.stats.rebalances >= 1

    def test_zero_host_relayout_in_decode_loop(self, zoo, monkeypatch):
        """Steady-state decode must not touch pad_requests or re-derive the
        chunk table: both are poisoned after admission and the loop still
        runs.  The one-shot path, by contrast, calls pad_requests."""

        cfg, eng = self._engine(zoo, asym=_single())
        prompts = RNG.integers(0, cfg.vocab, (4, 4), dtype=np.int32)
        for p in prompts:
            eng.submit(p, 6)

        def boom(*a, **k):
            raise AssertionError("host relayout inside the decode loop")

        monkeypatch.setattr(serve, "pad_requests", boom)
        eng.admit()
        monkeypatch.setattr(eng.asym, "chunk_table", boom)
        monkeypatch.setattr(eng.asym, "batch_layout", boom)
        while (eng.slot_rid >= 0).any():
            eng.step()
        assert eng.stats.completed == 4
        assert eng.stats.host_relayouts == 0


# ---------------------------------------------------------------------------
# Buffer donation
# ---------------------------------------------------------------------------


class TestDonation:
    def test_engine_donated_path_identical_tokens(self, zoo):
        cfg, params = zoo["internlm2-1.8b"]
        prompts = RNG.integers(0, cfg.vocab, (4, 5), dtype=np.int32)
        outs = {}
        for donate in (True, False):
            eng = ServingEngine(
                cfg, params, _single(), seq_cap=24, slots_per_pod=4,
                class_sharded="off", donate=donate,
            )
            outs[donate] = eng.generate(prompts, 5)
            if donate:
                # The donation is real: the pre-step state buffers are gone.
                old = eng.state
                eng.generate(prompts, 2)
                assert all(x.is_deleted() for x in jax.tree.leaves(old))
        assert np.array_equal(outs[True], outs[False])

    def test_serve_generate_donates_and_matches(self, zoo):
        cfg, params = zoo["internlm2-1.8b"]
        prompts = jnp.asarray(RNG.integers(0, cfg.vocab, (3, 6)), jnp.int32)
        out_d, _ = serve.generate(cfg, params, prompts, 4, 12, donate=True)
        out_n, _ = serve.generate(cfg, params, prompts, 4, 12, donate=False)
        assert np.array_equal(out_d, out_n)

    def test_trainer_donated_step_identical_params(self, tmp_path):
        """The trainer threads params/opt state through its jitted step with
        donate_argnums; the donated update must equal the undonated one."""

        from repro.optim import adamw as O
        from repro.runtime.trainer import Trainer, TrainerConfig

        def mk(sub):
            return Trainer(
                get_config("internlm2-1.8b").reduced(), make_host_mesh(),
                tcfg=TrainerConfig(steps=1, global_batch=4, seq_len=16,
                                   ckpt_dir=str(tmp_path / sub)),
                opt_cfg=O.AdamWConfig(lr=1e-3, total_steps=1, warmup_steps=1),
            )

        # Twin trainers (same seed -> identical jit-initialized state):
        # snapshotting the live buffers instead would pin them via the
        # Array's cached host copy and silently disable the donation
        # under test.
        t, ref = mk("don"), mk("ref")
        batch, _ = t._next_batch(0)
        batch_ref, _ = ref._next_batch(0)

        undonated = jax.jit(ref._make_train_step())  # same step fn, no donation
        p_ref, o_ref, _ = undonated(ref.params, ref.opt_state, batch_ref)
        old_params, old_opt = t.params, t.opt_state
        with t.mesh:
            p_don, o_don, _ = t.train_step(t.params, t.opt_state, batch)
        # Donation actually happened (params AND optimizer state)...
        assert all(x.is_deleted() for x in jax.tree.leaves(old_params))
        assert all(x.is_deleted() for x in jax.tree.leaves(old_opt))
        # ...and changed nothing.
        for a, b in zip(jax.tree.leaves(p_don), jax.tree.leaves(p_ref)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(o_don), jax.tree.leaves(o_ref)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Mixed class-sharded engine vs the one-shot pad_requests path
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >=2 host devices")
class TestMixedEngineParity:
    @pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x7b"])
    def test_engine_bit_identical_to_one_shot(self, zoo, arch):
        """Same prompts, same greedy decode: the persistent class-sharded
        engine must emit exactly the one-shot mixed path's tokens —
        including through MoE capacity routing, whose cross-row coupling
        makes this sensitive to every lane of the slot table."""

        cfg, params = zoo[arch]
        SH.use_mesh_for_activations(None)
        b, plen, gen = 6, 8, 5
        seq_cap = plen + gen
        prompts = RNG.integers(0, cfg.vocab, (b, plen), dtype=np.int32)

        ref, step = _oneshot_mixed(
            cfg, params, prompts, gen, seq_cap, _biglittle()
        )
        asym = _biglittle()
        eng = ServingEngine(
            cfg, params, asym, seq_cap=seq_cap,
            slots_per_pod=asym.batch_layout(b).c_max,
        )
        got = eng.generate(prompts, gen)
        assert eng.mixed
        assert np.array_equal(got, ref)

        # ShardProvenance still proves the per-class programs (paper §5.3).
        assert [(p.pod, p.device_class) for p in eng.provenance] \
            == [(0, "big"), (1, "little")]
        assert [(p.pod, p.device_class, p.backend) for p in eng.provenance] \
            == [(p.pod, p.device_class, p.backend) for p in step.provenance]
        assert eng.stats.host_relayouts == 0

    def test_class_sharded_on_requires_devices(self, zoo):
        cfg, params = zoo["internlm2-1.8b"]
        big = AsymmetricMesh(
            [DeviceClass("a", chips_per_pod=1, n_pods=9),
             DeviceClass("b", chips_per_pod=1, rel_throughput=0.5)],
        )
        with pytest.raises(ValueError, match="devices"):
            ServingEngine(cfg, params, big, seq_cap=16, class_sharded="on")

    def test_engine_rejects_non_token_archs(self, zoo):
        cfg, params = zoo["internlm2-1.8b"]
        whisper = get_config("whisper-small").reduced()
        with pytest.raises(ValueError, match="token-in"):
            ServingEngine(whisper, None, _biglittle(), seq_cap=16)


# ---------------------------------------------------------------------------
# Serve CLI: steady-state timing split
# ---------------------------------------------------------------------------


class TestServeCLI:
    def _run(self, monkeypatch, capsys, *extra):
        argv = ["serve", "--arch", "internlm2-1.8b", "--reduced",
                "--batch", "4", "--prompt-len", "4", "--gen-len", "4",
                "--class-sharded", "off", *extra]
        monkeypatch.setattr("sys.argv", argv)
        serve.main()
        out = capsys.readouterr().out.strip().splitlines()
        return json.loads(out[-1])

    def test_engine_json_reports_compile_and_steady_separately(
        self, monkeypatch, capsys
    ):
        js = self._run(monkeypatch, capsys)
        assert js["path"] == "engine"
        assert js["compile_s"] > 0
        assert js["tokens_per_s"] > 0
        # compile time is NOT folded into the throughput number
        assert js["tokens_per_s"] > js["batch"] * js["generated"] / js["wall_s"]
        assert js["engine"]["host_relayouts"] == 0

    def test_one_shot_json_same_tokens(self, monkeypatch, capsys):
        js_e = self._run(monkeypatch, capsys)
        js_o = self._run(monkeypatch, capsys, "--one-shot")
        assert js_o["path"] == "one-shot"
        assert js_o["compile_s"] > 0
        assert js_e["sample"] == js_o["sample"]

"""Integration: end-to-end training with fault tolerance and scheduling."""

import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, DeviceClass
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig


def _mesh():
    return make_host_mesh()


def _trainer(tmp, arch="internlm2-1.8b", steps=12, asym=None, failure_hook=None,
             pod_time_hook=None, n_micro=1):
    cfg = get_config(arch).reduced()
    return Trainer(
        cfg,
        _mesh(),
        tcfg=TrainerConfig(
            steps=steps, global_batch=8, seq_len=32,
            ckpt_dir=str(tmp), ckpt_every=4, n_micro=n_micro,
        ),
        opt_cfg=AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=2),
        asym=asym,
        failure_hook=failure_hook,
        pod_time_hook=pod_time_hook,
    )


class TestTraining:
    def test_loss_decreases(self, tmp_path):
        t = _trainer(tmp_path, steps=20)
        hist = t.run()
        first = np.mean([h["loss"] for h in hist[:4]])
        last = np.mean([h["loss"] for h in hist[-4:]])
        assert last < first

    def test_grad_accumulation_runs(self, tmp_path):
        t = _trainer(tmp_path, steps=4, n_micro=2)
        hist = t.run()
        assert len(hist) == 4
        assert np.isfinite(hist[-1]["loss"])

    def test_metrics_present(self, tmp_path):
        hist = _trainer(tmp_path, steps=3).run()
        for key in ("loss", "lr", "grad_norm", "ce"):
            assert key in hist[0]


class TestFaultTolerance:
    def test_failure_restores_and_completes(self, tmp_path):
        fails = {5, 9}

        def hook(step):
            if step in fails:
                fails.discard(step)
                raise SimulatedFailure(step)

        t = _trainer(tmp_path, steps=12, failure_hook=hook)
        hist = t.run()
        assert t.restarts == 2
        assert t.step == 12
        assert np.isfinite(hist[-1]["loss"])

    def test_restart_resumes_from_committed_step(self, tmp_path):
        seen = []

        def hook(step):
            seen.append(step)
            if step == 6 and seen.count(6) == 1:
                raise SimulatedFailure(6)

        t = _trainer(tmp_path, steps=8, failure_hook=hook)
        t.run()
        # failed at 6 -> restored to last ckpt (step 4) -> replayed 4,5,6,7
        assert seen.count(5) == 2
        assert t.restarts == 1

    def test_deterministic_data_replay(self, tmp_path):
        """After restore, the replayed batches are identical (seeded by
        step), so training is reproducible across failures."""

        t1 = _trainer(tmp_path / "a", steps=10)
        h1 = t1.run()

        fails = {7}

        def hook(step):
            if step in fails:
                fails.discard(step)
                raise SimulatedFailure(step)

        t2 = _trainer(tmp_path / "b", steps=10, failure_hook=hook)
        h2 = t2.run()
        # Final loss identical despite mid-run restart.
        assert h1[-1]["loss"] == pytest.approx(h2[-1]["loss"], rel=1e-5)


class TestAsymmetricScheduling:
    def test_straggler_sheds_work(self, tmp_path):
        """A pod that is consistently 4x slower must end with a smaller
        batch share under CA-DAS (the paper's dynamic scheduling)."""

        asym = AsymmetricMesh(
            [DeviceClass("fast", chips_per_pod=1), DeviceClass("slow", chips_per_pod=1)],
            strategy="ca-das",
            batch_tile=1,
        )

        def times(step):
            sizes = asym.batch_layout(8).sizes
            return [sizes[0] / 4.0 + 1e-6, sizes[1] / 1.0 + 1e-6]

        t = _trainer(tmp_path, steps=15, asym=asym, pod_time_hook=times)
        t.run()
        sizes = asym.batch_layout(8).sizes
        assert sizes[0] > sizes[1]

    def test_sss_stays_equal(self, tmp_path):
        asym = AsymmetricMesh(
            [DeviceClass("a", chips_per_pod=1), DeviceClass("b", chips_per_pod=1)],
            strategy="sss",
            batch_tile=1,
        )
        t = _trainer(tmp_path, steps=4, asym=asym,
                     pod_time_hook=lambda s: [0.1, 0.4])
        t.run()
        sizes = asym.batch_layout(8).sizes
        assert sizes[0] == sizes[1]

    def test_masked_loss_matches_unpadded(self, tmp_path):
        """The padded asymmetric layout must give the same loss as the
        plain layout for the same logical batch (masking exactness)."""

        from repro.data.pipeline import AsymmetricBatcher, SyntheticLM
        from repro.models import model_zoo as Z

        cfg = get_config("internlm2-1.8b").reduced()
        params = Z.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = Z.make_loss_fn(cfg)

        src = SyntheticLM(vocab=cfg.vocab, seed=0)
        plain = src.batch(0, 6, 16)
        l_plain, _ = loss_fn(params, jax.tree.map(jnp.asarray, dict(plain)))

        asym = AsymmetricMesh(
            [DeviceClass("a", chips_per_pod=1),
             DeviceClass("b", chips_per_pod=1, rel_throughput=0.5)],
            strategy="sas", batch_tile=4,
        )
        padded = AsymmetricBatcher(src, asym).batch(0, 6, 16).arrays
        l_padded, _ = loss_fn(params, jax.tree.map(jnp.asarray, dict(padded)))
        assert float(l_plain) == pytest.approx(float(l_padded), rel=1e-5)


class TestElastic:
    def test_reshard_continues_training(self, tmp_path):
        t = _trainer(tmp_path, steps=4)
        t.run(4)
        loss_before = t.step
        t.reshard(make_host_mesh())  # same size here; exercises the path
        t.tcfg.steps = 8
        hist = t.run(8)
        assert t.step == 8
        assert np.isfinite(hist[-1]["loss"])

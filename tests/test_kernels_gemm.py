"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocking import BlockConfig, PAPER_A15, PAPER_A7, GotoBlocking
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gemm import gemm_pallas, gemm_pallas_lean, validate_block_config
from repro.kernels.ops import gemm, linear

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


GEMM_SHAPES = [
    (128, 128, 128),
    (256, 512, 128),
    (300, 200, 180),   # ragged: exercises padding
    (64, 1024, 96),
    (512, 128, 512),
]


@pytest.mark.parametrize("shape", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_pallas_matches_oracle(shape, dtype):
    m, k, n = shape
    a, b = _rand((m, k), dtype), _rand((k, n), dtype)
    cfg = BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=a.dtype.itemsize)
    out = gemm_pallas(a, b, cfg, interpret=True)
    expect = ref.gemm_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2  # f32: blocked-K rounding
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 128), (128, 256, 256)])
def test_gemm_block_shape_invariance(blocks):
    bm, bk, bn = blocks
    a, b = _rand((384, 384), jnp.float32), _rand((384, 384), jnp.float32)
    cfg = BlockConfig(bm=bm, bk=bk, bn=bn, dtype_bytes=4)
    out = gemm_pallas(a, b, cfg, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.gemm_ref(a, b)), rtol=1e-5, atol=1e-4
    )


# ---------------------------------------------------------------------------
# VMEM-lean k-streaming variant (the TPU_LITTLE micro-kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_pallas_lean_matches_oracle(shape, dtype):
    m, k, n = shape
    a, b = _rand((m, k), dtype), _rand((k, n), dtype)
    cfg = BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=a.dtype.itemsize)
    out = gemm_pallas_lean(a, b, cfg, interpret=True)
    expect = ref.gemm_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), rtol=tol, atol=tol
    )


def test_gemm_pallas_lean_bitwise_matches_default():
    """Same blocks, same fp32 accumulation order — the lean variant is a
    scheduling/footprint change, not a numeric one."""

    a, b = _rand((384, 300), jnp.float32), _rand((300, 200), jnp.float32)
    cfg = BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
    assert np.array_equal(
        np.asarray(gemm_pallas(a, b, cfg, interpret=True)),
        np.asarray(gemm_pallas_lean(a, b, cfg, interpret=True)),
    )


def test_gemm_pallas_lean_single_buffer_fit_admits_bigger_panels():
    """The point of the variant: a config that only fits single-buffered
    (lean VMEM model) runs correctly through the lean kernel."""

    from repro.core.blocking import TPU_LITTLE

    # (512, 1280, 1024) bf16: ~6.0 MiB single-buffered working set vs
    # ~10.0 MiB double-buffered — lean-only inside little's 7.55 MiB
    # budget, exactly the panel the control trees keep for little.
    cfg = BlockConfig(bm=512, bk=1280, bn=1024, dtype_bytes=2)
    assert not cfg.fits(TPU_LITTLE)
    assert cfg.fits(TPU_LITTLE, double_buffer=False)
    a, b = _rand((512, 1280), jnp.bfloat16), _rand((1280, 1024), jnp.bfloat16)
    out = gemm_pallas_lean(a, b, cfg, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.gemm_ref(a, b), np.float32),
        rtol=2e-2, atol=2e-2,
    )


# ---------------------------------------------------------------------------
# Config-vs-shape validation (regression: oversized bk was silent)
# ---------------------------------------------------------------------------


class TestBlockConfigValidation:
    def test_bk_exceeding_padded_k_raises(self):
        """Regression: bk=256 against K=100 (pads to 128) used to be
        silently accepted — padding K all the way to 256 and more than
        doubling every grid step's FLOPs."""

        a, b = _rand((128, 100), jnp.float32), _rand((100, 128), jnp.float32)
        cfg = BlockConfig(bm=128, bk=256, bn=128, dtype_bytes=4)
        with pytest.raises(ValueError, match=r"bk=256 exceeds padded K=128"):
            gemm_pallas(a, b, cfg, interpret=True)
        with pytest.raises(ValueError, match=r"bk=256 exceeds padded K=128"):
            gemm_pallas_lean(a, b, cfg, interpret=True)

    @pytest.mark.parametrize(
        "cfg_dims,match",
        [((512, 128, 128), "bm=512 exceeds padded M"),
         ((128, 128, 512), "bn=512 exceeds padded N")],
    )
    def test_bm_bn_also_validated(self, cfg_dims, match):
        bm, bk, bn = cfg_dims
        a, b = _rand((100, 128), jnp.float32), _rand((128, 100), jnp.float32)
        with pytest.raises(ValueError, match=match):
            gemm_pallas(a, b, BlockConfig(bm=bm, bk=bk, bn=bn, dtype_bytes=4),
                        interpret=True)

    def test_blocks_up_to_lane_padding_still_accepted(self):
        # A block equal to the lane-padded dim is the legitimate way to
        # run a sub-128 problem; sub-block dims stay fine too.
        validate_block_config(100, 100, 100, BlockConfig(128, 128, 128, dtype_bytes=4))
        validate_block_config(300, 200, 180, BlockConfig(128, 256, 128, dtype_bytes=4))
        validate_block_config(128, 128, 128, BlockConfig(64, 64, 64, dtype_bytes=4))


def test_blocked_ref_matches_paper_loop_structure():
    """The Figure-1 five-loop reference agrees with plain matmul for both
    published cache configs (and a deliberately ragged one)."""

    a = RNG.normal(size=(300, 1100)).astype(np.float32)
    b = RNG.normal(size=(1100, 200)).astype(np.float32)
    for cfg in (PAPER_A15, PAPER_A7, GotoBlocking(mc=32, kc=952, nc=64)):
        out = ref.blocked_gemm_ref(a, b, cfg)
        np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-3)


def test_blocked_tpu_ref_matches():
    a, b = _rand((256, 512), jnp.float32), _rand((512, 256), jnp.float32)
    cfg = BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
    np.testing.assert_allclose(
        np.asarray(ref.blocked_gemm_tpu_ref(a, b, cfg)),
        np.asarray(ref.gemm_ref(a, b)),
        rtol=1e-5,
        atol=1e-4,
    )


def test_ops_gemm_leading_dims():
    a = _rand((2, 3, 64), jnp.float32)
    b = _rand((64, 32), jnp.float32)
    out = gemm(a, b, backend="xla")
    assert out.shape == (2, 3, 32)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.einsum("bsd,df->bsf", a, b)), rtol=1e-5
    )


def test_ops_backends_agree():
    a, b = _rand((130, 70), jnp.float32), _rand((70, 50), jnp.float32)
    cfg = BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
    x = gemm(a, b, backend="xla")
    p = gemm(a, b, backend="pallas_interpret", config=cfg)
    np.testing.assert_allclose(np.asarray(x), np.asarray(p), rtol=1e-5, atol=1e-4)


def test_linear_bias():
    a, w = _rand((4, 16), jnp.float32), _rand((16, 8), jnp.float32)
    b = _rand((8,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(linear(a, w, b)), np.asarray(a @ w + b), rtol=1e-5, atol=1e-5
    )


ATTN_CASES = [
    # (B, Sq, Sk, H, D, causal, window)
    (2, 128, 128, 2, 64, True, None),
    (1, 100, 100, 1, 64, True, None),     # ragged padding
    (1, 64, 192, 2, 64, True, None),      # query suffix (decode-ish)
    (2, 128, 128, 2, 64, False, None),    # bidirectional (whisper encoder)
    (1, 256, 256, 1, 64, True, 64),       # sliding window (mixtral)
    (1, 128, 128, 2, 128, True, None),    # head dim 128
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_oracle(case):
    b, sq, sk, h, d, causal, window = case
    q = _rand((b, sq, h, d), jnp.float32)
    k = _rand((b, sk, h, d), jnp.float32)
    v = _rand((b, sk, h, d), jnp.float32)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64, interpret=True
    )
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = _rand((2, 128, 2, 64), jnp.bfloat16)
    k = _rand((2, 128, 2, 64), jnp.bfloat16)
    v = _rand((2, 128, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), rtol=3e-2, atol=3e-2
    )


def test_chunked_attention_matches_oracle():
    from repro.models.layers import chunked_attention

    q = _rand((2, 96, 4, 32), jnp.float32)
    k = _rand((2, 96, 4, 32), jnp.float32)
    v = _rand((2, 96, 4, 32), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, q_chunk=32)
    expect = ref.attention_ref(q, k, v, causal=True)
    # chunked_attention computes in COMPUTE_DTYPE (bf16) — tolerance to match
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-2, atol=2e-2)


def test_chunked_attention_window():
    from repro.models.layers import chunked_attention

    q = _rand((1, 128, 2, 32), jnp.float32)
    k = _rand((1, 128, 2, 32), jnp.float32)
    v = _rand((1, 128, 2, 32), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=32, q_chunk=64)
    expect = ref.attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-2, atol=2e-2)

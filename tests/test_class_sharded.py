"""True per-class programs in one SPMD step (shard_map over the pod axis).

The PR-3 acceptance criteria: a 2-class ``AsymmetricMesh`` step traced
through ``class_sharded`` provably uses each class's own tuned block
config (asserted via ``block_source`` provenance per shard *and* by
bit-equality with the explicit per-config kernel call), and the
single-class fallback is bit-identical to the no-shard_map path.

Runs on the 8 forced host devices the conftest sets up
(``--xla_force_host_platform_device_count=8``).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import blocking as B
from repro.core import execution as X
from repro.core import schedule as S
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.kernels.gemm import gemm_pallas
from repro.kernels.ops import gemm
from repro.launch.mesh import make_host_mesh
from repro.tuning import cache as C

RNG = np.random.default_rng(7)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2, reason="class_sharded tests need >=2 host devices"
)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _pod_mesh(n=2):
    return make_host_mesh(pod=n)


def _write_biglittle_cache(tmp_path, big_cfg, little_cfg, m, k, n,
                           big_backend="test", little_backend="test"):
    """Per-class tuned entries under both dtype keys: bfloat16 so the mesh
    trees themselves resolve tuned (block_source provenance), float32 so
    the f32 test calls re-resolve to the same shapes.  The ``*_backend``
    fields record a per-class micro-kernel variant ("test" is not a
    BACKENDS key, so the default kernel applies — the pre-variant
    behavior)."""

    import dataclasses

    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    for dtype_name, nbytes in (("bfloat16", 2), ("float32", 4)):
        for spec, cfg, backend in (
            (B.TPU_V5E, big_cfg, big_backend),
            (B.TPU_LITTLE, little_cfg, little_backend),
        ):
            cache.put(spec.name, dtype_name, m, k, n,
                      dataclasses.replace(cfg, dtype_bytes=nbytes),
                      backend=backend)
    cache.save()
    return path


# ---------------------------------------------------------------------------
# Per-shard config routing (the acceptance criterion)
# ---------------------------------------------------------------------------


class TestPerShardRouting:
    def test_each_shard_runs_its_own_tuned_config(self, tmp_path, monkeypatch):
        """REPRO_TUNING_CACHE set with distinct per-class entries: the big
        pod's shard computes with big's tuned block config and the little
        pod's with little's — asserted via provenance AND numerics (each
        shard bit-equal to the explicit gemm_pallas call with that
        class's config)."""

        m = k = n = 128
        big_cfg = B.BlockConfig(bm=128, bk=128, bn=64, dtype_bytes=4)
        little_cfg = B.BlockConfig(bm=64, bk=128, bn=128, dtype_bytes=4)
        path = _write_biglittle_cache(tmp_path, big_cfg, little_cfg, m, k, n)
        monkeypatch.setenv(C.ENV_VAR, path)

        am = AsymmetricMesh(
            biglittle_classes(chips_per_pod=1),
            tree_shape=(m, k, n), backend="pallas_interpret",
        )
        mesh = _pod_mesh(2)
        step = am.class_sharded(
            lambda x, w: gemm(x, w),
            mesh=mesh, in_specs=(P("pod"), P()), out_specs=P("pod"),
        )
        assert step.mixed

        # block_source provenance per shard: both classes tuned, each
        # shard owned by its own class with its own block config.
        assert [(p.pod, p.device_class, p.block_source) for p in step.provenance] \
            == [(0, "big", "tuned"), (1, "little", "tuned")]
        for prov, cfg in zip(step.provenance, (big_cfg, little_cfg)):
            assert (prov.block.bm, prov.block.bk, prov.block.bn) \
                == (cfg.bm, cfg.bk, cfg.bn)

        x = _rand((2 * m, k))  # rows split pod-major: big gets [:m], little [m:]
        w = _rand((k, n))
        out = np.asarray(jax.jit(step)(x, w))

        big_expect = np.asarray(gemm_pallas(x[:m], w, big_cfg, interpret=True))
        little_expect = np.asarray(gemm_pallas(x[m:], w, little_cfg, interpret=True))
        assert np.array_equal(out[:m], big_expect)
        assert np.array_equal(out[m:], little_expect)
        # The two configs genuinely differ, so this could not have been a
        # single-program run.
        assert big_cfg != little_cfg
        # Both class trees were traced, each under its own ambient context.
        assert set(step.trace_log) == {("big", "tuned"), ("little", "tuned")}

    def test_mixed_vs_primary_context_differ_in_program(self, tmp_path,
                                                        monkeypatch):
        # The pre-PR behavior ran everything under the primary tree: the
        # little rows then used big's config.  Under class_sharded the
        # little shard's result matches little's config — and differs from
        # what big's config computes only in provenance, not numerics
        # (same math), so assert on the trace instead: the old path logs
        # one class, the new path logs both.
        m = k = n = 128
        big_cfg = B.BlockConfig(bm=128, bk=128, bn=64, dtype_bytes=4)
        little_cfg = B.BlockConfig(bm=64, bk=128, bn=128, dtype_bytes=4)
        path = _write_biglittle_cache(tmp_path, big_cfg, little_cfg, m, k, n)
        monkeypatch.setenv(C.ENV_VAR, path)

        am = AsymmetricMesh(
            biglittle_classes(chips_per_pod=1),
            tree_shape=(m, k, n), backend="pallas_interpret",
        )
        with am.execution_context() as ctx:  # the old single-primary path
            assert ctx.device_class == "big"
        step = am.class_sharded(
            lambda x, w: gemm(x, w),
            mesh=_pod_mesh(2), in_specs=(P("pod"), P()), out_specs=P("pod"),
        )
        jax.jit(step)(_rand((2 * m, k)), _rand((k, n)))
        assert {c for c, _ in step.trace_log} == {"big", "little"}


# ---------------------------------------------------------------------------
# Per-shard micro-kernel variants (big -> pallas, little -> pallas_lean)
# ---------------------------------------------------------------------------


class TestPerShardVariantRouting:
    def test_mixed_step_runs_two_kernel_variants(self, tmp_path, monkeypatch):
        """One SPMD step, two micro-kernels: the cache records the lean
        variant as little's winner, so the mixed step runs the big shard
        through the pipelined kernel and the little shard through the
        VMEM-lean k-streaming kernel — proven by ShardProvenance AND by
        bit-equality of each shard with the explicit per-variant call."""

        from repro.kernels.gemm import gemm_pallas_lean

        m = k = n = 128
        big_cfg = B.BlockConfig(bm=128, bk=128, bn=64, dtype_bytes=4)
        little_cfg = B.BlockConfig(bm=64, bk=128, bn=128, dtype_bytes=4)
        path = _write_biglittle_cache(
            tmp_path, big_cfg, little_cfg, m, k, n,
            big_backend="pallas", little_backend="pallas_lean",
        )
        monkeypatch.setenv(C.ENV_VAR, path)

        am = AsymmetricMesh(
            biglittle_classes(chips_per_pod=1),
            tree_shape=(m, k, n), backend="pallas_interpret",
        )
        # The per-class trees name *different* dispatch-table entries,
        # each mapped onto the interpret family this CPU host runs.
        assert am.class_backends() == {
            "big": "pallas_interpret",
            "little": "pallas_lean_interpret",
        }

        step = am.class_sharded(
            lambda x, w: gemm(x, w),
            mesh=_pod_mesh(2), in_specs=(P("pod"), P()), out_specs=P("pod"),
        )
        assert step.mixed
        assert [(p.pod, p.device_class, p.backend) for p in step.provenance] \
            == [(0, "big", "pallas_interpret"),
                (1, "little", "pallas_lean_interpret")]

        x = _rand((2 * m, k))  # rows split pod-major: big [:m], little [m:]
        w = _rand((k, n))
        out = np.asarray(jax.jit(step)(x, w))

        big_expect = np.asarray(gemm_pallas(x[:m], w, big_cfg, interpret=True))
        little_expect = np.asarray(
            gemm_pallas_lean(x[m:], w, little_cfg, interpret=True)
        )
        assert np.array_equal(out[:m], big_expect)
        assert np.array_equal(out[m:], little_expect)
        assert set(step.trace_log) == {("big", "tuned"), ("little", "tuned")}

    def test_mixed_variant_step_bit_close_to_single_backend_run(
        self, tmp_path, monkeypatch
    ):
        """The lean variant changes scheduling, not numerics: the mixed
        two-variant step is bit-identical to the same step with every
        shard on the default pipelined kernel."""

        m = k = n = 128
        big_cfg = B.BlockConfig(bm=128, bk=128, bn=64, dtype_bytes=4)
        little_cfg = B.BlockConfig(bm=64, bk=128, bn=128, dtype_bytes=4)
        x, w = _rand((2 * m, k)), _rand((k, n))

        outs = {}
        for tag, little_backend in (("mixed", "pallas_lean"), ("single", "pallas")):
            path = _write_biglittle_cache(
                tmp_path / tag, big_cfg, little_cfg, m, k, n,
                big_backend="pallas", little_backend=little_backend,
            )
            monkeypatch.setenv(C.ENV_VAR, path)
            am = AsymmetricMesh(
                biglittle_classes(chips_per_pod=1),
                tree_shape=(m, k, n), backend="pallas_interpret",
            )
            step = am.class_sharded(
                lambda a, b: gemm(a, b),
                mesh=_pod_mesh(2), in_specs=(P("pod"), P()), out_specs=P("pod"),
            )
            outs[tag] = np.asarray(jax.jit(step)(x, w))  # repro: noqa=RPR003 -- two iterations, fresh step per cache config by design
        assert np.array_equal(outs["mixed"], outs["single"])

    def test_vmem_forced_lean_upgrade_no_cache(self, monkeypatch):
        """Without any tuned entries, the §5.3 shared-B-panel constraint
        itself forces little onto the lean kernel at big shapes: the lean
        working set keeps a 4x larger bm than the pipelined shrink."""

        monkeypatch.delenv(C.ENV_VAR, raising=False)
        am = AsymmetricMesh(
            biglittle_classes(chips_per_pod=1),
            tree_shape=(2048, 2048, 2048), backend="pallas_interpret",
        )
        trees = am.control_trees()
        assert am.class_backends() == {
            "big": "pallas_interpret",
            "little": "pallas_lean_interpret",
        }
        big, little = trees["big"], trees["little"]
        assert little.block.bk == big.block.bk       # shared B panel
        assert little.block.bm == 4 * 128            # lean keeps bm=512...
        from repro.core.control_tree import _rederive_bm

        pipelined = _rederive_bm(B.TPU_LITTLE, big.block, 2)
        assert little.block.bm > pipelined.bm        # ...vs 128 pipelined
        assert little.block.fits(B.TPU_LITTLE, double_buffer=False)
        assert not little.block.fits(B.TPU_LITTLE)
        # Provenance surfaces the variant per shard before any tracing.
        step = am.class_sharded(
            lambda a, b: gemm(a, b),
            mesh=_pod_mesh(2), in_specs=(P("pod"), P()), out_specs=P("pod"),
        )
        assert [p.backend for p in step.provenance] \
            == ["pallas_interpret", "pallas_lean_interpret"]


# ---------------------------------------------------------------------------
# Single-class fallback: bit-identical, no shard_map
# ---------------------------------------------------------------------------


class TestSingleClassFallback:
    def test_fallback_is_bit_identical(self):
        am = AsymmetricMesh(
            [DeviceClass("only", chips_per_pod=1, n_pods=2)],
            tree_shape=(128, 128, 128), backend="xla",
        )
        step = am.class_sharded(
            lambda x, w: gemm(x, w),
            mesh=_pod_mesh(2), in_specs=(P("pod"), P()), out_specs=P("pod"),
        )
        assert not step.mixed  # no shard_map on the fallback
        x, w = _rand((256, 128)), _rand((128, 128))
        with am.execution_context():
            expect = gemm(x, w)
        assert np.array_equal(np.asarray(step(x, w)), np.asarray(expect))

    def test_no_pod_axis_falls_back(self):
        am = AsymmetricMesh(biglittle_classes(chips_per_pod=1))
        step = am.class_sharded(
            lambda x, w: gemm(x, w),
            mesh=make_host_mesh(),  # no pod axis
            in_specs=(P("pod"), P()), out_specs=P("pod"),
        )
        assert not step.mixed

    def test_validation(self):
        ctxs = [X.default_context()]
        with pytest.raises(ValueError, match="out of range"):
            X.class_sharded(
                lambda x: x, mesh=_pod_mesh(2), contexts=ctxs, pod_class=[0, 1],
                in_specs=(P("pod"),), out_specs=P("pod"),
            )
        two = [X.default_context(device_class="a"),
               X.default_context(device_class="b")]
        with pytest.raises(ValueError, match="size"):
            X.class_sharded(
                lambda x: x, mesh=_pod_mesh(2), contexts=two,
                pod_class=[0, 1, 1],
                in_specs=(P("pod"),), out_specs=P("pod"),
            )
        with pytest.raises(ValueError, match="no 'pod' axis|has no"):
            X.class_sharded(
                lambda x: x, mesh=make_host_mesh(), contexts=two,
                pod_class=[0, 1],
                in_specs=(P("pod"),), out_specs=P("pod"),
            )


# ---------------------------------------------------------------------------
# Trainer integration: the mixed step trains, exactly
# ---------------------------------------------------------------------------


class TestTrainerMixedStep:
    def _fixture(self):
        from repro.configs import get_config
        from repro.data.pipeline import AsymmetricBatcher, SyntheticLM
        from repro.models import model_zoo as Z

        cfg = get_config("internlm2-1.8b").reduced()
        params = Z.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = Z.make_loss_fn(cfg)
        asym = AsymmetricMesh(
            [DeviceClass("a", chips_per_pod=1),
             DeviceClass("b", chips_per_pod=1, rel_throughput=0.5)],
            strategy="sas", batch_tile=2,
        )
        src = SyntheticLM(vocab=cfg.vocab, seed=0)
        bw = AsymmetricBatcher(src, asym).batch(0, 6, 16)
        batch = jax.tree.map(jnp.asarray, dict(bw.arrays))
        return cfg, params, loss_fn, asym, batch, bw.layout

    def test_weighted_epilogue_equals_manual_split(self):
        """The mixed step's gradients are bit-identical to splitting the
        batch per pod in python and taking the mask-weighted sum — the
        shard_map adds zero numerical deviation of its own."""

        from repro.optim import adamw as O
        from repro.runtime.trainer import build_class_sharded_grad_step

        cfg, params, loss_fn, asym, batch, layout = self._fixture()
        c = layout.c_max
        outs = []
        for i in range(len(layout.sizes)):
            sub = {k: v[i * c : (i + 1) * c] for k, v in batch.items()}
            _, _, g = O.accumulate_gradients(loss_fn, params, sub, 1)
            outs.append((float(sub["mask"].sum()), g))
        total = sum(w for w, _ in outs)
        manual = jax.tree.map(
            lambda *gs: sum(w / total * g for (w, _), g in zip(outs, gs)),
            *[g for _, g in outs],
        )

        mesh = _pod_mesh(2)
        grad_fn = build_class_sharded_grad_step(loss_fn, asym, mesh)
        assert grad_fn.mixed
        _, _, g_mix = jax.jit(grad_fn)(params, batch)
        for a, b in zip(jax.tree.leaves(g_mix), jax.tree.leaves(manual)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_n_micro_accumulation_weighted_by_valid_tokens(self):
        """Regression: with n_micro > 1 a shard's tail micro-batches are
        pure padding; the unweighted micro mean deflated that shard's
        loss/grads before the w_i/W scaling.  The masked-weighted micro
        accumulation must still give exactly the global masked mean (loss)
        and the bit-exact Σ w_ij·g_ij / W gradients."""

        from repro.optim import adamw as O
        from repro.runtime.trainer import build_class_sharded_grad_step

        cfg, params, loss_fn, asym, batch, layout = self._fixture()
        c, n_micro = layout.c_max, 2
        assert c % n_micro == 0
        # little's shard is half padding -> its second micro is all-pad.
        assert layout.sizes[1] <= c // 2

        l_plain, _, _ = O.accumulate_gradients(loss_fn, params, batch, 1)
        grad_fn = build_class_sharded_grad_step(
            loss_fn, asym, _pod_mesh(2), n_micro=n_micro
        )
        l_mix, _, g_mix = jax.jit(grad_fn)(params, batch)
        assert float(l_mix) == pytest.approx(float(l_plain), rel=1e-5)

        # Manual oracle: per pod, per micro, fp32-accumulate w_ij * g_ij
        # in the same order, divide by the global weight.
        mc = c // n_micro
        acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        total = 0.0
        per_pod = []
        for i in range(len(layout.sizes)):
            pod_acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            pod_w = 0.0
            for j in range(n_micro):
                lo = i * c + j * mc
                sub = {k: v[lo : lo + mc] for k, v in batch.items()}
                (_, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, sub)
                w = float(sub["mask"].sum())
                pod_acc = jax.tree.map(lambda a, x: a + w * x, pod_acc, g)
                pod_w += w
            per_pod.append((pod_acc, pod_w))
            total += pod_w
        # Mirror the implementation's order: per-shard mean, then w_i/W.
        manual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        for pod_acc, pod_w in per_pod:
            scale = jnp.float32(pod_w) / total
            manual = jax.tree.map(
                lambda a, x: a + (x / max(pod_w, 1.0)) * scale, manual, pod_acc
            )
        for a, b in zip(jax.tree.leaves(g_mix), jax.tree.leaves(manual)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_mixed_loss_matches_global_masked_mean(self):
        from repro.optim import adamw as O
        from repro.runtime.trainer import build_class_sharded_grad_step

        cfg, params, loss_fn, asym, batch, _ = self._fixture()
        l_plain, _, _ = O.accumulate_gradients(loss_fn, params, batch, 1)
        grad_fn = build_class_sharded_grad_step(loss_fn, asym, _pod_mesh(2))
        l_mix, _, _ = jax.jit(grad_fn)(params, batch)
        assert float(l_plain) == pytest.approx(float(l_mix), rel=1e-5)

    def test_trainer_runs_and_exposes_provenance(self, tmp_path):
        from repro.configs import get_config
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.trainer import Trainer, TrainerConfig

        asym = AsymmetricMesh(
            [DeviceClass("fast", chips_per_pod=1),
             DeviceClass("slow", chips_per_pod=1, rel_throughput=0.5)],
            strategy="ca-das", batch_tile=1,
        )
        t = Trainer(
            get_config("internlm2-1.8b").reduced(),
            _pod_mesh(2),
            tcfg=TrainerConfig(steps=3, global_batch=8, seq_len=32,
                               ckpt_dir=str(tmp_path), ckpt_every=3),
            opt_cfg=AdamWConfig(lr=1e-3, total_steps=3, warmup_steps=1),
            asym=asym,
        )
        assert t.class_sharded_enabled()
        assert [(p.pod, p.device_class) for p in t.class_sharded_step.provenance] \
            == [(0, "fast"), (1, "slow")]
        hist = t.run()
        assert len(hist) == 3 and np.isfinite(hist[-1]["loss"])
        assert {c for c, _ in t.class_sharded_step.trace_log} == {"fast", "slow"}

    def test_trainer_auto_gate_and_force(self, tmp_path):
        from repro.configs import get_config
        from repro.runtime.trainer import Trainer, TrainerConfig

        asym = AsymmetricMesh(
            [DeviceClass("a", chips_per_pod=1),
             DeviceClass("b", chips_per_pod=1, rel_throughput=0.5)],
        )
        # No pod axis: auto stays off (legacy single-context path)...
        t = Trainer(
            get_config("internlm2-1.8b").reduced(), make_host_mesh(),
            tcfg=TrainerConfig(steps=1, global_batch=4, seq_len=16,
                               ckpt_dir=str(tmp_path)),
            asym=asym,
        )
        assert not t.class_sharded_enabled()
        assert t.class_sharded_step is None
        # ...and forcing it is a loud error, not a silent fallback.
        with pytest.raises(ValueError, match="class_sharded=True"):
            Trainer(
                get_config("internlm2-1.8b").reduced(), make_host_mesh(),
                tcfg=TrainerConfig(steps=1, global_batch=4, seq_len=16,
                                   ckpt_dir=str(tmp_path), class_sharded=True),
                asym=asym,
            )


# ---------------------------------------------------------------------------
# DynamicScheduler fed from per-shard timings (CA-DAS feedback closes)
# ---------------------------------------------------------------------------


class TestPerShardFeedback:
    def test_converges_to_calibrated_ratio(self):
        """Per-shard step times derived from the §5.2.2 wallclock
        calibration's measured per-class rates drive the scheduler to the
        calibrated ratio — the full DAS loop: mixed step out, per-shard
        timings in, chunk table re-derived."""

        from benchmarks.bench_schedulers import measure_class_step_times
        from repro.tuning.ratio import calibrate_class_ratios

        classes = biglittle_classes(chips_per_pod=1)
        meas = measure_class_step_times(classes, probe_shape=(128, 128, 128))
        cal = calibrate_class_ratios(classes, backend="wallclock",
                                     measurements=meas)
        per_unit = [m.seconds / m.units for m in meas]

        am = AsymmetricMesh(classes, strategy="ca-das", batch_tile=2)
        for _ in range(25):
            layout = am.batch_layout(64)
            times = [s * t + 1e-12 for s, t in zip(layout.sizes, per_unit)]
            am.observe_step(layout.sizes, times)

        sched_ratio = S.balanced_ratio(list(am.scheduler.rates))
        cal_ratio = S.balanced_ratio(list(cal.ratios))
        assert sched_ratio == pytest.approx(cal_ratio, rel=0.35)

    def test_bench_mixed_step_mode_runs(self):
        from benchmarks.bench_schedulers import mixed_step

        rows = mixed_step(n_rounds=2, global_batch=16,
                          probe_shape=(128, 128, 128), reps=1)
        names = [r.name for r in rows]
        assert "sched_mixed_step" in names
        assert any("shards=[0:big,1:little]" in r.derived for r in rows)


# ---------------------------------------------------------------------------
# Activation constraints under manual axes
# ---------------------------------------------------------------------------


class TestManualAxesGuard:
    def test_pod_spec_helpers(self):
        from repro.distributed import sharding as SH

        am = AsymmetricMesh(biglittle_classes(chips_per_pod=1))
        idx, spec = SH.pod_class_specs(am)
        assert list(idx) == [0, 1] and spec == P("pod")
        assert SH.pod_batch_specs({"tokens": 0, "mask": 0}) == \
            {"tokens": P("pod"), "mask": P("pod")}
        state = {"k": jnp.zeros((2, 4, 3))}
        assert SH.pod_state_specs(state) == {"k": P(None, "pod", None)}

    def test_constrain_drops_manual_axes(self):
        from repro.distributed import sharding as SH

        mesh = _pod_mesh(2)
        SH.use_mesh_for_activations(mesh)
        x = jnp.ones((4, 8))
        with SH.activation_manual_axes(("pod",)):
            # dp axes = ("pod", "data"); pod is manual -> only data (size
            # 1) survives; must trace without touching the pod axis.
            y = SH.constrain_batch(x)
        assert y.shape == x.shape
        assert SH._ACT_MANUAL == frozenset()  # restored on exit

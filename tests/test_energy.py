"""Energy-aware scheduling: power models, objectives, parking (ISSUE 9).

The invariants under test:

  * the spec-level :class:`PowerModel` and the calibrated Exynos simulator
    agree joule-for-joule when fed the same busy/wait split (the
    cross-check :meth:`ClusterModel.power_model` promises);
  * under a *uniform* power model the ``energy`` and ``edp`` objectives
    reduce **bit-identically** to ``perf`` — in the discounts, in the DAS
    greedy schedule, and in the dynamic scheduler's table;
  * under the real asymmetric power model, energy-aware DAS shifts work
    toward the energy-efficient class and spends fewer modeled joules;
  * slot budgets spill to the highest *aggregate*-throughput pod (the
    ISSUE-9 bugfix) and hard-zero parked pods;
  * the serving engine parks inefficient pods at low queue depth under
    ``objective="energy"``, keeps decoded tokens identical to ``perf``,
    and accounts strictly fewer modeled joules on the same trace.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import blocking as B
from repro.core import schedule as S
from repro.core import simulator as sim
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.models import model_zoo as Z
from repro.runtime.serving import ServingEngine
from repro.tuning import measure

RNG = np.random.default_rng(7)


def _biglittle(**kw):
    kw.setdefault("strategy", "ca-das")
    kw.setdefault("batch_tile", 1)
    return AsymmetricMesh(biglittle_classes(chips_per_pod=1), **kw)


# ---------------------------------------------------------------------------
# PowerModel + simulator cross-check
# ---------------------------------------------------------------------------


class TestPowerModel:
    def test_terms(self):
        pm = B.PowerModel(idle_w=10.0, flop_j=1e-12, byte_j=1e-11, poll_frac=0.5)
        assert pm.active_w(1e12, 1e11) == pytest.approx(10.0 + 1.0 + 1.0)
        assert pm.poll_w(1e12, 1e11) == pytest.approx(10.0 + 0.5 * 2.0)
        assert pm.energy_j(2.0, 1e12, 1e11) == pytest.approx(20.0 + 1.0 + 1.0)
        assert pm.gated_w == 0.0

    def test_tpu_constants_mirror_exynos_asymmetry(self):
        # Active-power ratio ~9.5x (A15:A7 cluster ratio), little ~2.4x
        # cheaper per unit of relative throughput — the paper's headline
        # big-is-faster / LITTLE-is-cheaper asymmetry.
        big = B.TPU_V5E_POWER.active_w(B.TPU_V5E.peak_flops, B.TPU_V5E.hbm_bw)
        little = B.TPU_LITTLE_POWER.active_w(
            B.TPU_LITTLE.peak_flops, B.TPU_LITTLE.hbm_bw
        )
        assert 8.0 < big / little < 11.0
        assert 2.0 < (big / 1.0) / (little / 0.25) < 3.0

    def test_cluster_power_model_matches_simulator_energy(self):
        # Same busy/wait split priced both ways on the Exynos 5422
        # constants: through the spec-level PowerModel (active period via
        # energy_j, wait via poll_w) plus the shared P_BASE board term,
        # and through the simulator's _energy.  They must agree exactly.
        clusters = sim.EXYNOS_5422
        busy = [0.8, 0.5]
        cores = [4, 3]
        makespan = 1.0

        spec_side = sim.P_BASE * makespan
        for cl, b, nc in zip(clusters, busy, cores):
            pm = cl.power_model(nc)
            rate = cl.rate(nc)
            spec_side += pm.energy_j(b, rate * b)
            spec_side += pm.poll_w(rate) * (makespan - b)
        sim_side = sim._energy(clusters, busy, cores, makespan)
        assert spec_side == pytest.approx(sim_side, rel=1e-12)

    def test_cluster_power_model_rejects_zero_rate(self):
        with pytest.raises(ValueError, match="effective_rate"):
            sim.A15.power_model(effective_rate=0.0)


# ---------------------------------------------------------------------------
# Objective discounts + DAS / DynamicScheduler reductions
# ---------------------------------------------------------------------------


class TestObjectives:
    def test_validate(self):
        assert set(S.OBJECTIVES) == {"perf", "energy", "edp"}
        for o in S.OBJECTIVES:
            assert S.validate_objective(o) == o
        with pytest.raises(ValueError, match="unknown objective"):
            S.validate_objective("engery")  # repro: noqa=RPR005 -- negative test: unknown name must raise

    def test_uniform_power_discounts_are_exactly_one(self):
        # powers proportional to rates = identical joules per unit: the
        # energy/edp discounts must be exactly 1.0, not approximately.
        rates = [4.0, 1.0, 2.5]
        powers = [r * 37.0 for r in rates]
        for obj in S.OBJECTIVES:
            disc = S.objective_discounts(obj, rates, powers)
            assert np.array_equal(disc, np.ones(3))

    def test_asymmetric_power_discounts_favor_efficient_class(self):
        # big: 290 W at rate 4 (72.5 J/unit); little: 30 W at rate 1.
        disc = S.objective_discounts("energy", [4.0, 1.0], [290.0, 30.0])
        assert disc[1] == 1.0 and 0 < disc[0] < 1
        assert disc[0] == pytest.approx(30.0 / 72.5)
        edp = S.objective_discounts("edp", [4.0, 1.0], [290.0, 30.0])
        assert edp[0] == pytest.approx(np.sqrt(30.0 / 72.5))

    def test_discounts_arity_check(self):
        with pytest.raises(ValueError, match="class powers"):
            S.objective_discounts("energy", [1.0, 2.0], [5.0])

    def test_das_uniform_power_bit_identical_to_perf(self):
        rates, strides = [4.0, 1.0], [8, 8]
        ref = S.das_schedule(96, rates, strides)
        for obj in ("energy", "edp"):
            r = S.das_schedule(
                96, rates, strides, objective=obj,
                powers=[r * 10.0 for r in rates],
            )
            assert [
                (c.cls, c.start, c.size) for c in r.assignments
            ] == [(c.cls, c.start, c.size) for c in ref.assignments]
            assert r.makespan == ref.makespan

    def test_das_energy_shifts_work_to_efficient_class(self):
        rates, strides = [4.0, 1.0], [4, 4]
        perf = S.das_schedule(100, rates, strides)
        energy = S.das_schedule(
            100, rates, strides, objective="energy", powers=[290.0, 30.0]
        )
        assert energy.sizes()[1] > perf.sizes()[1]
        assert sum(energy.sizes()) == 100
        # Physical accounting stays physical: makespan reflects real rates.
        assert energy.makespan >= perf.makespan

    def test_das_energy_accounting_monotone_in_active_joules(self):
        # The discount minimizes *active* joules (powers x busy): the
        # energy objective spends strictly fewer of them than perf.
        # (System-level idle draw over a longer makespan is the serving
        # engine's parking problem, not the intra-step selector's.)
        rates, strides, powers = [4.0, 1.0], [4, 4], [290.0, 30.0]
        perf = S.das_schedule(100, rates, strides, powers=powers)
        energy = S.das_schedule(100, rates, strides, objective="energy",
                                powers=powers)
        assert perf.energy_j is not None and energy.energy_j is not None
        assert energy.energy_j < perf.energy_j

    def test_das_idle_accounting_term(self):
        # idle_powers adds idle x (makespan - busy) per class, exactly.
        rates, strides, powers = [4.0, 1.0], [4, 4], [290.0, 30.0]
        idle = [60.0, 8.0]
        r = S.das_schedule(100, rates, strides, powers=powers,
                           idle_powers=idle)
        expect = sum(p * b for p, b in zip(powers, r.busy)) + sum(
            iw * (r.makespan - b) for iw, b in zip(idle, r.busy)
        )
        assert r.energy_j == pytest.approx(expect)

    def test_dynamic_scheduler_uniform_power_table_identical(self):
        kw = dict(init_ratios=[4.0, 1.0], tiles=[1, 1])
        ref = S.DynamicScheduler(2, **kw).table(100).sizes()
        uni = S.DynamicScheduler(
            2, objective="energy", powers=[40.0, 10.0], **kw
        ).table(100).sizes()
        assert uni == ref

    def test_dynamic_scheduler_energy_table_shifts(self):
        kw = dict(init_ratios=[4.0, 1.0], tiles=[1, 1])
        perf = S.DynamicScheduler(2, **kw).table(100).sizes()
        en = S.DynamicScheduler(
            2, objective="energy", powers=[290.0, 30.0], **kw
        ).table(100).sizes()
        assert en[1] > perf[1] and sum(en) == 100

    def test_dynamic_scheduler_powers_arity(self):
        with pytest.raises(ValueError):
            S.DynamicScheduler(2, objective="energy", powers=[1.0])


# ---------------------------------------------------------------------------
# Cost-model objectives (tuner)
# ---------------------------------------------------------------------------


class TestCostModelObjectives:
    SHAPE = (512, 512, 512)

    def test_breakdown_carries_power(self):
        m, k, n = self.SHAPE
        cfg = measure.cost_breakdown(
            m, k, n, B.derive_block_config(m, k, n)
        )
        assert cfg.power is B.TPU_V5E.power
        assert cfg.flops == pytest.approx(2.0 * m * k * n)
        assert cfg.energy_j > 0 and cfg.edp == pytest.approx(
            cfg.energy_j * cfg.time_s
        )

    def test_score_dispatch(self):
        m, k, n = self.SHAPE
        bd = measure.cost_breakdown(m, k, n, B.derive_block_config(m, k, n))
        assert bd.score("perf") == bd.time_s
        assert bd.score("energy") == bd.energy_j
        assert bd.score("edp") == bd.edp
        with pytest.raises(ValueError):
            bd.score("joules")

    def test_energy_score_orders_same_config_set_consistently(self):
        # Same spec for every candidate: energy = idle*t + work terms with
        # identical flops, so time ranking and energy ranking agree on the
        # winner — the search under "energy" can only match or beat the
        # analytical seed, same as perf.
        m, k, n = self.SHAPE
        fn_p = measure.make_backend("cost-model", spec=B.TPU_V5E)
        fn_e = measure.make_backend(
            "cost-model", spec=B.TPU_V5E, objective="energy"
        )
        cfgs = [
            B.derive_block_config(m, k, n),
            B.BlockConfig(bm=128, bk=128, bn=128),
            B.BlockConfig(bm=256, bk=256, bn=128),
        ]
        best_p = min(cfgs, key=lambda c: fn_p(m, k, n, c))
        best_e = min(cfgs, key=lambda c: fn_e(m, k, n, c))
        assert (best_p.bm, best_p.bk, best_p.bn) == (
            best_e.bm, best_e.bk, best_e.bn
        )

    def test_wallclock_cannot_price_joules(self):
        with pytest.raises(ValueError, match="cost-model"):
            measure.make_backend("wallclock", objective="energy")


# ---------------------------------------------------------------------------
# Mesh power helpers + slot-budget spill (bugfix)
# ---------------------------------------------------------------------------


class TestMeshPower:
    def test_pod_watts_and_efficiency_order(self):
        asym = _biglittle()
        active = asym.pod_active_watts()
        assert active[0] > active[1] > 0
        assert asym.pod_idle_watts() == [
            B.TPU_V5E_POWER.idle_w, B.TPU_LITTLE_POWER.idle_w
        ]
        assert asym.pod_gated_watts() == [0.0, 0.0]
        # little (pod 1) is cheaper per unit of aggregate throughput.
        assert asym.pods_by_efficiency() == [1, 0]

    def test_objective_validated_and_powers_fed_to_scheduler(self):
        asym = _biglittle(objective="energy")
        assert asym.objective == "energy"
        assert asym.scheduler.objective == "energy"
        assert asym.scheduler.powers is not None
        with pytest.raises(ValueError):
            _biglittle(objective="fast")  # repro: noqa=RPR005 -- negative test: unknown name must raise
        # perf mesh keeps the scheduler objective-free (bit-identical).
        assert _biglittle().scheduler.objective == "perf"

    def test_slot_spill_prefers_aggregate_throughput(self):
        # Regression (ISSUE-9 bugfix): spill used to rank by
        # rel_throughput alone, so a one-chip pod with high per-chip
        # throughput absorbed spill before a many-chip pod with far more
        # aggregate capacity.  chips 1/2/8 at rel 1.0/0.9/0.5 → aggregate
        # 1.0/1.8/4.0 → spill lands on pod 2 first.
        classes = [
            DeviceClass(name="solo", chips_per_pod=1, rel_throughput=1.0),
            DeviceClass(name="duo", chips_per_pod=2, rel_throughput=0.9),
            DeviceClass(name="octo", chips_per_pod=8, rel_throughput=0.5),
        ]
        asym = AsymmetricMesh(classes, strategy="ca-das", batch_tile=1)
        budgets = asym.slot_budgets(4, 10)
        assert sum(budgets) == 10
        assert budgets[2] == 4  # largest aggregate pod saturates first
        assert budgets == [2, 4, 4]

    def test_parked_pods_get_zero_budget(self):
        asym = _biglittle()
        assert asym.slot_budgets(4, 3, parked=[0]) == [0, 3]
        # Capacity caps at unparked regions.
        assert asym.slot_budgets(4, 9, parked=[0]) == [0, 4]
        assert sum(asym.slot_budgets(4, 3)) == 3


# ---------------------------------------------------------------------------
# Engine parking + energy accounting (end to end, small)
# ---------------------------------------------------------------------------


class TestEngineEnergy:
    @pytest.fixture(scope="class")
    def small(self):
        cfg = get_config("internlm2-1.8b").reduced()
        params = Z.init_params(jax.random.PRNGKey(0), cfg)
        return cfg, params

    def _run(self, cfg, params, objective, prompts, gen_len):
        eng = ServingEngine(
            cfg, params, _biglittle(objective=objective),
            seq_cap=32, slots_per_pod=4, class_sharded="off",
        )
        out = eng.generate(prompts, gen_len)
        return eng, out

    def test_energy_parks_and_spends_fewer_joules(self, small):
        cfg, params = small
        prompts = RNG.integers(0, cfg.vocab, (3, 4), dtype=np.int32)
        perf_eng, perf_out = self._run(cfg, params, "perf", prompts, 6)
        en_eng, en_out = self._run(cfg, params, "energy", prompts, 6)

        # Tokens are bit-identical: the objective changes placement and
        # pacing, never the math.
        assert np.array_equal(perf_out, en_out)
        # At 3 in-flight requests the little pod alone covers the load
        # (after hysteresis), so the big pod parks under energy.
        assert perf_eng.stats.pod_parks == 0
        assert en_eng.stats.pod_parks >= 1
        assert en_eng._parked == {0}
        # Modeled joules strictly drop; throughput accounting stays sane.
        assert 0 < en_eng.stats.energy_j < perf_eng.stats.energy_j
        assert en_eng.stats.tokens_per_j > perf_eng.stats.tokens_per_j
        assert en_eng.stats.modeled_decode_s > 0

    def test_perf_objective_never_parks(self, small):
        cfg, params = small
        prompts = RNG.integers(0, cfg.vocab, (2, 4), dtype=np.int32)
        eng, _ = self._run(cfg, params, "perf", prompts, 4)
        assert eng._parked == set()
        assert eng.stats.pod_parks == 0 and eng.stats.pod_unparks == 0

    def test_energy_readmits_under_load(self, small):
        # Saturating the slot table forces the parked pod back in:
        # parking is load-adaptive, not a static cap.
        cfg, params = small
        eng = ServingEngine(
            cfg, params, _biglittle(objective="energy"),
            seq_cap=32, slots_per_pod=2, class_sharded="off",
        )
        few = RNG.integers(0, cfg.vocab, (1, 4), dtype=np.int32)
        eng.generate(few, 3)
        assert eng._parked == {0}
        many = RNG.integers(0, cfg.vocab, (4, 4), dtype=np.int32)
        out = eng.generate(many, 3)
        assert out.shape[0] == 4
        assert eng.stats.pod_unparks >= 1

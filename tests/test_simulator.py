"""Validation of the calibrated simulator against the paper's OWN claims.

Calibration inputs are only the single-cluster rates and cache parameters
(Section 3); everything asserted here is a *derived* published result.
"""

import numpy as np
import pytest

from repro.core import simulator as sim

R_BIG = 6144  # paper's largest problem size regime


class TestSingleCluster:
    def test_a15_peak(self):
        # Section 3.4: "the four cores of the Cortex-A15 cluster attain a
        # peak performance of 9.6 GFLOPS"
        g = sim.simulate_single_cluster(R_BIG, sim.A15, 4).gflops
        assert g == pytest.approx(9.6, rel=0.06)

    def test_a7_peak(self):
        # "For the Cortex-A7 cluster, the peak performance is close to 2.4"
        g = sim.simulate_single_cluster(R_BIG, sim.A7, 4).gflops
        assert g == pytest.approx(2.4, rel=0.06)

    def test_a15_over_a7_about_4x(self):
        # "performance achieved by the complete Cortex-A15 cluster is
        # roughly four times that of the Cortex-A7 cluster"
        a15 = sim.simulate_single_cluster(R_BIG, sim.A15, 4).gflops
        a7 = sim.simulate_single_cluster(R_BIG, sim.A7, 4).gflops
        assert 3.3 < a15 / a7 < 4.7

    def test_three_a15_cores_most_energy_efficient(self):
        # Section 3.4: "the most energy-efficient solution is obtained with
        # three cores instead of the complete cluster"
        eff = [
            sim.simulate_single_cluster(R_BIG, sim.A15, n).gflops_per_w
            for n in (1, 2, 3, 4)
        ]
        assert int(np.argmax(eff)) == 2  # 3 cores

    def test_4xa7_more_efficient_than_1xa15(self):
        # "exploitation of four Cortex-A7 cores delivers significantly
        # higher energy efficiency than ... a single Cortex-A15 core,
        # though the overall performance ... is slightly worse"
        a7 = sim.simulate_single_cluster(R_BIG, sim.A7, 4)
        a15 = sim.simulate_single_cluster(R_BIG, sim.A15, 1)
        assert a7.gflops_per_w > a15.gflops_per_w * 1.1
        assert a7.gflops < a15.gflops


class TestSSS:
    def test_sss_is_40pct_of_a15(self):
        # Section 4: SSS on all 8 cores delivers "only about 40% of the
        # highest performance ... employing only the four Cortex-A15 cores"
        sss = sim.simulate_static(R_BIG).gflops
        a15 = sim.simulate_single_cluster(R_BIG, sim.A15, 4).gflops
        assert sss / a15 == pytest.approx(0.40, abs=0.05)

    def test_sss_worst_energy(self):
        # "this configuration achieves the worst energy results"
        sss = sim.simulate_static(R_BIG).gflops_per_w
        others = [
            sim.simulate_single_cluster(R_BIG, sim.A15, 4).gflops_per_w,
            sim.simulate_single_cluster(R_BIG, sim.A7, 4).gflops_per_w,
            sim.simulate_static(R_BIG, ratio=5).gflops_per_w,
            sim.simulate_dynamic(R_BIG).gflops_per_w,
        ]
        assert all(sss < o for o in others)


class TestSAS:
    def test_optimum_ratio_5_to_6(self):
        # Section 5.2.2: "the performance grows until a ratio of 5-6"
        results = sim.sweep_ratio(R_BIG, ratios=range(1, 8))
        best = int(np.argmax([r.gflops for r in results])) + 1
        assert best in (5, 6)

    def test_sas_beats_a15_by_20pct(self):
        # "the increment of performance for SAS compared with ... four
        # Cortex-A15 cores only is close to 20%"
        best = max(r.gflops for r in sim.sweep_ratio(R_BIG, ratios=range(1, 8)))
        a15 = sim.simulate_single_cluster(R_BIG, sim.A15, 4).gflops
        assert best / a15 == pytest.approx(1.20, abs=0.07)

    def test_small_problems_worse(self):
        # "SAS offers lower performance for the small problems"
        small = sim.simulate_static(512, ratio=5).gflops
        big = sim.simulate_static(R_BIG, ratio=5).gflops
        assert small < big

    def test_close_to_ideal(self):
        best = max(r.gflops for r in sim.sweep_ratio(R_BIG, ratios=range(1, 8)))
        assert best > 0.9 * sim.ideal_gflops(R_BIG)


class TestCASAS:
    def test_ca_helps_only_below_ratio_5(self):
        # Section 5.3.1: "improvements at this point are only visible when
        # too much work is assigned to the Cortex-A7 cluster (ratios < 5)"
        for ratio in (1, 3):
            ca = sim.simulate_static(R_BIG, ratio=ratio, cache_aware=True).gflops
            plain = sim.simulate_static(R_BIG, ratio=ratio).gflops
            assert ca > plain * 1.05
        for ratio in (5, 6):
            ca = sim.simulate_static(R_BIG, ratio=ratio, cache_aware=True).gflops
            plain = sim.simulate_static(R_BIG, ratio=ratio).gflops
            assert ca == pytest.approx(plain, rel=0.03)

    def test_loop4_beats_loop5(self):
        # Section 5.3.1 / Figure 11: fine-grain Loop 4 > Loop 5.
        l4 = sim.simulate_static(R_BIG, ratio=5, cache_aware=True, fine="loop4").gflops
        l5 = sim.simulate_static(R_BIG, ratio=5, cache_aware=True, fine="loop5").gflops
        assert l4 > l5


class TestCADAS:
    def test_cadas_beats_das(self):
        # Section 5.4.1: "the use of two control-trees has a great impact"
        cadas = sim.simulate_dynamic(R_BIG, cache_aware=True).gflops
        das = sim.simulate_dynamic(R_BIG, cache_aware=False).gflops
        assert cadas > das * 1.05

    def test_cadas_at_least_best_static_chosen_ratio(self):
        # CA-DAS needs no ratio knob yet matches the tuned CA-SAS(5).
        cadas = sim.simulate_dynamic(R_BIG, cache_aware=True).gflops
        ca_sas5 = sim.simulate_static(R_BIG, ratio=5, cache_aware=True).gflops
        assert cadas >= ca_sas5 * 0.97

    def test_loop4_beats_loop5_dynamic(self):
        l4 = sim.simulate_dynamic(R_BIG, fine="loop4").gflops
        l5 = sim.simulate_dynamic(R_BIG, fine="loop5").gflops
        assert l4 > l5

"""Unit tests for the SSS/SAS/CA-SAS/DAS partitioners (paper Sections 4, 5.2, 5.4)."""

import numpy as np
import pytest

from repro.core import schedule as S


class TestStatic:
    def test_sss_equal(self):
        t = S.sss_partition(100, 4)
        assert t.sizes() == [25, 25, 25, 25]

    def test_sss_remainder(self):
        t = S.sss_partition(10, 3)
        assert sum(t.sizes()) == 10
        assert max(t.sizes()) - min(t.sizes()) <= 1

    def test_sas_ratio(self):
        # Paper Figure 8: ratio 3 -> fast cluster gets 3x the slow one.
        t = S.sas_partition(80, ratios=[3.0, 1.0])
        assert t.sizes() == [60, 20]

    def test_sas_workers(self):
        t = S.sas_partition(100, ratios=[1.0, 1.0], workers=[4, 1])
        assert t.sizes() == [80, 20]

    def test_ca_sas_tile_alignment(self):
        t = S.ca_sas_partition(1000, ratios=[5.0, 1.0], tiles=[152, 32])
        sizes = t.sizes()
        assert sum(sizes) == 1000
        assert sizes[0] % 152 == 0  # big cluster aligned to its m_c

    def test_ca_sas_starved_class_alone_goes_partial(self):
        # Regression: one starved class (tile > its share) must not strip
        # alignment from everyone — only the starved class takes a partial
        # panel; the big class keeps its m_c alignment.
        t = S.ca_sas_partition(1000, ratios=[20.0, 1.0], tiles=[152, 64])
        sizes = t.sizes()
        assert sum(sizes) == 1000
        assert sizes[0] % 152 == 0  # big stays aligned (was unaligned pre-fix)
        assert sizes[1] > 0  # little runs a partial panel + the residue

    def test_ca_sas_three_classes_starvation_localized(self):
        # Middle class starved; the other two keep their own alignment.
        t = S.ca_sas_partition(2048, ratios=[8.0, 0.2, 4.0], tiles=[128, 200, 64])
        sizes = t.sizes()
        assert sum(sizes) == 2048
        assert sizes[0] % 128 == 0
        assert sizes[2] % 64 == 0
        assert 0 < sizes[1] < 200

    def test_validate_rejects_bad_table(self):
        tb = S.ChunkTable(10, (S.Chunk(0, 0, 4), S.Chunk(1, 5, 5)))
        with pytest.raises(ValueError):
            tb.validate()


class TestDynamic:
    def test_das_covers_everything(self):
        r = S.das_schedule(1000, rates=[4.0, 1.0], strides=[152, 32])
        assert sum(r.sizes()) == 1000

    def test_das_balances_by_rate(self):
        r = S.das_schedule(10000, rates=[4.0, 1.0], strides=[100, 100])
        sizes = r.sizes()
        assert 3.0 < sizes[0] / max(sizes[1], 1) < 5.5

    def test_das_makespan_beats_sss(self):
        # The paper's core claim: dynamic beats the oblivious 50/50 split.
        rates, strides = [4.0, 1.0], [152, 32]
        dyn = S.das_schedule(2000, rates=rates, strides=strides)
        half = 1000 / rates[0], 1000 / rates[1]
        sss_makespan = max(half)
        assert dyn.makespan < sss_makespan * 0.6

    def test_das_deterministic(self):
        a = S.das_schedule(500, rates=[2.0, 1.0], strides=[50, 20])
        b = S.das_schedule(500, rates=[2.0, 1.0], strides=[50, 20])
        assert a.assignments == b.assignments

    def test_das_dead_pod_skipped(self):
        # Regression: a zero-rate class used to raise ZeroDivisionError;
        # now the dead pod simply never grabs work.
        r = S.das_schedule(1000, rates=[4.0, 0.0, 1.0], strides=[152, 32, 32])
        sizes = r.sizes()
        assert sum(sizes) == 1000
        assert sizes[1] == 0
        assert sizes[0] > sizes[2] > 0

    def test_das_all_dead_raises(self):
        with pytest.raises(ValueError, match="zero"):
            S.das_schedule(100, rates=[0.0, 0.0], strides=[8, 8])

    def test_das_zero_units_trivial(self):
        r = S.das_schedule(0, rates=[0.0, 0.0], strides=[8, 8])
        assert r.assignments == [] and r.makespan == 0.0


class TestDynamicScheduler:
    def test_converges_to_measured_ratio(self):
        d = S.DynamicScheduler(2, init_ratios=[1.0, 1.0], tiles=[8, 8])
        for _ in range(20):
            t = d.table(256)
            s = t.sizes()
            # pod0 is 3x faster: time proportional to units/rate
            d.observe(s, [s[0] / 3.0 + 1e-9, s[1] / 1.0 + 1e-9])
        s = d.table(256).sizes()
        assert 2.0 < s[0] / max(s[1], 1) < 4.5

    def test_starvation_floor(self):
        d = S.DynamicScheduler(2, init_ratios=[1.0, 1e-6], tiles=[1, 1])
        d.observe([10, 0], [0.1, 0.1])
        assert d.rates[1] >= 0.02 * d.rates[0] * 0.99

    def test_rebalance_counter(self):
        d = S.DynamicScheduler(2, init_ratios=[1.0, 1.0], tiles=[1, 1])
        d.table(100)
        d.observe([50, 50], [0.1, 0.4])
        d.table(100)
        assert d.rebalances >= 1

    def test_hysteresis_holds_table_below_threshold(self):
        # Sub-threshold drift must NOT re-derive the partition: the table
        # object is reused verbatim and no rebalance is counted, even
        # though a fresh SAS split of the drifted rates would differ.
        d = S.DynamicScheduler(2, init_ratios=[1.0, 1.0], tiles=[1, 1],
                               rebalance_threshold=0.05)
        t0 = d.table(100)
        assert t0.sizes() == [50, 50]
        d.rates = np.array([1.06, 1.0])  # fresh SAS would give [51, 49]
        assert not d.needs_rebalance()   # normalized drift ~2.9% < 5%
        t1 = d.table(100)
        assert t1 is t0
        assert d.rebalances == 0

    def test_hysteresis_releases_past_threshold(self):
        d = S.DynamicScheduler(2, init_ratios=[1.0, 1.0], tiles=[1, 1],
                               rebalance_threshold=0.05)
        d.table(100)
        d.rates = np.array([1.3, 1.0])
        assert d.needs_rebalance()       # drift ~13% > 5%
        t1 = d.table(100)
        assert t1.sizes() == [57, 43]
        assert d.rebalances == 1
        # The new rates become the hysteresis anchor.
        assert not d.needs_rebalance()

    def test_hysteresis_different_n_units_rederives_without_counting(self):
        # A different unit count always re-derives (the cached sizes can't
        # cover it) but is not a "rebalance" — the split didn't drift.
        d = S.DynamicScheduler(2, init_ratios=[2.0, 1.0], tiles=[1, 1])
        a = d.table(90)
        b = d.table(60)
        assert sum(a.sizes()) == 90 and sum(b.sizes()) == 60
        assert d.rebalances == 0

    def test_drift_before_any_table_is_infinite(self):
        d = S.DynamicScheduler(2)
        assert d.drift() == float("inf") and d.needs_rebalance()

    def test_observe_rejects_wrong_arity(self):
        # Regression: a caller passing per-pod lists to a per-class
        # scheduler used to corrupt the rate vector silently (numpy
        # broadcast); now it is a hard error naming both lengths.
        d = S.DynamicScheduler(2, init_ratios=[1.0, 1.0], tiles=[1, 1])
        with pytest.raises(ValueError, match="expects 2 per-class"):
            d.observe([10, 10, 10], [0.1, 0.1, 0.1])
        with pytest.raises(ValueError):
            d.observe([10, 10], [0.1, 0.1, 0.1])
        with pytest.raises(ValueError):
            d.observe([10], [0.1, 0.1])

    def test_drift_floored_class_does_not_thrash(self):
        # Regression: drift used to normalize each class's share delta by
        # its OWN reference share, so a class pinned at the 2% starvation
        # floor turned ±50% jitter in its tiny rate into ~50% "drift" and
        # re-partitioned every step.  Normalizing by the max reference
        # share keeps sub-threshold absolute movement sub-threshold.
        d = S.DynamicScheduler(2, init_ratios=[1.0, 1e-6], tiles=[1, 1],
                               rebalance_threshold=0.05)
        d.observe([10, 0], [0.1, 0.1])        # floors class 1 at 2%
        d.table(100)
        floor_rate = d.rates[1]
        d.rates = np.array([d.rates[0], floor_rate * 1.5])  # 50% jitter
        assert d.drift() < 0.05
        assert not d.needs_rebalance()
        # A genuine shift in the class *ratio* still releases: the small
        # class growing to 20% of the big one moves the split ~15%.
        d.rates = np.array([d.rates[0], d.rates[0] * 0.2])
        assert d.needs_rebalance()

    def test_balanced_ratio(self):
        assert S.balanced_ratio([9.6, 2.4]) == pytest.approx(4.0)

    def test_balanced_ratio_order_and_arity(self):
        # Regression: used to hardcode rates[0]/rates[1] — crashed on one
        # class and silently inverted on unsorted rates.
        assert S.balanced_ratio([2.4, 9.6]) == pytest.approx(4.0)
        assert S.balanced_ratio([5.0]) == 1.0
        assert S.balanced_ratio([1.0, 4.0, 2.0]) == pytest.approx(4.0)
        with pytest.raises(ValueError):
            S.balanced_ratio([])
        with pytest.raises(ValueError):
            S.balanced_ratio([1.0, 0.0])

"""Substrate tests: optimizer, data pipeline, checkpointing, collectives,
control trees, HLO analyzer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import blocking as B
from repro.core.control_tree import build_control_trees
from repro.data.pipeline import AsymmetricBatcher, SyntheticLM
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, calibrate_ratios
from repro.distributed.collectives import dequantize_int8, quantize_int8
from repro.launch import hlo_analysis as H
from repro.optim import adamw as O


class TestAdamW:
    def test_reduces_quadratic(self):
        cfg = O.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100,
                            schedule="constant")
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = O.init_opt_state(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, m = O.adamw_update(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = O.clip_by_global_norm(g, 1.0)
        assert float(O.global_norm(clipped)) <= 1.0 + 1e-5
        assert float(norm) > 100

    def test_schedule_warmup_and_decay(self):
        cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(O.lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(O.lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(O.lr_at(cfg, jnp.int32(100))) < 0.01

    def test_grad_accumulation_equivalence(self):
        def loss_fn(p, b):
            pred = b["x"] @ p["w"]
            l = jnp.mean((pred - b["y"]) ** 2)
            return l, {"l": l}

        p = {"w": jnp.ones((4, 2))}
        rng = np.random.default_rng(0)
        batch = {"x": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                 "y": jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)}
        l1, _, g1 = O.accumulate_gradients(loss_fn, p, batch, 1)
        l4, _, g4 = O.accumulate_gradients(loss_fn, p, batch, 4)
        np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]), rtol=1e-4)


class TestData:
    def test_deterministic_resume(self):
        src = SyntheticLM(vocab=100, seed=7)
        a = src.batch(5, 4, 16)
        b = src.batch(5, 4, 16)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_stream(self):
        src = SyntheticLM(vocab=100, seed=7)
        b = src.batch(0, 2, 16)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_asymmetric_batcher_preserves_rows(self):
        src = SyntheticLM(vocab=50, seed=1)
        am = AsymmetricMesh(
            [DeviceClass("a", chips_per_pod=2), DeviceClass("b", chips_per_pod=1,
                                                            rel_throughput=0.5)],
            strategy="sas", batch_tile=2,
        )
        bw = AsymmetricBatcher(src, am).batch(3, 10, 8)
        logical = src.batch(3, 10, 8)
        mask = bw.arrays["mask"][:, 0] > 0
        np.testing.assert_array_equal(bw.arrays["tokens"][mask], logical["tokens"])
        assert bw.arrays["mask"].sum() == 10 * 8

    def test_calibrate_ratios(self):
        r = calibrate_ratios([[0.1, 0.1], [0.4, 0.4]], [8, 8])
        assert r[0] == pytest.approx(1.0)
        assert r[1] == pytest.approx(0.25)


class TestCheckpointer:
    def test_roundtrip_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(3.5)}}
        for step in (1, 2, 3):
            ck.save(step, tree)
        assert ck.committed_steps() == [2, 3]
        out, manifest = ck.restore(tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert manifest["step"] == 3

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=True)
        ck.save(1, {"w": jnp.ones((128, 128))})
        ck.wait()
        assert ck.latest_step() == 1

    def test_restore_specific_step(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=0, async_save=False)
        ck.save(1, {"w": jnp.float32(1)})
        ck.save(2, {"w": jnp.float32(2)})
        out, _ = ck.restore({"w": jnp.float32(0)}, step=1)
        assert float(out["w"]) == 1.0

    def test_shape_mismatch_rejected(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(1, {"w": jnp.ones((2,))})
        with pytest.raises(ValueError):
            ck.restore({"w": jnp.ones((3,))})


class TestCollectives:
    def test_int8_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-7

    def test_error_feedback_reduces_bias(self):
        """Accumulated error feedback keeps the long-run mean unbiased."""

        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 1e-3
        err = jnp.zeros_like(g_true)
        total = jnp.zeros_like(g_true)
        for _ in range(200):
            q, s = quantize_int8(g_true + err)
            g_hat = dequantize_int8(q, s)
            err = g_true + err - g_hat
            total = total + g_hat
        np.testing.assert_allclose(np.asarray(total / 200), np.asarray(g_true),
                                   rtol=0.05, atol=1e-6)


class TestControlTree:
    SPECS = {
        "big": B.TPU_V5E,
        "little": B.TpuCoreSpec(name="little", vmem_bytes=8 * 1024 * 1024),
    }

    def test_two_trees_cache_aware(self):
        trees = build_control_trees(self.SPECS, 4096, 4096, 4096, coarse_loop="rows")
        assert trees["big"].block.bk == trees["little"].block.bk  # shared B panel
        assert trees["little"].block.vmem_bytes() <= 8 * 1024 * 1024 * 0.9
        assert trees["little"].block.bm <= trees["big"].block.bm

    def test_single_tree_oblivious(self):
        trees = build_control_trees(self.SPECS, 4096, 4096, 4096, cache_aware=False)
        assert trees["big"].block == trees["little"].block

    def test_cols_coarse_loop_independent(self):
        trees = build_control_trees(self.SPECS, 4096, 4096, 4096, coarse_loop="cols")
        assert trees["little"].block.fits(self.SPECS["little"])


class TestHloAnalysis:
    def test_scan_trip_multiplication(self):
        L_, D_, B_ = 5, 32, 4

        def f(params, x):
            def layer(x, p):
                return jnp.tanh(x @ p), None
            x, _ = jax.lax.scan(layer, x, params)
            return x.sum()

        params = jnp.ones((L_, D_, D_))
        x = jnp.ones((B_, D_))
        c = jax.jit(f).lower(params, x).compile()
        cost = H.analyze(c.as_text())
        assert cost.flops == pytest.approx(2 * B_ * D_ * D_ * L_, rel=0.01)
        assert list(cost.while_trips.values()) == [L_]

    def test_grad_scan_counts_bwd(self):
        L_, D_, B_ = 4, 16, 2

        def f(params, x):
            def layer(x, p):
                return jnp.tanh(x @ p), None
            x, _ = jax.lax.scan(layer, x, params)
            return x.sum()

        params = jnp.ones((L_, D_, D_))
        x = jnp.ones((B_, D_))
        c = jax.jit(jax.grad(f)).lower(params, x).compile()
        cost = H.analyze(c.as_text())
        assert cost.flops == pytest.approx(3 * 2 * B_ * D_ * D_ * L_, rel=0.01)

    def test_collective_bytes_sharded_matmul(self):
        if jax.device_count() < 1:
            pytest.skip("needs devices")
        # all-reduce from contracting-dim sharding on a 1-device mesh is
        # elided; just assert the analyzer runs on sharded HLO and finds
        # positive bytes.
        from repro.launch.mesh import _mk

        mesh = _mk((1,), ("model",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        f = jax.jit(lambda a, b: a @ b,
                    in_shardings=(NamedSharding(mesh, P(None, "model")),
                                  NamedSharding(mesh, P("model", None))),
                    out_shardings=NamedSharding(mesh, P()))
        with mesh:
            c = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        assert cost.flops > 0 and cost.bytes > 0

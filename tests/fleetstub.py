"""A numpy stand-in for :class:`repro.runtime.serving.ServingEngine`.

Implements exactly the engine surface :class:`repro.runtime.fleet.Fleet`
touches — slot table, class queues, submit/admit/step, the fleet
drain/export/health methods — with a deterministic token function in
place of the jitted decode: generated token ``k`` of a request is a pure
function of its prompt, so bit-identity across engines, migrations, and
retries holds for the stub exactly as greedy decode makes it hold for
the real engine.  This keeps the hypothesis conservation property fast
enough to explore hundreds of seeded fault plans; the real-engine
bit-identity matrix lives in ``test_fleet.py``.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.runtime.serving import Request


def stub_tokens(prompt: np.ndarray, n: int) -> np.ndarray:
    """The stub's "greedy decode": ``n`` generated tokens, a pure
    function of the prompt (the property every fleet exactness test
    leans on)."""

    seed = int(np.asarray(prompt, np.int64).sum()) % 997
    return np.asarray([(seed * 7 + k * 13) % 997 for k in range(n)], np.int32)


@dataclasses.dataclass
class StubCompletion:
    rid: int
    tokens: np.ndarray
    prompt_len: int
    stop: str = "budget"


class _StubStats:
    def __init__(self):
        self.tokens = 0
        self.modeled_decode_s = 0.0


class _StubAsym:
    """Just enough ``asym`` for Fleet's default ``powers``."""

    def __init__(self, watts: float):
        self._watts = watts

    def pod_active_watts(self):
        return [self._watts]


class StubEngine:
    """Slot-table serving semantics without jax: one class queue,
    ``speed`` generated tokens per slot per step on a modeled clock of
    ``1/speed`` seconds per step (so calibrated tps == active slots ×
    speed, like the real engine's row-rate calibration)."""

    def __init__(self, n_slots: int = 2, speed: float = 1.0, watts: float = 10.0):
        if n_slots < 1 or speed <= 0:
            raise ValueError("need n_slots >= 1 and speed > 0")
        self.n_slots = int(n_slots)
        self.speed = float(speed)
        self.queues = [collections.deque()]
        self.slot_rid = np.full(self.n_slots, -1, np.int64)
        self._slot_req: dict[int, Request] = {}
        self._slot_toks: dict[int, list[int]] = {}
        self._slot_remaining: dict[int, int] = {}
        self._next_rid = 0
        self.completions: list[StubCompletion] = []
        self.stats = _StubStats()
        self.asym = _StubAsym(watts)

    # -- the engine API the fleet drives ----------------------------------

    def submit(self, prompt, max_new_tokens: int) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queues[0].append(
            Request(
                rid=rid,
                prompt=np.asarray(prompt, np.int32).reshape(-1),
                max_new_tokens=int(max_new_tokens),
            )
        )
        return rid

    def admit(self) -> int:
        admitted = 0
        for slot in np.nonzero(self.slot_rid < 0)[0]:
            if not self.queues[0]:
                break
            req = self.queues[0].popleft()
            slot = int(slot)
            self.slot_rid[slot] = req.rid
            self._slot_req[slot] = req
            self._slot_toks[slot] = []
            self._slot_remaining[slot] = req.max_new_tokens
            admitted += 1
        return admitted

    def step(self) -> int:
        active = np.nonzero(self.slot_rid >= 0)[0]
        if len(active) == 0:
            return 0
        for slot in active:
            slot = int(slot)
            req = self._slot_req[slot]
            k = len(self._slot_toks[slot])
            self._slot_toks[slot].append(
                int(stub_tokens(req.prompt, k + 1)[k])
            )
            self._slot_remaining[slot] -= 1
            if self._slot_remaining[slot] == 0:
                self._retire(slot)
        self.stats.tokens += len(active)
        self.stats.modeled_decode_s += 1.0 / self.speed
        return len(active)

    def _retire(self, slot: int) -> None:
        req = self._slot_req.pop(slot)
        toks = np.asarray(self._slot_toks.pop(slot), np.int32)
        del self._slot_remaining[slot]
        self.slot_rid[slot] = -1
        self.completions.append(
            StubCompletion(
                rid=req.rid,
                tokens=np.concatenate([req.prompt, toks]),
                prompt_len=len(req.prompt),
            )
        )

    # -- the fleet surface -------------------------------------------------

    def withdraw(self, rid: int):
        for i, req in enumerate(self.queues[0]):
            if req.rid == rid:
                del self.queues[0][i]
                return req
        return None

    def export_queued(self) -> list[Request]:
        out = list(self.queues[0])
        self.queues[0].clear()
        out.sort(key=lambda r: r.rid)
        return out

    def partial_tokens(self, rid: int):
        for slot, req in self._slot_req.items():
            if req.rid == rid:
                return np.asarray(self._slot_toks[slot], np.int32)
        return None

    def calibrated_tps(self) -> float:
        return self.speed

    def health(self) -> dict:
        return {
            "queued": len(self.queues[0]),
            "active": int((self.slot_rid >= 0).sum()),
            "slots": self.n_slots,
            "calibrated_tps": self.calibrated_tps(),
            "completed": len(self.completions),
        }

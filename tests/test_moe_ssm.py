"""Behavioural tests for the MoE dispatch and the Mamba2 SSD block."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.moe import MoEConfig, apply_moe, init_moe, moe_active_params
from repro.models.ssm import (
    SSMConfig,
    apply_mamba2,
    decode_mamba2,
    init_mamba2,
    init_mamba2_state,
)


class TestMoE:
    def _setup(self, n_experts=4, top_k=2, cap=4.0):
        cfg = MoEConfig(d_model=32, n_experts=n_experts, top_k=top_k,
                        d_ff_expert=64, capacity_factor=cap)
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
        return cfg, p, x

    def test_output_shape_and_finite(self):
        cfg, p, x = self._setup()
        y, aux = apply_moe(p, x, cfg)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y, np.float32)).all()
        assert float(aux) >= 0

    def test_matches_dense_expert_sum_at_high_capacity(self):
        """With capacity >> tokens (no drops), MoE output must equal the
        explicit gate-weighted sum over each token's top-k experts."""

        cfg, p, x = self._setup(cap=16.0)
        y, _ = apply_moe(p, x, cfg)

        # oracle: per-token explicit computation
        logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        gw, idx = jax.lax.top_k(probs, cfg.top_k)
        gw = gw / gw.sum(-1, keepdims=True)

        def expert(e, v):
            h = jax.nn.silu(v @ p["w1"][e]) * (v @ p["w3"][e])
            return h @ p["w2"][e]

        expect = jnp.zeros_like(x)
        for b in range(x.shape[0]):
            for s in range(x.shape[1]):
                acc = jnp.zeros((cfg.d_model,))
                for j in range(cfg.top_k):
                    acc += gw[b, s, j] * expert(int(idx[b, s, j]), x[b, s])
                expect = expect.at[b, s].set(acc)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(expect, np.float32), rtol=4e-2, atol=4e-2
        )

    def test_capacity_drops_tokens(self):
        """At tiny capacity some tokens must be dropped (their output is
        only the shared path / zero), never NaN."""

        cfg, p, x = self._setup(cap=0.3)
        y, _ = apply_moe(p, x, cfg)
        assert np.isfinite(np.asarray(y, np.float32)).all()
        y_hi, _ = apply_moe(p, x, MoEConfig(**{**cfg.__dict__, "capacity_factor": 16.0}))
        assert not np.allclose(np.asarray(y), np.asarray(y_hi))

    def test_shared_expert_path(self):
        cfg = MoEConfig(d_model=32, n_experts=4, top_k=2, d_ff_expert=64, d_ff_shared=64)
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
        y, _ = apply_moe(p, x, cfg)
        assert np.isfinite(np.asarray(y, np.float32)).all()

    def test_aux_loss_penalizes_imbalance(self):
        """A router forced to one expert must pay more aux loss than a
        uniform router."""

        cfg, p, x = self._setup()
        x = jnp.abs(x) + 0.5  # positive activations so the collapsed
        # router's logit_0 = 10*sum(x) is large for every token
        p_uniform = dict(p, router=jnp.zeros_like(p["router"]))
        p_collapsed = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(10.0))
        _, aux_u = apply_moe(p_uniform, x, cfg)
        _, aux_c = apply_moe(p_collapsed, x, cfg)
        assert float(aux_c) > float(aux_u)

    def test_active_params(self):
        cfg = MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff_expert=64)
        assert moe_active_params(cfg) < 8 / 2 * moe_active_params(cfg)


class TestMamba2:
    CFG = SSMConfig(d_model=64, d_state=16, headdim=16, expand=2, chunk=8)

    def test_chunk_size_invariance(self):
        p = init_mamba2(jax.random.PRNGKey(0), self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64), jnp.float32)
        y8, f8 = apply_mamba2(p, x, self.CFG)
        cfg32 = SSMConfig(**{**self.CFG.__dict__, "chunk": 32})
        y32, f32 = apply_mamba2(p, x, cfg32)
        np.testing.assert_allclose(np.asarray(y8, np.float32), np.asarray(y32, np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(f8), np.asarray(f32), rtol=1e-3, atol=1e-3)

    def test_decode_matches_full_sequence(self):
        p = init_mamba2(jax.random.PRNGKey(0), self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
        y_full, f_full = apply_mamba2(p, x, self.CFG)
        st = init_mamba2_state(2, self.CFG)
        ys = []
        for t in range(16):
            yt, st = decode_mamba2(p, x[:, t : t + 1], self.CFG, st)
            ys.append(yt)
        y_dec = jnp.concatenate(ys, 1)
        np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                                   np.asarray(y_full, np.float32), rtol=6e-2, atol=6e-2)
        np.testing.assert_allclose(np.asarray(st["ssm"]), np.asarray(f_full),
                                   rtol=1e-3, atol=1e-3)

    def test_state_carries_context(self):
        """The recurrent state must make outputs depend on the past."""

        p = init_mamba2(jax.random.PRNGKey(0), self.CFG)
        tok = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 64), jnp.float32)
        st0 = init_mamba2_state(1, self.CFG)
        y_fresh, _ = decode_mamba2(p, tok, self.CFG, st0)
        # warm the state with some context first
        ctx = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 64), jnp.float32)
        st = st0
        for t in range(4):
            _, st = decode_mamba2(p, ctx[:, t : t + 1], self.CFG, st)
        y_warm, _ = decode_mamba2(p, tok, self.CFG, st)
        assert not np.allclose(np.asarray(y_fresh), np.asarray(y_warm), atol=1e-4)

    def test_decay_bounds_state(self):
        """With A<0 the state norm must stay bounded over a long roll."""

        p = init_mamba2(jax.random.PRNGKey(0), self.CFG)
        st = init_mamba2_state(1, self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 64), jnp.float32)
        norms = []
        for _ in range(64):
            _, st = decode_mamba2(p, x, self.CFG, st)
            norms.append(float(jnp.linalg.norm(st["ssm"])))
        assert norms[-1] < 10 * max(norms[:8]) + 10

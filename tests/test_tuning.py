"""Tests for the repro.tuning autotuning subsystem."""

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blocking as B
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.kernels import ref
from repro.kernels.gemm import gemm_pallas, resolve_block_config
from repro.tuning import cache as C
from repro.tuning import candidates as CAND
from repro.tuning import measure as M
from repro.tuning import ratio as R
from repro.tuning import tune as T

SHAPES = [(256, 256, 256), (512, 512, 512), (300, 1100, 200), (1024, 2048, 512)]


# ---------------------------------------------------------------------------
# Candidates: every candidate feasible, analytical always included
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("spec_name", sorted(CAND.SPECS))
def test_candidates_feasible_and_aligned(shape, spec_name):
    m, k, n = shape
    spec = CAND.get_spec(spec_name)
    cands = CAND.enumerate_candidates(m, k, n, spec=spec)
    assert cands, "candidate set must be non-empty"
    for cfg in cands:
        assert cfg.fits(spec), f"{cfg} exceeds the VMEM budget of {spec_name}"
        assert cfg.bm % spec.mxu == 0
        assert cfg.bk % spec.mxu == 0
        assert cfg.bn % spec.mxu == 0


@pytest.mark.parametrize("shape", SHAPES)
def test_candidates_include_analytical(shape):
    m, k, n = shape
    seed = CAND.analytical_config(m, k, n)
    cands = CAND.enumerate_candidates(m, k, n)
    assert cands[0] == seed
    keys = {(c.bm, c.bk, c.bn) for c in cands}
    assert len(keys) == len(cands), "candidates must be deduplicated"


def test_neighborhood_feasible():
    seed = CAND.analytical_config(512, 512, 512)
    for cfg in CAND.neighborhood(seed):
        assert cfg.fits(B.TPU_V5E)
        assert cfg != seed or True  # perturbed dims stay aligned
        assert cfg.bm % 128 == 0 and cfg.bk % 128 == 0 and cfg.bn % 128 == 0


# ---------------------------------------------------------------------------
# Cost model: deterministic, sane, and the search never loses to analytical
# ---------------------------------------------------------------------------


def test_cost_model_deterministic_and_positive():
    cfg = B.BlockConfig(bm=256, bk=256, bn=256)
    t1 = M.cost_model_time(512, 512, 512, cfg)
    t2 = M.cost_model_time(512, 512, 512, cfg)
    assert t1 == t2 > 0.0


def test_cost_model_charges_padding():
    # A 1024-block on a 512 problem pays for computed zeros.
    small = B.BlockConfig(bm=512, bk=512, bn=512)
    big = B.BlockConfig(bm=1024, bk=512, bn=512)
    assert M.cost_model_time(512, 512, 512, big) > M.cost_model_time(512, 512, 512, small)


def test_cost_model_charges_grid_overhead():
    # Thousands of tiny blocks launch-cost more than tens of large ones.
    tiny = B.BlockConfig(bm=128, bk=128, bn=128)
    large = B.BlockConfig(bm=512, bk=512, bn=512)
    assert M.cost_model_time(2048, 2048, 2048, tiny) > M.cost_model_time(
        2048, 2048, 2048, large
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_search_no_worse_than_analytical(shape):
    m, k, n = shape
    backend = M.make_backend("cost-model")
    res = T.search_shape(m, k, n, spec=B.TPU_V5E, dtype_bytes=2, backend=backend)
    assert res.best_time_s <= res.analytical_time_s
    assert res.speedup >= 1.0


# ---------------------------------------------------------------------------
# Cache: roundtrip, version invalidation, atomicity, fallback
# ---------------------------------------------------------------------------


def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cfg = B.BlockConfig(bm=256, bk=512, bn=256)
    cache.put("tpu-v5e", "bfloat16", 512, 512, 512, cfg, backend="cost-model", time_s=1e-3)
    cache.save()

    loaded = C.TuningCache.load(path)
    got = loaded.get("tpu-v5e", "bfloat16", 512, 512, 512)
    assert got == cfg
    # Bucketing: a shape padding to the same 128-aligned dims hits the entry.
    assert loaded.get("tpu-v5e", "bfloat16", 500, 450, 390) == cfg
    # A smaller problem in a different bucket must NOT alias onto it —
    # its blocks would overshoot the problem and pay padded FLOPs.
    assert loaded.get("tpu-v5e", "bfloat16", 260, 260, 260) is None
    # Different dtype / spec miss.
    assert loaded.get("tpu-v5e", "float32", 512, 512, 512) is None
    assert loaded.get("tpu-little", "bfloat16", 512, 512, 512) is None


def test_cache_version_mismatch_invalidates(tmp_path):
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        json.dump(
            {
                "version": C.CACHE_VERSION + 1,
                "entries": {"tpu-v5e/bfloat16/512x512x512": {"bm": 256, "bk": 256, "bn": 256}},
            },
            f,
        )
    loaded = C.TuningCache.load(path)
    assert loaded.entries == {}
    # Fallback on miss returns the analytical derivation.
    cfg, hit = loaded.lookup_or_analytical(512, 512, 512)
    assert not hit
    assert cfg == B.derive_block_config(512, 512, 512, dtype_bytes=2)


def test_cache_corrupt_file_starts_empty(tmp_path):
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        f.write("{not json")
    assert C.TuningCache.load(path).entries == {}


def test_cache_non_object_json_starts_empty(tmp_path):
    # e.g. $REPRO_TUNING_CACHE accidentally pointed at BENCH_gemm.json
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        json.dump([{"bench": "gemm"}], f)
    assert C.TuningCache.load(path).entries == {}


def test_cache_malformed_entry_is_a_miss(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    key = C.shape_bucket_key("tpu-v5e", "float32", 256, 256, 256)
    with open(path, "w") as f:
        json.dump({"version": C.CACHE_VERSION, "entries": {key: {"oops": 1}}}, f)
    loaded = C.TuningCache.load(path)
    assert loaded.get("tpu-v5e", "float32", 256, 256, 256) is None
    # ...and the kernel hot path falls back to analytical instead of crashing.
    monkeypatch.setenv(C.ENV_VAR, path)
    cfg = resolve_block_config(256, 256, 256, jnp.dtype(jnp.float32))
    assert cfg == B.derive_block_config(256, 256, 256, dtype_bytes=4)


def test_cache_atomic_write_leaves_no_temp(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put("tpu-v5e", "bfloat16", 128, 128, 128, B.BlockConfig(128, 128, 128))
    cache.save()
    leftovers = [p for p in os.listdir(tmp_path) if p.startswith(".tuning-cache-")]
    assert leftovers == []
    assert json.load(open(path))["version"] == C.CACHE_VERSION


# ---------------------------------------------------------------------------
# tune CLI: search -> write -> second run hits the cache
# ---------------------------------------------------------------------------


def test_tune_cli_writes_cache_and_hits_on_rerun(tmp_path, caplog):
    path = str(tmp_path / "cache.json")
    argv = [
        "--spec", "tpu-v5e", "--backend", "cost-model",
        "--shapes", "512x512x512,1024x1024x1024", "--cache", path,
    ]
    summary = T.main(argv)
    assert os.path.exists(path)
    assert len(summary["shapes"]) == 2
    for rec in summary["shapes"]:
        assert not rec["cache_hit"]
        assert rec["best_time_s"] <= rec["analytical_time_s"]

    import logging

    with caplog.at_level(logging.INFO, logger="repro.tuning.tune"):
        summary2 = T.main(argv)
    assert all(rec["cache_hit"] for rec in summary2["shapes"])
    assert any("cache hit" in r.message for r in caplog.records)


def test_tune_cli_calibrate_ratios_with_wallclock_backend(tmp_path):
    # --calibrate-ratios must not crash under --backend wallclock: the
    # ratio calibration always uses the cost model (one host cannot
    # wallclock-compare heterogeneous specs).
    path = str(tmp_path / "cache.json")
    summary = T.main(
        ["--backend", "wallclock", "--shapes", "128x128x128", "--cache", path,
         "--max-candidates", "1", "--calibrate-ratios"]
    )
    assert len(summary["init_ratios"]) == 2
    assert summary["init_ratios"][1] < 1.0


def test_tune_cli_dry_run_writes_nothing(tmp_path):
    path = str(tmp_path / "cache.json")
    summary = T.main(
        ["--backend", "cost-model", "--cache", path, "--dry-run"]
    )
    assert summary["cache_path"] is None
    assert not os.path.exists(path)
    assert summary["shapes"], "dry run still searches the default shapes"


def test_parse_shapes_rejects_garbage():
    assert T.parse_shapes("512x512x512") == [(512, 512, 512)]
    with pytest.raises(ValueError):
        T.parse_shapes("512x512")
    with pytest.raises(ValueError):
        T.parse_shapes("")


# ---------------------------------------------------------------------------
# Kernel integration: REPRO_TUNING_CACHE drives cfg=None resolution
# ---------------------------------------------------------------------------


def _write_cache(tmp_path, cfg, m, k, n, dtype_name="float32"):
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put("tpu-v5e", dtype_name, m, k, n, cfg, backend="test")
    cache.save()
    return path


def test_gemm_resolves_cached_config(tmp_path, monkeypatch):
    # A deliberately distinctive config the analytical route would not pick.
    tuned = B.BlockConfig(bm=128, bk=256, bn=128, dtype_bytes=4)
    path = _write_cache(tmp_path, tuned, 256, 256, 256)
    monkeypatch.setenv(C.ENV_VAR, path)
    cfg = resolve_block_config(256, 256, 256, jnp.dtype(jnp.float32))
    assert (cfg.bm, cfg.bk, cfg.bn) == (128, 256, 128)

    # Unset -> analytical, untouched defaults.
    monkeypatch.delenv(C.ENV_VAR)
    cfg = resolve_block_config(256, 256, 256, jnp.dtype(jnp.float32))
    assert cfg == B.derive_block_config(256, 256, 256, dtype_bytes=4)


def test_gemm_pallas_with_cache_matches_oracle(tmp_path, monkeypatch):
    m = k = n = 256
    tuned = B.BlockConfig(bm=128, bk=128, bn=256, dtype_bytes=4)
    path = _write_cache(tmp_path, tuned, m, k, n)
    monkeypatch.setenv(C.ENV_VAR, path)

    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out_cached = gemm_pallas(a, b, interpret=True)

    monkeypatch.delenv(C.ENV_VAR)
    expect = ref.gemm_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out_cached), np.asarray(expect), rtol=1e-5, atol=1e-4
    )
    # And explicitly through the tuned config equals the cached-path result
    # bit for bit (same block shapes -> same arithmetic order).
    out_explicit = gemm_pallas(a, b, tuned, interpret=True)
    assert np.array_equal(np.asarray(out_cached), np.asarray(out_explicit))


def test_cached_config_dtype_bytes_reconciled(tmp_path, monkeypatch):
    # Cache tuned for bf16; a float32 call must not inherit dtype_bytes=2.
    tuned = B.BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=2)
    path = _write_cache(tmp_path, tuned, 128, 128, 128, dtype_name="float32")
    monkeypatch.setenv(C.ENV_VAR, path)
    cfg = resolve_block_config(128, 128, 128, jnp.dtype(jnp.float32))
    assert cfg.dtype_bytes == 4


# ---------------------------------------------------------------------------
# Ratio calibration: measured ratios replace hand-typed rel_throughput
# ---------------------------------------------------------------------------


def test_calibrate_biglittle_ratios():
    classes = biglittle_classes()
    cal = R.calibrate_class_ratios(classes, backend="cost-model")
    assert cal.class_names == ("big", "little")
    assert cal.ratios[0] == 1.0
    # The little spec has half the peak FLOPs and HBM bandwidth — the
    # calibrated ratio must reflect real hardware degradation, not just
    # block-config noise (regression: a spec that only overrode VMEM made
    # this come out ~0.78).
    assert 0.0 < cal.ratios[1] < 0.6
    assert cal.knob() > 1.5


def test_mesh_from_calibration():
    classes = biglittle_classes()
    mesh = AsymmetricMesh.from_calibration(classes, strategy="ca-sas", batch_tile=8)
    assert mesh.calibration is not None
    assert mesh.classes[0].rel_throughput == 1.0
    assert mesh.classes[1].rel_throughput == pytest.approx(
        mesh.calibration.ratios[1]
    )
    # The calibrated mesh still schedules exactly.
    layout = mesh.batch_layout(256)
    assert sum(layout.sizes) == 256
    # The faster class gets strictly more work.
    assert layout.sizes[0] > layout.sizes[1]


def test_mesh_from_calibration_explicit_calibration():
    classes = biglittle_classes()
    cal = R.Calibration(
        class_names=("big", "little"),
        ratios=(1.0, 0.5),
        probe_shape=(512, 512, 512),
        backend="cost-model",
        times_s=(1.0, 2.0),
    )
    mesh = AsymmetricMesh.from_calibration(classes, cal, strategy="sas")
    assert mesh.classes[1].rel_throughput == 0.5


def test_wallclock_calibration_rejects_heterogeneous_specs():
    # One host cannot time two different core specs; the calibration must
    # refuse rather than silently produce ~1:1 ratios.
    with pytest.raises(ValueError, match="heterogeneous"):
        R.calibrate_class_ratios(biglittle_classes(), backend="wallclock")


def test_sweep_ratio_knob_prefers_asymmetric():
    best, results = R.sweep_ratio_knob(2048, ratios=(1, 2, 3, 4, 5, 6, 7))
    # The paper's sweep peaks in the 3-6 region (A15:A7 ≈ 4), never at 1.
    assert best > 1.0
    assert len(results) == 7


# ---------------------------------------------------------------------------
# Measurement backends agree on ordering for a clear-cut case
# ---------------------------------------------------------------------------


def test_wallclock_backend_runs_small():
    cfg = B.BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
    t = M.wallclock_time(128, 128, 128, cfg, dtype=jnp.float32, reps=1, warmup=0)
    assert t > 0.0

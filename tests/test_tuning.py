"""Tests for the repro.tuning autotuning subsystem."""

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blocking as B
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.kernels import ref
from repro.kernels.gemm import gemm_pallas, resolve_block_config
from repro.tuning import cache as C
from repro.tuning import candidates as CAND
from repro.tuning import measure as M
from repro.tuning import ratio as R
from repro.tuning import tune as T

SHAPES = [(256, 256, 256), (512, 512, 512), (300, 1100, 200), (1024, 2048, 512)]


# ---------------------------------------------------------------------------
# Candidates: every candidate feasible, analytical always included
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("spec_name", sorted(CAND.SPECS))
def test_candidates_feasible_and_aligned(shape, spec_name):
    m, k, n = shape
    spec = CAND.get_spec(spec_name)
    cands = CAND.enumerate_candidates(m, k, n, spec=spec)
    assert cands, "candidate set must be non-empty"
    for cfg in cands:
        assert cfg.fits(spec), f"{cfg} exceeds the VMEM budget of {spec_name}"
        assert cfg.bm % spec.mxu == 0
        assert cfg.bk % spec.mxu == 0
        assert cfg.bn % spec.mxu == 0


@pytest.mark.parametrize("shape", SHAPES)
def test_candidates_include_analytical(shape):
    m, k, n = shape
    seed = CAND.analytical_config(m, k, n)
    cands = CAND.enumerate_candidates(m, k, n)
    assert cands[0] == seed
    keys = {(c.bm, c.bk, c.bn) for c in cands}
    assert len(keys) == len(cands), "candidates must be deduplicated"


def test_neighborhood_feasible():
    seed = CAND.analytical_config(512, 512, 512)
    for cfg in CAND.neighborhood(seed):
        assert cfg.fits(B.TPU_V5E)
        assert cfg != seed or True  # perturbed dims stay aligned
        assert cfg.bm % 128 == 0 and cfg.bk % 128 == 0 and cfg.bn % 128 == 0


# ---------------------------------------------------------------------------
# Cost model: deterministic, sane, and the search never loses to analytical
# ---------------------------------------------------------------------------


def test_cost_model_deterministic_and_positive():
    cfg = B.BlockConfig(bm=256, bk=256, bn=256)
    t1 = M.cost_model_time(512, 512, 512, cfg)
    t2 = M.cost_model_time(512, 512, 512, cfg)
    assert t1 == t2 > 0.0


def test_cost_model_charges_padding():
    # A 1024-block on a 512 problem pays for computed zeros.
    small = B.BlockConfig(bm=512, bk=512, bn=512)
    big = B.BlockConfig(bm=1024, bk=512, bn=512)
    assert M.cost_model_time(512, 512, 512, big) > M.cost_model_time(512, 512, 512, small)


def test_cost_model_charges_grid_overhead():
    # Thousands of tiny blocks launch-cost more than tens of large ones.
    tiny = B.BlockConfig(bm=128, bk=128, bn=128)
    large = B.BlockConfig(bm=512, bk=512, bn=512)
    assert M.cost_model_time(2048, 2048, 2048, tiny) > M.cost_model_time(
        2048, 2048, 2048, large
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_search_no_worse_than_analytical(shape):
    m, k, n = shape
    backend = M.make_backend("cost-model")
    res = T.search_shape(m, k, n, spec=B.TPU_V5E, dtype_bytes=2, backend=backend)
    assert res.best_time_s <= res.analytical_time_s
    assert res.speedup >= 1.0


# ---------------------------------------------------------------------------
# Cache: roundtrip, version invalidation, atomicity, fallback
# ---------------------------------------------------------------------------


def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cfg = B.BlockConfig(bm=256, bk=512, bn=256)
    cache.put("tpu-v5e", "bfloat16", 512, 512, 512, cfg, backend="cost-model", time_s=1e-3)
    cache.save()

    loaded = C.TuningCache.load(path)
    got = loaded.get("tpu-v5e", "bfloat16", 512, 512, 512)
    assert got == cfg
    # Bucketing: a shape padding to the same 128-aligned dims hits the entry.
    assert loaded.get("tpu-v5e", "bfloat16", 500, 450, 390) == cfg
    # A smaller problem in a different bucket must NOT alias onto it —
    # its blocks would overshoot the problem and pay padded FLOPs.
    assert loaded.get("tpu-v5e", "bfloat16", 260, 260, 260) is None
    # Different dtype / spec miss.
    assert loaded.get("tpu-v5e", "float32", 512, 512, 512) is None
    assert loaded.get("tpu-little", "bfloat16", 512, 512, 512) is None


def test_cache_version_mismatch_invalidates(tmp_path):
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        json.dump(
            {
                "version": C.CACHE_VERSION + 1,
                "entries": {"tpu-v5e/bfloat16/512x512x512": {"bm": 256, "bk": 256, "bn": 256}},
            },
            f,
        )
    loaded = C.TuningCache.load(path)
    assert loaded.entries == {}
    # Fallback on miss returns the analytical derivation.
    cfg, hit = loaded.lookup_or_analytical(512, 512, 512)
    assert not hit
    assert cfg == B.derive_block_config(512, 512, 512, dtype_bytes=2)


def test_cache_corrupt_file_starts_empty(tmp_path):
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        f.write("{not json")
    assert C.TuningCache.load(path).entries == {}


def test_cache_non_object_json_starts_empty(tmp_path):
    # e.g. $REPRO_TUNING_CACHE accidentally pointed at BENCH_gemm.json
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        json.dump([{"bench": "gemm"}], f)
    assert C.TuningCache.load(path).entries == {}


def test_cache_malformed_entry_is_a_miss(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    key = C.shape_bucket_key("tpu-v5e", "float32", 256, 256, 256)
    with open(path, "w") as f:
        json.dump({"version": C.CACHE_VERSION, "entries": {key: {"oops": 1}}}, f)
    loaded = C.TuningCache.load(path)
    assert loaded.get("tpu-v5e", "float32", 256, 256, 256) is None
    # ...and the kernel hot path falls back to analytical instead of crashing.
    monkeypatch.setenv(C.ENV_VAR, path)
    cfg = resolve_block_config(256, 256, 256, jnp.dtype(jnp.float32))
    assert cfg == B.derive_block_config(256, 256, 256, dtype_bytes=4)


def test_cache_atomic_write_leaves_no_temp(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put("tpu-v5e", "bfloat16", 128, 128, 128, B.BlockConfig(128, 128, 128))
    cache.save()
    leftovers = [p for p in os.listdir(tmp_path) if p.startswith(".tuning-cache-")]
    assert leftovers == []
    assert json.load(open(path))["version"] == C.CACHE_VERSION


# ---------------------------------------------------------------------------
# tune CLI: search -> write -> second run hits the cache
# ---------------------------------------------------------------------------


def test_tune_cli_writes_cache_and_hits_on_rerun(tmp_path, caplog):
    path = str(tmp_path / "cache.json")
    argv = [
        "--spec", "tpu-v5e", "--backend", "cost-model",
        "--shapes", "512x512x512,1024x1024x1024", "--cache", path,
    ]
    summary = T.main(argv)
    assert os.path.exists(path)
    assert len(summary["shapes"]) == 2
    for rec in summary["shapes"]:
        assert not rec["cache_hit"]
        assert rec["best_time_s"] <= rec["analytical_time_s"]

    import logging

    with caplog.at_level(logging.INFO, logger="repro.tuning.tune"):
        summary2 = T.main(argv)
    assert all(rec["cache_hit"] for rec in summary2["shapes"])
    assert any("cache hit" in r.message for r in caplog.records)


def test_tune_cli_calibrate_ratios_with_wallclock_backend(tmp_path):
    # --calibrate-ratios must not crash under --backend wallclock: the
    # ratio calibration always uses the cost model (one host cannot
    # wallclock-compare heterogeneous specs).
    path = str(tmp_path / "cache.json")
    summary = T.main(
        ["--backend", "wallclock", "--shapes", "128x128x128", "--cache", path,
         "--max-candidates", "1", "--calibrate-ratios"]
    )
    assert len(summary["init_ratios"]) == 2
    assert summary["init_ratios"][1] < 1.0


def test_tune_cli_dry_run_writes_nothing(tmp_path):
    path = str(tmp_path / "cache.json")
    summary = T.main(
        ["--backend", "cost-model", "--cache", path, "--dry-run"]
    )
    assert summary["cache_path"] is None
    assert not os.path.exists(path)
    assert summary["shapes"], "dry run still searches the default shapes"


def test_parse_shapes_rejects_garbage():
    assert T.parse_shapes("512x512x512") == [(512, 512, 512)]
    with pytest.raises(ValueError):
        T.parse_shapes("512x512")
    with pytest.raises(ValueError):
        T.parse_shapes("")


# ---------------------------------------------------------------------------
# Kernel integration: REPRO_TUNING_CACHE drives cfg=None resolution
# ---------------------------------------------------------------------------


def _write_cache(tmp_path, cfg, m, k, n, dtype_name="float32"):
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put("tpu-v5e", dtype_name, m, k, n, cfg, backend="test")  # repro: noqa=RPR005 -- fixture provenance label, not a dispatch token
    cache.save()
    return path


def test_gemm_resolves_cached_config(tmp_path, monkeypatch):
    # A deliberately distinctive config the analytical route would not pick.
    tuned = B.BlockConfig(bm=128, bk=256, bn=128, dtype_bytes=4)
    path = _write_cache(tmp_path, tuned, 256, 256, 256)
    monkeypatch.setenv(C.ENV_VAR, path)
    cfg = resolve_block_config(256, 256, 256, jnp.dtype(jnp.float32))
    assert (cfg.bm, cfg.bk, cfg.bn) == (128, 256, 128)

    # Unset -> analytical, untouched defaults.
    monkeypatch.delenv(C.ENV_VAR)
    cfg = resolve_block_config(256, 256, 256, jnp.dtype(jnp.float32))
    assert cfg == B.derive_block_config(256, 256, 256, dtype_bytes=4)


def test_gemm_pallas_with_cache_matches_oracle(tmp_path, monkeypatch):
    m = k = n = 256
    tuned = B.BlockConfig(bm=128, bk=128, bn=256, dtype_bytes=4)
    path = _write_cache(tmp_path, tuned, m, k, n)
    monkeypatch.setenv(C.ENV_VAR, path)

    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out_cached = gemm_pallas(a, b, interpret=True)

    monkeypatch.delenv(C.ENV_VAR)
    expect = ref.gemm_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out_cached), np.asarray(expect), rtol=1e-5, atol=1e-4
    )
    # And explicitly through the tuned config equals the cached-path result
    # bit for bit (same block shapes -> same arithmetic order).
    out_explicit = gemm_pallas(a, b, tuned, interpret=True)
    assert np.array_equal(np.asarray(out_cached), np.asarray(out_explicit))


def test_cached_config_dtype_bytes_reconciled(tmp_path, monkeypatch):
    # Cache tuned for bf16; a float32 call must not inherit dtype_bytes=2.
    tuned = B.BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=2)
    path = _write_cache(tmp_path, tuned, 128, 128, 128, dtype_name="float32")
    monkeypatch.setenv(C.ENV_VAR, path)
    cfg = resolve_block_config(128, 128, 128, jnp.dtype(jnp.float32))
    assert cfg.dtype_bytes == 4


# ---------------------------------------------------------------------------
# Ratio calibration: measured ratios replace hand-typed rel_throughput
# ---------------------------------------------------------------------------


def test_calibrate_biglittle_ratios():
    classes = biglittle_classes()
    cal = R.calibrate_class_ratios(classes, backend="cost-model")
    assert cal.class_names == ("big", "little")
    assert cal.ratios[0] == 1.0
    # The little spec has half the peak FLOPs and HBM bandwidth — the
    # calibrated ratio must reflect real hardware degradation, not just
    # block-config noise (regression: a spec that only overrode VMEM made
    # this come out ~0.78).
    assert 0.0 < cal.ratios[1] < 0.6
    assert cal.knob() > 1.5


def test_mesh_from_calibration():
    classes = biglittle_classes()
    mesh = AsymmetricMesh.from_calibration(classes, strategy="ca-sas", batch_tile=8)
    assert mesh.calibration is not None
    assert mesh.classes[0].rel_throughput == 1.0
    assert mesh.classes[1].rel_throughput == pytest.approx(
        mesh.calibration.ratios[1]
    )
    # The calibrated mesh still schedules exactly.
    layout = mesh.batch_layout(256)
    assert sum(layout.sizes) == 256
    # The faster class gets strictly more work.
    assert layout.sizes[0] > layout.sizes[1]


def test_mesh_from_calibration_explicit_calibration():
    classes = biglittle_classes()
    cal = R.Calibration(
        class_names=("big", "little"),
        ratios=(1.0, 0.5),
        probe_shape=(512, 512, 512),
        backend="cost-model",
        times_s=(1.0, 2.0),
    )
    mesh = AsymmetricMesh.from_calibration(classes, cal, strategy="sas")
    assert mesh.classes[1].rel_throughput == 0.5


def test_wallclock_calibration_rejects_heterogeneous_specs():
    # One host cannot time two different core specs; the calibration must
    # refuse rather than silently produce ~1:1 ratios.
    with pytest.raises(ValueError, match="heterogeneous"):
        R.calibrate_class_ratios(biglittle_classes(), backend="wallclock")


def test_sweep_ratio_knob_prefers_asymmetric():
    best, results = R.sweep_ratio_knob(2048, ratios=(1, 2, 3, 4, 5, 6, 7))
    # The paper's sweep peaks in the 3-6 region (A15:A7 ≈ 4), never at 1.
    assert best > 1.0
    assert len(results) == 7


# ---------------------------------------------------------------------------
# Measurement backends agree on ordering for a clear-cut case
# ---------------------------------------------------------------------------


def test_wallclock_backend_runs_small():
    cfg = B.BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
    t = M.wallclock_time(128, 128, 128, cfg, dtype=jnp.float32, reps=1, warmup=0)
    assert t > 0.0


def test_wallclock_times_the_lean_kernel_too():
    cfg = B.BlockConfig(bm=128, bk=128, bn=128, dtype_bytes=4)
    t = M.wallclock_time(128, 128, 128, cfg, dtype=jnp.float32, reps=1, warmup=0,
                         kernel_backend="pallas_lean")
    assert t > 0.0
    with pytest.raises(ValueError, match="cannot time kernel backend"):
        M.wallclock_time(128, 128, 128, cfg, kernel_backend="xla")


# ---------------------------------------------------------------------------
# Micro-kernel variants as a search dimension (paper §5.3)
# ---------------------------------------------------------------------------

# A deliberately constrained, memory-bound core: 2 MiB VMEM and thin HBM.
# Here the lean kernel's larger single-buffered panels beat the pipelined
# kernel's overlap — the regime the variant dimension exists for.
NANO = B.TpuCoreSpec(
    name="tpu-nano", vmem_bytes=2 * 1024 * 1024,
    peak_flops=200e12, hbm_bw=50e9,
)


def test_kernel_candidates_widen_the_feasible_set():
    cands = CAND.enumerate_kernel_candidates(
        1024, 1024, 1024, spec=NANO, dtype_bytes=4
    )
    by_backend = {}
    for c in cands:
        by_backend.setdefault(c.backend, []).append(c.cfg)
    assert set(by_backend) == {"pallas", "pallas_lean"}
    # Every candidate is feasible under its own kernel's VMEM model...
    for cfg in by_backend["pallas"]:
        assert cfg.fits(NANO)
    for cfg in by_backend["pallas_lean"]:
        assert cfg.fits(NANO, double_buffer=False)
    # ...and the lean set contains configs the pipelined kernel cannot
    # hold (the variant genuinely widens the search space).
    lean_only = [c for c in by_backend["pallas_lean"] if not c.fits(NANO)]
    assert lean_only
    # Dedup covers the variant axis: (cfg, backend) pairs are unique.
    keys = {c.key for c in cands}
    assert len(keys) == len(cands)
    with pytest.raises(ValueError, match="unknown kernel backend"):
        CAND.enumerate_kernel_candidates(256, 256, 256, backends=["mosaic"])
    # Dispatch entries that are not timeable kernels are rejected too:
    # "xla" and the interpret twins are execution modes, not variants a
    # scorer can model (regression: they used to pass validation and leak
    # into the cache's recorded-variant field).
    for not_a_kernel in ("xla", "pallas_interpret", "pallas_lean_interpret"):
        with pytest.raises(ValueError, match="unknown kernel backend"):
            CAND.enumerate_kernel_candidates(256, 256, 256,
                                             backends=[not_a_kernel])


def test_kernel_backends_derive_from_the_registry():
    """One variant registry: the search dimension, the wallclock timer,
    and the benchmarks all derive from kernels.gemm.GEMM_KERNELS, and
    every registered variant has dispatch + interpret-twin entries."""

    from repro.core import execution as X
    from repro.kernels.gemm import GEMM_KERNELS

    assert CAND.KERNEL_BACKENDS == tuple(GEMM_KERNELS)
    for name in GEMM_KERNELS:
        assert name in X.BACKENDS
        assert X.interpret_twin(name) in X.BACKENDS


def test_cost_model_serializes_lean_streams():
    """Pipelined: max(compute, memory) + overhead.  Lean single-buffers,
    so each K step waits for its DMA: compute + memory + overhead."""

    cfg = B.BlockConfig(bm=256, bk=256, bn=256, dtype_bytes=4)
    pip = M.cost_breakdown(512, 512, 512, cfg, spec=NANO)
    lean = M.cost_breakdown(512, 512, 512, cfg, spec=NANO,
                            kernel_backend="pallas_lean")
    assert pip.compute_s == lean.compute_s and pip.memory_s == lean.memory_s
    assert pip.time_s == max(pip.compute_s, pip.memory_s) + pip.overhead_s
    assert lean.time_s == lean.compute_s + lean.memory_s + lean.overhead_s
    assert lean.time_s > pip.time_s  # same config: overlap always wins


def test_search_picks_lean_when_panels_beat_overlap(tmp_path):
    """On the constrained memory-bound spec the lean-only panels cut HBM
    re-reads by more than the lost overlap costs: the search organically
    selects pallas_lean and the cache records the winning variant."""

    cache = C.TuningCache(path=str(tmp_path / "cache.json"))
    res = T.tune_shapes(
        [(1024, 1024, 1024)], spec=NANO, dtype="f32",
        backend_name="cost-model", cache=cache,
    )[0]
    assert res.best_backend == "pallas_lean"
    assert res.best_time_s < res.analytical_time_s  # beats the pipelined seed
    assert not res.best.fits(NANO)                  # a lean-only panel won
    assert res.best.fits(NANO, double_buffer=False)

    key = C.shape_bucket_key(NANO.name, "float32", 1024, 1024, 1024)
    entry = cache.entries[key]
    assert entry["backend"] == "pallas_lean"
    assert entry["measured_with"] == "cost-model"

    # A rerun is a cache hit that reports the recorded variant.
    hit = T.tune_shapes(
        [(1024, 1024, 1024)], spec=NANO, dtype="f32",
        backend_name="cost-model", cache=cache,
    )[0]
    assert hit.cache_hit and hit.best_backend == "pallas_lean"


def test_single_variant_search_unchanged():
    """kernel_backends=('pallas',) calls the scorer 4-arg (old protocol)
    and never proposes lean-only configs."""

    calls = []

    def scorer(m, k, n, cfg):  # no kernel_backend kwarg: the old contract
        calls.append(cfg)
        return M.cost_model_time(m, k, n, cfg, spec=NANO)

    res = T.search_shape(512, 512, 512, spec=NANO, dtype_bytes=4,
                         backend=scorer, kernel_backends=("pallas",))
    assert res.best_backend == "pallas"
    assert calls and all(c.fits(NANO) for c in calls)


def test_old_cache_backend_field_not_misread_as_variant(tmp_path, monkeypatch):
    """Pre-variant caches stored the measurement backend ("cost-model")
    under "backend"; consumers must treat that as 'no variant recorded'
    and keep the default kernel."""

    from repro.core import execution as X

    cfg = B.BlockConfig(bm=256, bk=256, bn=256, dtype_bytes=2)
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put(B.TPU_V5E.name, "bfloat16", 512, 512, 512, cfg, backend="cost-model")
    cache.save()
    monkeypatch.setenv(C.ENV_VAR, path)

    assert C.cached_kernel_backend(512, 512, 512, "bfloat16",
                                   spec_name=B.TPU_V5E.name) == "cost-model"
    assert X.tuned_kernel_backend(512, 512, 512, spec=B.TPU_V5E,
                                  dtype_name="bfloat16") is None

    from repro.core.control_tree import build_control_trees

    tree = build_control_trees(
        {"x": B.TPU_V5E}, 512, 512, 512, backend="pallas_interpret"
    )["x"]
    assert tree.block_source == "tuned" and tree.block == cfg
    assert tree.backend == "pallas_interpret"  # default kernel kept


def test_lean_recorded_entry_never_reaches_pipelined_consumers(
    tmp_path, monkeypatch
):
    """Regression: a cache winner recorded for the lean kernel carries a
    single-buffer-only block; the pipelined kernel's working set is twice
    what that block was validated under, so every double-buffered lookup
    path must treat the entry as a miss (and the lean paths keep it)."""

    from repro.core import execution as X

    # Lean-only on TPU_LITTLE: ~6.0 MiB single- vs ~10.0 MiB double-buffered.
    cfg = B.BlockConfig(bm=512, bk=1280, bn=1024, dtype_bytes=2)
    assert not cfg.fits(B.TPU_LITTLE) and cfg.fits(B.TPU_LITTLE, double_buffer=False)
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put(B.TPU_LITTLE.name, "bfloat16", 2048, 2048, 2048, cfg,
              backend="pallas_lean")
    cache.save()
    monkeypatch.setenv(C.ENV_VAR, path)
    monkeypatch.setenv(C.ENV_SPEC_VAR, B.TPU_LITTLE.name)

    # The kernel-path resolver: pipelined consumer misses, lean consumer hits.
    got, src = X.resolve_block_config(
        2048, 2048, 2048, spec=B.TPU_LITTLE, dtype_name="bfloat16",
        dtype_bytes=2, double_buffer=True,
    )
    assert src == "analytical" and got.fits(B.TPU_LITTLE)
    got, src = X.resolve_block_config(
        2048, 2048, 2048, spec=B.TPU_LITTLE, dtype_name="bfloat16",
        dtype_bytes=2, double_buffer=False,
    )
    assert src == "tuned" and got == cfg
    # Same via the env-spec (cfg=None kernel path, spec=None).
    _, src = X.resolve_block_config(2048, 2048, 2048, dtype_name="bfloat16",
                                    dtype_bytes=2, double_buffer=True)
    assert src == "analytical"

    # The per-call context path: a pipelined tree skips the lean-only
    # entry for off-bucket calls and derives a block its kernel can hold.
    from repro.core.control_tree import ControlTree

    tree = ControlTree(
        device_class="little",
        block=B.derive_block_config(256, 256, 256, spec=B.TPU_LITTLE),
        backend="pallas_interpret", spec=B.TPU_LITTLE,
        problem_shape=(256, 256, 256),
    )
    got = X.context_for_tree(tree).block_config(2048, 2048, 2048, "bfloat16", 2)
    assert got.fits(B.TPU_LITTLE)
    # ...while the tree-build path pairs the entry with the lean backend.
    from repro.core.control_tree import build_control_trees

    built = build_control_trees(
        {"little": B.TPU_LITTLE}, 2048, 2048, 2048, backend="pallas_interpret"
    )["little"]
    assert built.block_source == "tuned" and built.block == cfg
    assert built.backend == "pallas_lean_interpret"


def test_cache_aware_false_baseline_stays_uniform(tmp_path, monkeypatch):
    """Regression: the single-control-tree SAS baseline (cache_aware=False)
    must mirror the *first* class's configuration wholesale — per-class
    recorded variants may not leak into the deliberately uniform run."""

    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cfg = B.BlockConfig(bm=256, bk=256, bn=256, dtype_bytes=2)
    cache.put(B.TPU_V5E.name, "bfloat16", 512, 512, 512, cfg, backend="pallas")
    cache.put(B.TPU_LITTLE.name, "bfloat16", 512, 512, 512, cfg,
              backend="pallas_lean")
    cache.save()
    monkeypatch.setenv(C.ENV_VAR, path)

    from repro.core.control_tree import build_control_trees

    trees = build_control_trees(
        {"big": B.TPU_V5E, "little": B.TPU_LITTLE}, 512, 512, 512,
        backend="pallas", cache_aware=False,
    )
    assert trees["little"].block == trees["big"].block
    assert trees["little"].backend == trees["big"].backend == "pallas"


def test_recorded_variant_reaches_the_tree(tmp_path, monkeypatch):
    """A cache entry recording pallas_lean routes that class's tree to the
    lean kernel (mapped onto the requested compiled/interpret family)."""

    cfg = B.BlockConfig(bm=256, bk=256, bn=256, dtype_bytes=2)
    path = str(tmp_path / "cache.json")
    cache = C.TuningCache(path=path)
    cache.put(B.TPU_LITTLE.name, "bfloat16", 512, 512, 512, cfg,
              backend="pallas_lean")
    cache.save()
    monkeypatch.setenv(C.ENV_VAR, path)

    from repro.core.control_tree import build_control_trees

    tree = build_control_trees(
        {"little": B.TPU_LITTLE}, 512, 512, 512, backend="pallas_interpret"
    )["little"]
    assert tree.block_source == "tuned"
    assert tree.backend == "pallas_lean_interpret"
    tree_hw = build_control_trees(
        {"little": B.TPU_LITTLE}, 512, 512, 512, backend="pallas"
    )["little"]
    assert tree_hw.backend == "pallas_lean"
    # XLA trees ignore kernel variants (blocks are decorative there).
    tree_xla = build_control_trees(
        {"little": B.TPU_LITTLE}, 512, 512, 512, backend="xla"
    )["little"]
    assert tree_xla.backend == "xla"
